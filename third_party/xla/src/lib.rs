//! Offline stub of the `xla` PJRT bindings.
//!
//! Mirrors the API surface used by `florida`'s runtime module
//! (`PjRtClient`, `PjRtLoadedExecutable`, `HloModuleProto`,
//! `XlaComputation`, `Literal`) without linking the XLA extension C
//! library. Every entry point that would talk to PJRT returns
//! [`Error::Unavailable`]; `PjRtClient::cpu()` fails first, so the
//! downstream methods exist only to satisfy the type checker.
//!
//! Swap this path dependency for the registry crate to run real PJRT.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub cannot perform PJRT operations.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT unavailable ({what}): built against the vendored xla stub; \
                 link the real xla crate + XLA extension library for runtime support"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error::Unavailable(what.to_string()))
}

/// Stub PJRT client. `cpu()` always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub HLO module proto (text loader).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Matches the real signature shape: `execute::<Literal>(&args)` →
    /// per-device, per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub literal (host tensor).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unavailable("Literal::get_first_element")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        unavailable("Literal::to_tuple2")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let text = err.to_string();
        assert!(text.contains("PJRT unavailable"));
        assert!(text.contains("stub"));
    }

    #[test]
    fn literal_constructors_exist() {
        let _ = Literal::vec1(&[1.0f32, 2.0]);
        let _ = Literal::vec1(&[1i32, 2]);
        let _ = Literal::scalar(0.5f32);
        assert!(Literal::vec1(&[0i32]).reshape(&[1, 1]).is_err());
    }
}
