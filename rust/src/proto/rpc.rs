//! Typed RPC layer over the [`Msg`] wire enum.
//!
//! Each client→server request variant is paired with its typed reply
//! (`Register → RegisterAck`, `PollTask → TaskOffer`, …) through the
//! [`Rpc`] trait. Conversions typed → [`Msg`] are infallible in both
//! directions; extraction of a typed reply from a wire message is where
//! protocol errors surface: [`Reply::from_msg`] turns `ErrorReply` and
//! `Ack { ok: false }` into [`Error::Server`], so a server-side failure
//! can never be silently dropped by a caller again.
//!
//! The router ([`crate::services::router`]) uses [`method_of`] /
//! [`client_id_of`] to name and authenticate requests without decoding
//! them twice; the client stubs ([`crate::client::FloridaClient`]) use
//! `Rpc::into_msg` + `Reply::from_msg` to expose a typed API over any
//! [`crate::client::ServerApi`].

use crate::crypto::attest::Verdict;
use crate::error::{Error, Result};

use super::msg::{Msg, PeerShare, RecoveredShare};
use super::{DeviceCaps, DeviceProfile, LoadHints, RoundRole, TaskDescriptor};

/// A typed server→client reply.
pub trait Reply: Sized + Send {
    /// Infallible conversion back onto the wire enum.
    fn into_msg(self) -> Msg;
    /// Extract the typed reply. `ErrorReply` becomes
    /// [`Error::Server`]; any other variant is a transport-level
    /// protocol violation.
    fn from_msg(m: Msg) -> Result<Self>;
}

/// A typed client→server request, paired with its reply type.
pub trait Rpc: Sized + Send {
    type Reply: Reply;
    /// Wire method name (per-RPC metrics, routing, logs).
    const METHOD: &'static str;
    /// Infallible conversion onto the wire enum.
    fn into_msg(self) -> Msg;
    /// Recover the typed request from a wire message (`None` when the
    /// message is a different variant).
    fn from_msg(m: Msg) -> Option<Self>;
}

fn reply_err(m: Msg) -> Error {
    match m {
        Msg::ErrorReply { message } => Error::Server(message),
        other => Error::unexpected_reply(&other),
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

macro_rules! request {
    ($(#[$doc:meta])* $req:ident { $($f:ident : $t:ty),* $(,)? } => $reply:ty, $method:literal) => {
        $(#[$doc])*
        #[derive(Clone, Debug, PartialEq)]
        pub struct $req {
            $(pub $f: $t),*
        }

        impl Rpc for $req {
            type Reply = $reply;
            const METHOD: &'static str = $method;

            fn into_msg(self) -> Msg {
                Msg::$req { $($f: self.$f),* }
            }

            fn from_msg(m: Msg) -> Option<Self> {
                match m {
                    Msg::$req { $($f),* } => Some($req { $($f),* }),
                    _ => None,
                }
            }
        }

        impl From<$req> for Msg {
            fn from(r: $req) -> Msg {
                r.into_msg()
            }
        }
    };
}

request!(
    /// Attest + register a device with the selection service.
    Register {
        device_id: String,
        verdict: Verdict,
        caps: DeviceCaps,
    } => RegisterAck,
    "register"
);

request!(
    /// Ask for an available task for (app, workflow).
    PollTask {
        client_id: u64,
        app_name: String,
        workflow_name: String,
    } => TaskOffer,
    "poll_task"
);

request!(
    /// Volunteer for the task's next round with a per-round DH pubkey.
    JoinRound {
        client_id: u64,
        task_id: u64,
        dh_pubkey: [u8; 32],
    } => JoinAck,
    "join_round"
);

request!(
    /// Poll the current round obligation.
    FetchRound {
        client_id: u64,
        task_id: u64,
    } => RoundRole,
    "fetch_round"
);

request!(
    /// Deposit encrypted Shamir shares for the virtual group.
    SecAggShares {
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<PeerShare>,
    } => Ack,
    "secagg_shares"
);

request!(
    /// Plaintext model-delta upload.
    UploadPlain {
        client_id: u64,
        task_id: u64,
        round: u64,
        base_version: u64,
        delta: Vec<f32>,
        weight: f64,
        loss: f64,
    } => Ack,
    "upload_plain"
);

request!(
    /// Masked (secure-aggregation) upload.
    UploadMasked {
        client_id: u64,
        task_id: u64,
        round: u64,
        vg_id: u32,
        masked: Vec<u32>,
        loss: f64,
    } => Ack,
    "upload_masked"
);

request!(
    /// Return recovered shares of dropped peers.
    UnmaskResponse {
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<RecoveredShare>,
    } => Ack,
    "unmask_response"
);

request!(
    /// Admin/status query for a task.
    GetTaskStatus { task_id: u64 } => TaskStatus,
    "get_task_status"
);

request!(
    /// Admin pull of the server telemetry snapshot, rendered in the
    /// requested `obs::export::FORMAT_*` encoding.
    GetTelemetry { format: u32 } => TelemetryReport,
    "get_telemetry"
);

request!(
    /// Liveness ping keeping the device's registry entry fresh. v1
    /// compatibility surface: on a v2 server it also renews (or opens)
    /// the client's implicit session lease.
    Heartbeat { client_id: u64 } => Ack,
    "heartbeat"
);

request!(
    /// Protocol v2 handshake: attest + register + submit the device
    /// profile and the highest protocol version the client speaks.
    SessionOpen {
        device_id: String,
        verdict: Verdict,
        caps: DeviceCaps,
        profile: DeviceProfile,
        proto_max: u32,
    } => SessionGrant,
    "session_open"
);

request!(
    /// Renew the liveness lease, carrying load/battery hints.
    SessionHeartbeat {
        client_id: u64,
        token: u64,
        hints: LoadHints,
    } => LeaseAck,
    "session_heartbeat"
);

request!(
    /// Release the lease early (graceful departure).
    SessionClose {
        client_id: u64,
        token: u64,
    } => Ack,
    "session_close"
);

request!(
    /// A leaf aggregator claims its deterministic slice of the current
    /// round's cohort (hierarchical aggregation).
    LeafAssign {
        leaf_id: u64,
        task_id: u64,
        leaf_index: u32,
        leaf_count: u32,
    } => LeafAssignment,
    "leaf_assign"
);

request!(
    /// A leaf forwards its merged partial accumulator to the master.
    ForwardPartial {
        leaf_id: u64,
        task_id: u64,
        round: u64,
        base_version: u64,
        members: Vec<u64>,
        sum: Vec<f64>,
        total_weight: f64,
        count: u64,
        loss_sum: f64,
        min_loss: f64,
    } => LeafAck,
    "forward_partial"
);

// ---------------------------------------------------------------------------
// Replies
// ---------------------------------------------------------------------------

/// Registration outcome. `accepted: false` keeps the structured reason
/// (the SDK maps it to `Error::Attestation`); only `ErrorReply` is an
/// `Err` at this layer.
#[derive(Clone, Debug, PartialEq)]
pub struct RegisterAck {
    pub accepted: bool,
    pub client_id: u64,
    pub reason: String,
}

impl Reply for RegisterAck {
    fn into_msg(self) -> Msg {
        Msg::RegisterAck {
            accepted: self.accepted,
            client_id: self.client_id,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::RegisterAck {
                accepted,
                client_id,
                reason,
            } => Ok(RegisterAck {
                accepted,
                client_id,
                reason,
            }),
            other => Err(reply_err(other)),
        }
    }
}

/// The advertised task, if any matched the poll.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskOffer {
    pub task: Option<TaskDescriptor>,
}

impl Reply for TaskOffer {
    fn into_msg(self) -> Msg {
        Msg::TaskOffer { task: self.task }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::TaskOffer { task } => Ok(TaskOffer { task }),
            other => Err(reply_err(other)),
        }
    }
}

/// Join outcome. Like [`RegisterAck`], a structured refusal is data the
/// SDK inspects ("already joined", criteria failures), not an `Err`.
#[derive(Clone, Debug, PartialEq)]
pub struct JoinAck {
    pub accepted: bool,
    pub reason: String,
}

impl Reply for JoinAck {
    fn into_msg(self) -> Msg {
        Msg::JoinAck {
            accepted: self.accepted,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::JoinAck { accepted, reason } => Ok(JoinAck { accepted, reason }),
            other => Err(reply_err(other)),
        }
    }
}

impl Reply for RoundRole {
    fn into_msg(self) -> Msg {
        Msg::RoundPlan { role: self }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::RoundPlan { role } => Ok(role),
            other => Err(reply_err(other)),
        }
    }
}

/// Positive acknowledgement. A wire `Ack { ok: false }` never reaches
/// callers as a value — `from_msg` converts it to [`Error::Server`], so
/// a rejected upload/share/unmask is always an observable `Err`.
#[derive(Clone, Debug, PartialEq)]
pub struct Ack {
    pub reason: String,
}

impl Reply for Ack {
    fn into_msg(self) -> Msg {
        Msg::Ack {
            ok: true,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::Ack { ok: true, reason } => Ok(Ack { reason }),
            Msg::Ack { ok: false, reason } => Err(Error::Server(reason)),
            other => Err(reply_err(other)),
        }
    }
}

/// Session handshake outcome. Like [`RegisterAck`], `accepted: false`
/// keeps the structured reason (attestation failures) as data; only
/// `ErrorReply` — e.g. a v1 server that cannot route `SessionOpen` —
/// is an `Err` at this layer, which is exactly the signal the SDK uses
/// to negotiate down to the one-shot flow.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionGrant {
    pub accepted: bool,
    pub client_id: u64,
    pub token: u64,
    pub lease_ms: u64,
    /// Negotiated protocol version (see [`crate::proto::negotiate_proto`]).
    pub proto: u32,
    pub reason: String,
}

impl Reply for SessionGrant {
    fn into_msg(self) -> Msg {
        Msg::SessionGrant {
            accepted: self.accepted,
            client_id: self.client_id,
            token: self.token,
            lease_ms: self.lease_ms,
            proto: self.proto,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::SessionGrant {
                accepted,
                client_id,
                token,
                lease_ms,
                proto,
                reason,
            } => Ok(SessionGrant {
                accepted,
                client_id,
                token,
                lease_ms,
                proto,
                reason,
            }),
            other => Err(reply_err(other)),
        }
    }
}

/// Lease-renewal outcome. `renewed: false` is protocol data the SDK
/// inspects (lease lost → reopen the session), not an `Err`.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseAck {
    pub renewed: bool,
    pub lease_ms: u64,
    pub reason: String,
}

impl Reply for LeaseAck {
    fn into_msg(self) -> Msg {
        Msg::LeaseAck {
            renewed: self.renewed,
            lease_ms: self.lease_ms,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::LeaseAck {
                renewed,
                lease_ms,
                reason,
            } => Ok(LeaseAck {
                renewed,
                lease_ms,
                reason,
            }),
            other => Err(reply_err(other)),
        }
    }
}

/// Round-slice grant for a leaf aggregator. A structured refusal
/// (`accepted: false` — no open round yet, bad leaf index) is data the
/// leaf inspects to back off and re-ask, mirroring [`JoinAck`].
#[derive(Clone, Debug, PartialEq)]
pub struct LeafAssignment {
    pub accepted: bool,
    pub round: u64,
    pub base_version: u64,
    pub members: Vec<u64>,
    pub reason: String,
}

impl Reply for LeafAssignment {
    fn into_msg(self) -> Msg {
        Msg::LeafAssignment {
            accepted: self.accepted,
            round: self.round,
            base_version: self.base_version,
            members: self.members,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::LeafAssignment {
                accepted,
                round,
                base_version,
                members,
                reason,
            } => Ok(LeafAssignment {
                accepted,
                round,
                base_version,
                members,
                reason,
            }),
            other => Err(reply_err(other)),
        }
    }
}

/// Partial-merge acknowledgement. Like [`Ack`], a wire
/// `LeafAck { ok: false }` surfaces as [`Error::Server`] — a rejected
/// partial (stale round, duplicate members) is always an observable
/// `Err` at the leaf.
#[derive(Clone, Debug, PartialEq)]
pub struct LeafAck {
    /// Member updates the master credited from the partial.
    pub folded: u64,
    pub reason: String,
}

impl Reply for LeafAck {
    fn into_msg(self) -> Msg {
        Msg::LeafAck {
            ok: true,
            folded: self.folded,
            reason: self.reason,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::LeafAck {
                ok: true,
                folded,
                reason,
            } => Ok(LeafAck { folded, reason }),
            Msg::LeafAck {
                ok: false, reason, ..
            } => Err(Error::Server(reason)),
            other => Err(reply_err(other)),
        }
    }
}

/// Task status snapshot (admin surface).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskStatus {
    pub task: TaskDescriptor,
    pub participants: u64,
    pub last_round_duration_ms: u64,
    pub last_accuracy: f64,
    pub last_loss: f64,
    pub epsilon: f64,
}

impl Reply for TaskStatus {
    fn into_msg(self) -> Msg {
        Msg::TaskStatus {
            task: self.task,
            participants: self.participants,
            last_round_duration_ms: self.last_round_duration_ms,
            last_accuracy: self.last_accuracy,
            last_loss: self.last_loss,
            epsilon: self.epsilon,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::TaskStatus {
                task,
                participants,
                last_round_duration_ms,
                last_accuracy,
                last_loss,
                epsilon,
            } => Ok(TaskStatus {
                task,
                participants,
                last_round_duration_ms,
                last_accuracy,
                last_loss,
                epsilon,
            }),
            other => Err(reply_err(other)),
        }
    }
}

/// Rendered telemetry snapshot (admin surface). `body` is opaque text in
/// the echoed `obs::export::FORMAT_*` encoding.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryReport {
    pub format: u32,
    pub body: String,
}

impl Reply for TelemetryReport {
    fn into_msg(self) -> Msg {
        Msg::TelemetryReport {
            format: self.format,
            body: self.body,
        }
    }

    fn from_msg(m: Msg) -> Result<Self> {
        match m {
            Msg::TelemetryReport { format, body } => Ok(TelemetryReport { format, body }),
            other => Err(reply_err(other)),
        }
    }
}

// ---------------------------------------------------------------------------
// Wire-message introspection used by the router
// ---------------------------------------------------------------------------

/// Wire method name of a client→server request; `None` for server→client
/// replies (which no service handles).
pub fn method_of(m: &Msg) -> Option<&'static str> {
    Some(match m {
        Msg::Register { .. } => Register::METHOD,
        Msg::PollTask { .. } => PollTask::METHOD,
        Msg::JoinRound { .. } => JoinRound::METHOD,
        Msg::FetchRound { .. } => FetchRound::METHOD,
        Msg::SecAggShares { .. } => SecAggShares::METHOD,
        Msg::UploadPlain { .. } => UploadPlain::METHOD,
        Msg::UploadMasked { .. } => UploadMasked::METHOD,
        Msg::UnmaskResponse { .. } => UnmaskResponse::METHOD,
        Msg::GetTaskStatus { .. } => GetTaskStatus::METHOD,
        Msg::GetTelemetry { .. } => GetTelemetry::METHOD,
        Msg::Heartbeat { .. } => Heartbeat::METHOD,
        Msg::SessionOpen { .. } => SessionOpen::METHOD,
        Msg::SessionHeartbeat { .. } => SessionHeartbeat::METHOD,
        Msg::SessionClose { .. } => SessionClose::METHOD,
        Msg::LeafAssign { .. } => LeafAssign::METHOD,
        Msg::ForwardPartial { .. } => ForwardPartial::METHOD,
        _ => return None,
    })
}

/// The registered client a request claims to act as. `None` for
/// pre-registration (`Register`) and admin (`GetTaskStatus`) requests,
/// and for server→client messages.
pub fn client_id_of(m: &Msg) -> Option<u64> {
    match m {
        Msg::PollTask { client_id, .. }
        | Msg::JoinRound { client_id, .. }
        | Msg::FetchRound { client_id, .. }
        | Msg::SecAggShares { client_id, .. }
        | Msg::UploadPlain { client_id, .. }
        | Msg::UploadMasked { client_id, .. }
        | Msg::UnmaskResponse { client_id, .. }
        | Msg::Heartbeat { client_id }
        | Msg::SessionHeartbeat { client_id, .. }
        | Msg::SessionClose { client_id, .. } => Some(*client_id),
        // `SessionOpen`, like `Register`, carries no principal: it is the
        // request that *creates* one. `LeafAssign`/`ForwardPartial`
        // carry a `leaf_id`, not a registered-device principal — leaves
        // are trusted platform infrastructure, admitted like admin
        // requests rather than authenticated against the device registry.
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips_through_msg() {
        let req = FetchRound {
            client_id: 7,
            task_id: 3,
        };
        let msg = req.clone().into_msg();
        assert_eq!(method_of(&msg), Some("fetch_round"));
        assert_eq!(client_id_of(&msg), Some(7));
        assert_eq!(FetchRound::from_msg(msg), Some(req));
    }

    #[test]
    fn reply_extraction_is_typed() {
        let role = RoundRole::Wait;
        let msg = role.clone().into_msg();
        assert_eq!(RoundRole::from_msg(msg).unwrap(), RoundRole::Wait);
        // Wrong variant → transport error, not a panic.
        assert!(RoundRole::from_msg(Msg::TaskOffer { task: None }).is_err());
    }

    #[test]
    fn error_reply_becomes_err_server() {
        let e = Ack::from_msg(Msg::ErrorReply {
            message: "boom".into(),
        })
        .unwrap_err();
        assert!(matches!(e, Error::Server(ref m) if m == "boom"));
    }

    #[test]
    fn negative_ack_becomes_err_server() {
        let e = Ack::from_msg(Msg::Ack {
            ok: false,
            reason: "stale round".into(),
        })
        .unwrap_err();
        assert!(matches!(e, Error::Server(ref m) if m == "stale round"));
        assert!(Ack::from_msg(Msg::Ack {
            ok: true,
            reason: String::new(),
        })
        .is_ok());
    }

    #[test]
    fn session_rpcs_are_typed_pairs() {
        let req = SessionHeartbeat {
            client_id: 9,
            token: 3,
            hints: LoadHints::default(),
        };
        let msg = req.clone().into_msg();
        assert_eq!(method_of(&msg), Some("session_heartbeat"));
        assert_eq!(client_id_of(&msg), Some(9));
        assert_eq!(SessionHeartbeat::from_msg(msg), Some(req));

        let grant = SessionGrant {
            accepted: true,
            client_id: 9,
            token: 3,
            lease_ms: 30_000,
            proto: crate::proto::PROTO_V2,
            reason: String::new(),
        };
        let back = SessionGrant::from_msg(grant.clone().into_msg()).unwrap();
        assert_eq!(back, grant);
        // A v1 server bounces SessionOpen with ErrorReply → Err(Server),
        // the SDK's cue to fall back to the one-shot Register flow.
        assert!(matches!(
            SessionGrant::from_msg(Msg::ErrorReply {
                message: "unexpected message".into()
            }),
            Err(Error::Server(_))
        ));
        // A lost lease is data, not an error.
        let ack = LeaseAck::from_msg(Msg::LeaseAck {
            renewed: false,
            lease_ms: 0,
            reason: "no live session".into(),
        })
        .unwrap();
        assert!(!ack.renewed);
    }

    #[test]
    fn leaf_rpcs_are_typed_pairs() {
        let req = LeafAssign {
            leaf_id: 100,
            task_id: 2,
            leaf_index: 0,
            leaf_count: 2,
        };
        let msg = req.clone().into_msg();
        assert_eq!(method_of(&msg), Some("leaf_assign"));
        // Leaves are infrastructure, not device principals.
        assert_eq!(client_id_of(&msg), None);
        assert_eq!(LeafAssign::from_msg(msg), Some(req));

        let fwd = ForwardPartial {
            leaf_id: 100,
            task_id: 2,
            round: 1,
            base_version: 1,
            members: vec![3, 4],
            sum: vec![0.5],
            total_weight: 2.0,
            count: 2,
            loss_sum: 0.2,
            min_loss: f64::INFINITY,
        };
        let msg = fwd.clone().into_msg();
        assert_eq!(method_of(&msg), Some("forward_partial"));
        assert_eq!(client_id_of(&msg), None);
        assert_eq!(ForwardPartial::from_msg(msg), Some(fwd));

        // A rejected partial is an observable Err at the leaf.
        let e = LeafAck::from_msg(Msg::LeafAck {
            ok: false,
            folded: 0,
            reason: "stale round".into(),
        })
        .unwrap_err();
        assert!(matches!(e, Error::Server(ref m) if m == "stale round"));
        let ok = LeafAck::from_msg(Msg::LeafAck {
            ok: true,
            folded: 2,
            reason: String::new(),
        })
        .unwrap();
        assert_eq!(ok.folded, 2);
        // A structured assignment refusal is data, not an error.
        let a = LeafAssignment::from_msg(Msg::LeafAssignment {
            accepted: false,
            round: 0,
            base_version: 0,
            members: vec![],
            reason: "no open round".into(),
        })
        .unwrap();
        assert!(!a.accepted);
    }

    #[test]
    fn telemetry_rpc_is_typed_and_admin_scoped() {
        let req = GetTelemetry { format: 1 };
        let msg = req.clone().into_msg();
        assert_eq!(method_of(&msg), Some("get_telemetry"));
        // Admin surface, like GetTaskStatus: no device principal.
        assert_eq!(client_id_of(&msg), None);
        assert_eq!(GetTelemetry::from_msg(msg), Some(req));

        let reply = TelemetryReport {
            format: 1,
            body: "florida_rounds_committed 2\n".into(),
        };
        let back = TelemetryReport::from_msg(reply.clone().into_msg()).unwrap();
        assert_eq!(back, reply);
        assert!(TelemetryReport::from_msg(Msg::ErrorReply {
            message: "x".into()
        })
        .is_err());
    }

    #[test]
    fn server_to_client_messages_have_no_method() {
        assert_eq!(method_of(&Msg::TaskOffer { task: None }), None);
        assert_eq!(client_id_of(&Msg::GetTaskStatus { task_id: 1 }), None);
        assert_eq!(
            client_id_of(&Msg::Register {
                device_id: "d".into(),
                verdict: crate::crypto::attest::Authority::new(b"k").issue(
                    "d",
                    crate::crypto::attest::IntegrityTier::Device,
                    1,
                    2
                ),
                caps: DeviceCaps::default(),
            }),
            None
        );
    }
}
