//! Platform protocol: core datatypes shared by services, SDK and wire.

pub mod msg;
pub mod rpc;

use std::sync::Arc;

use crate::codec::{Reader, Wire, Writer};
use crate::crypto::attest::{IntegrityTier, Verdict};
use crate::error::{Error, Result};
use crate::util::json::Json;

pub use msg::{
    decode_frame, decode_frame_traced, encode_frame, encode_frame_traced, Msg, WireCodec,
};

// ---------------------------------------------------------------------------
// Session protocol v2: capability negotiation + liveness leases
// ---------------------------------------------------------------------------

/// Protocol v1: the original one-shot surface (`Register` → `PollTask` →
/// `JoinRound` → …) with fire-and-forget heartbeats.
pub const PROTO_V1: u32 = 1;
/// Protocol v2: negotiated sessions — `SessionOpen` submits a
/// [`DeviceProfile`], the server answers with a token + liveness lease,
/// and `SessionHeartbeat` renews the lease carrying [`LoadHints`].
pub const PROTO_V2: u32 = 2;

/// Version negotiation: the server grants the highest version both sides
/// speak. Unknown future versions negotiate *down* to v2; a nonsensical
/// 0 negotiates up to v1 — the handshake never fails on version alone.
pub fn negotiate_proto(client_max: u32) -> u32 {
    client_max.clamp(PROTO_V1, PROTO_V2)
}

/// Compute tier a device reports about itself (the paper's "wide variety
/// of performance characteristics" — §1). Orders low → high so
/// capability-aware cohort policies can rank on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ComputeTier {
    Low = 0,
    Mid = 1,
    High = 2,
}

impl ComputeTier {
    pub fn from_u8(v: u8) -> Option<ComputeTier> {
        Some(match v {
            0 => ComputeTier::Low,
            1 => ComputeTier::Mid,
            2 => ComputeTier::High,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ComputeTier::Low => "low",
            ComputeTier::Mid => "mid",
            ComputeTier::High => "high",
        }
    }
}

/// Bandwidth class a device reports about its network.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BandwidthClass {
    Constrained = 0,
    Broadband = 1,
    Fast = 2,
}

impl BandwidthClass {
    pub fn from_u8(v: u8) -> Option<BandwidthClass> {
        Some(match v {
            0 => BandwidthClass::Constrained,
            1 => BandwidthClass::Broadband,
            2 => BandwidthClass::Fast,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BandwidthClass::Constrained => "constrained",
            BandwidthClass::Broadband => "broadband",
            BandwidthClass::Fast => "fast",
        }
    }
}

/// The heterogeneity axes a device submits at `SessionOpen` (platform
/// identity already rides in [`DeviceCaps`]): compute tier, bandwidth
/// class, and how long the device expects to remain available.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    pub compute_tier: ComputeTier,
    pub bandwidth: BandwidthClass,
    /// Expected availability window, ms (0 = unknown). A duration, so
    /// it rides JSON as a number — keep below 2^53 (f64-exact); only
    /// credentials (tokens, nonces) get the string encoding.
    pub avail_window_ms: u64,
}

impl Default for DeviceProfile {
    fn default() -> Self {
        DeviceProfile {
            compute_tier: ComputeTier::Mid,
            bandwidth: BandwidthClass::Broadband,
            avail_window_ms: 0,
        }
    }
}

impl Wire for DeviceProfile {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.compute_tier as u8);
        w.put_u8(self.bandwidth as u8);
        w.put_u64(self.avail_window_ms);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(DeviceProfile {
            compute_tier: ComputeTier::from_u8(r.get_u8()?)
                .ok_or_else(|| Error::Codec("bad compute tier".into()))?,
            bandwidth: BandwidthClass::from_u8(r.get_u8()?)
                .ok_or_else(|| Error::Codec("bad bandwidth class".into()))?,
            avail_window_ms: r.get_u64()?,
        })
    }
}

impl DeviceProfile {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("compute_tier", self.compute_tier as u8 as u64)
            .set("bandwidth", self.bandwidth as u8 as u64)
            .set("avail_window_ms", self.avail_window_ms)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(DeviceProfile {
            compute_tier: ComputeTier::from_u8(
                j.req_usize("compute_tier").map_err(Error::Codec)? as u8,
            )
            .ok_or_else(|| Error::Codec("bad compute tier".into()))?,
            bandwidth: BandwidthClass::from_u8(
                j.req_usize("bandwidth").map_err(Error::Codec)? as u8,
            )
            .ok_or_else(|| Error::Codec("bad bandwidth class".into()))?,
            avail_window_ms: j.opt_usize("avail_window_ms", 0) as u64,
        })
    }
}

/// Load/battery hints carried by `SessionHeartbeat` (the lease-renewal
/// path): the server's view of how loaded the live fleet is.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LoadHints {
    /// CPU/utilization load, 0..1.
    pub load: f32,
    /// Battery level, 0..1 (negative = unknown / mains-powered).
    pub battery: f32,
    pub charging: bool,
}

impl Default for LoadHints {
    fn default() -> Self {
        LoadHints {
            load: 0.0,
            battery: 1.0,
            charging: true,
        }
    }
}

impl Wire for LoadHints {
    fn encode(&self, w: &mut Writer) {
        w.put_f32(self.load);
        w.put_f32(self.battery);
        w.put_bool(self.charging);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(LoadHints {
            load: r.get_f32()?,
            battery: r.get_f32()?,
            charging: r.get_bool()?,
        })
    }
}

impl LoadHints {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("load", self.load as f64)
            .set("battery", self.battery as f64)
            .set("charging", self.charging)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(LoadHints {
            load: j.opt_f64("load", 0.0) as f32,
            battery: j.opt_f64("battery", 1.0) as f32,
            charging: j.opt_bool("charging", true),
        })
    }
}

/// Device capabilities reported at registration (heterogeneity surface).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceCaps {
    /// e.g. "android", "windows", "ios", "linux"
    pub os: String,
    /// SDK language binding, e.g. "python", "kotlin", "cpp", "dotnet", "js"
    pub sdk: String,
    pub tier: IntegrityTier,
    pub charging: bool,
    pub metered_network: bool,
}

impl Default for DeviceCaps {
    fn default() -> Self {
        DeviceCaps {
            os: "linux".into(),
            sdk: "rust".into(),
            tier: IntegrityTier::Device,
            charging: true,
            metered_network: false,
        }
    }
}

impl Wire for DeviceCaps {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.os);
        w.put_str(&self.sdk);
        w.put_u8(self.tier as u8);
        w.put_bool(self.charging);
        w.put_bool(self.metered_network);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(DeviceCaps {
            os: r.get_str()?,
            sdk: r.get_str()?,
            tier: IntegrityTier::from_u8(r.get_u8()?)
                .ok_or_else(|| Error::Codec("bad tier".into()))?,
            charging: r.get_bool()?,
            metered_network: r.get_bool()?,
        })
    }
}

impl DeviceCaps {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("os", self.os.as_str())
            .set("sdk", self.sdk.as_str())
            .set("tier", self.tier as u8 as u64)
            .set("charging", self.charging)
            .set("metered", self.metered_network)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(DeviceCaps {
            os: j.req_str("os").map_err(Error::Codec)?.to_string(),
            sdk: j.req_str("sdk").map_err(Error::Codec)?.to_string(),
            tier: IntegrityTier::from_u8(j.req_usize("tier").map_err(Error::Codec)? as u8)
                .ok_or_else(|| Error::Codec("bad tier".into()))?,
            charging: j.opt_bool("charging", true),
            metered_network: j.opt_bool("metered", false),
        })
    }
}

/// Device-selection criteria attached to a task (§3.3.1: "set selection
/// criteria for device participation").
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionCriteria {
    pub min_tier: IntegrityTier,
    pub require_charging: bool,
    pub allow_metered: bool,
    /// Allowed OSes; empty = any.
    pub os_allow: Vec<String>,
}

impl Default for SelectionCriteria {
    fn default() -> Self {
        SelectionCriteria {
            min_tier: IntegrityTier::Basic,
            require_charging: false,
            allow_metered: true,
            os_allow: Vec::new(),
        }
    }
}

impl SelectionCriteria {
    /// Does a device qualify for this task?
    pub fn matches(&self, caps: &DeviceCaps) -> bool {
        if caps.tier < self.min_tier {
            return false;
        }
        if self.require_charging && !caps.charging {
            return false;
        }
        if !self.allow_metered && caps.metered_network {
            return false;
        }
        if !self.os_allow.is_empty() && !self.os_allow.iter().any(|o| o == &caps.os) {
            return false;
        }
        true
    }
}

impl Wire for SelectionCriteria {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.min_tier as u8);
        w.put_bool(self.require_charging);
        w.put_bool(self.allow_metered);
        w.put_varint(self.os_allow.len() as u64);
        for os in &self.os_allow {
            w.put_str(os);
        }
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        let min_tier = IntegrityTier::from_u8(r.get_u8()?)
            .ok_or_else(|| Error::Codec("bad tier".into()))?;
        let require_charging = r.get_bool()?;
        let allow_metered = r.get_bool()?;
        let n = r.get_varint()? as usize;
        let mut os_allow = Vec::with_capacity(n.min(64));
        for _ in 0..n {
            os_allow.push(r.get_str()?);
        }
        Ok(SelectionCriteria {
            min_tier,
            require_charging,
            allow_metered,
            os_allow,
        })
    }
}

/// Task lifecycle states (§3.3.1 task management: running, paused, ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    Created = 0,
    Running = 1,
    Paused = 2,
    Completed = 3,
    Cancelled = 4,
    Failed = 5,
}

impl TaskState {
    pub fn from_u8(v: u8) -> Option<TaskState> {
        Some(match v {
            0 => TaskState::Created,
            1 => TaskState::Running,
            2 => TaskState::Paused,
            3 => TaskState::Completed,
            4 => TaskState::Cancelled,
            5 => TaskState::Failed,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskState::Created => "created",
            TaskState::Running => "running",
            TaskState::Paused => "paused",
            TaskState::Completed => "completed",
            TaskState::Cancelled => "cancelled",
            TaskState::Failed => "failed",
        }
    }
}

/// Public task descriptor, as advertised to clients (§3.3.1 fields).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskDescriptor {
    pub task_id: u64,
    pub task_name: String,
    pub app_name: String,
    pub workflow_name: String,
    pub state: TaskState,
    pub round: u64,
    pub total_rounds: u64,
}

impl Wire for TaskDescriptor {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.task_id);
        w.put_str(&self.task_name);
        w.put_str(&self.app_name);
        w.put_str(&self.workflow_name);
        w.put_u8(self.state as u8);
        w.put_u64(self.round);
        w.put_u64(self.total_rounds);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(TaskDescriptor {
            task_id: r.get_u64()?,
            task_name: r.get_str()?,
            app_name: r.get_str()?,
            workflow_name: r.get_str()?,
            state: TaskState::from_u8(r.get_u8()?)
                .ok_or_else(|| Error::Codec("bad task state".into()))?,
            round: r.get_u64()?,
            total_rounds: r.get_u64()?,
        })
    }
}

/// Local-training hyper-parameters sent with each round.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainParams {
    /// Artifact preset name (selects the compiled executable).
    pub preset: String,
    pub lr: f32,
    /// FedProx μ (0 = plain FedAvg local training).
    pub prox_mu: f32,
}

impl Wire for TrainParams {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.preset);
        w.put_f32(self.lr);
        w.put_f32(self.prox_mu);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(TrainParams {
            preset: r.get_str()?,
            lr: r.get_f32()?,
            prox_mu: r.get_f32()?,
        })
    }
}

/// Secure-aggregation setup for one virtual group (§3.1.2, §4.1).
#[derive(Clone, Debug, PartialEq)]
pub struct SecAggSetup {
    pub vg_id: u32,
    /// (client_id, per-round X25519 public key) for every VG member,
    /// sorted by client_id — mask sign convention follows this order.
    pub roster: Vec<(u64, [u8; 32])>,
    /// Quantizer params (shared lattice).
    pub quant_range: f32,
    pub quant_bits: u32,
    /// Shamir threshold for dropout recovery.
    pub threshold: u32,
}

impl Wire for SecAggSetup {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.vg_id);
        w.put_varint(self.roster.len() as u64);
        for (id, pk) in &self.roster {
            w.put_u64(*id);
            w.put_bytes(pk);
        }
        w.put_f32(self.quant_range);
        w.put_u32(self.quant_bits);
        w.put_u32(self.threshold);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        let vg_id = r.get_u32()?;
        let n = r.get_varint()? as usize;
        if n > 4096 {
            return Err(Error::Codec(format!("roster too large: {n}")));
        }
        let mut roster = Vec::with_capacity(n);
        for _ in 0..n {
            let id = r.get_u64()?;
            let pkv = r.get_bytes()?;
            let pk: [u8; 32] = pkv
                .try_into()
                .map_err(|_| Error::Codec("pubkey not 32 bytes".into()))?;
            roster.push((id, pk));
        }
        Ok(SecAggSetup {
            vg_id,
            roster,
            quant_range: r.get_f32()?,
            quant_bits: r.get_u32()?,
            threshold: r.get_u32()?,
        })
    }
}

/// What a polled client should do this round.
#[derive(Clone, Debug, PartialEq)]
pub enum RoundRole {
    /// Keep polling; selection not finished (or round closing).
    Wait,
    /// Not selected this round.
    NotSelected,
    /// Train: full instruction attached.
    Train(RoundInstruction),
    /// Provide unmasking shares for dropped peers.
    Unmask(UnmaskRequest),
    /// Round finished; wait for the next.
    RoundDone,
    /// Task finished.
    TaskDone,
}

/// Full per-round training instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundInstruction {
    pub round: u64,
    /// zlib-compressed `ModelSnapshot`, shared with the orchestrator's
    /// version-keyed [`crate::model::SnapshotStore`] cache — handing an
    /// instruction to a poller is an `Arc` clone, not a recompression.
    pub model_blob: Arc<Vec<u8>>,
    pub train: TrainParams,
    /// Present iff the task uses secure aggregation.
    pub secagg: Option<SecAggSetup>,
    /// Upload deadline, ms since server start.
    pub deadline_ms: u64,
}

impl Wire for RoundInstruction {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.round);
        w.put_bytes(&self.model_blob);
        self.train.encode(w);
        match &self.secagg {
            None => w.put_bool(false),
            Some(s) => {
                w.put_bool(true);
                s.encode(w);
            }
        }
        w.put_u64(self.deadline_ms);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(RoundInstruction {
            round: r.get_u64()?,
            model_blob: Arc::new(r.get_bytes()?),
            train: TrainParams::decode(r)?,
            secagg: if r.get_bool()? {
                Some(SecAggSetup::decode(r)?)
            } else {
                None
            },
            deadline_ms: r.get_u64()?,
        })
    }
}

/// Ask surviving VG members for shares of dropped peers' DH secrets.
#[derive(Clone, Debug, PartialEq)]
pub struct UnmaskRequest {
    pub round: u64,
    pub vg_id: u32,
    /// (dropped client id, encrypted Shamir share addressed to *you*).
    pub dropped: Vec<(u64, Vec<u8>)>,
}

impl Wire for UnmaskRequest {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.round);
        w.put_u32(self.vg_id);
        w.put_varint(self.dropped.len() as u64);
        for (id, share) in &self.dropped {
            w.put_u64(*id);
            w.put_bytes(share);
        }
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        let round = r.get_u64()?;
        let vg_id = r.get_u32()?;
        let n = r.get_varint()? as usize;
        if n > 4096 {
            return Err(Error::Codec("too many dropped".into()));
        }
        let mut dropped = Vec::with_capacity(n);
        for _ in 0..n {
            dropped.push((r.get_u64()?, r.get_bytes()?));
        }
        Ok(UnmaskRequest {
            round,
            vg_id,
            dropped,
        })
    }
}

/// Attestation verdict on the wire.
impl Wire for Verdict {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.device_id);
        w.put_u8(self.tier as u8);
        w.put_u64(self.nonce);
        w.put_u64(self.expires_ms);
        w.put_bytes(&self.sig);
    }

    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(Verdict {
            device_id: r.get_str()?,
            tier: IntegrityTier::from_u8(r.get_u8()?)
                .ok_or_else(|| Error::Codec("bad tier".into()))?,
            nonce: r.get_u64()?,
            expires_ms: r.get_u64()?,
            sig: r
                .get_bytes()?
                .try_into()
                .map_err(|_| Error::Codec("sig not 32 bytes".into()))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn criteria_matching() {
        let mut crit = SelectionCriteria::default();
        let mut caps = DeviceCaps::default();
        assert!(crit.matches(&caps));

        crit.min_tier = IntegrityTier::Strong;
        assert!(!crit.matches(&caps));
        caps.tier = IntegrityTier::Strong;
        assert!(crit.matches(&caps));

        crit.require_charging = true;
        caps.charging = false;
        assert!(!crit.matches(&caps));
        caps.charging = true;

        crit.allow_metered = false;
        caps.metered_network = true;
        assert!(!crit.matches(&caps));
        caps.metered_network = false;

        crit.os_allow = vec!["android".into()];
        assert!(!crit.matches(&caps));
        caps.os = "android".into();
        assert!(crit.matches(&caps));
    }

    #[test]
    fn wire_roundtrips() {
        let caps = DeviceCaps {
            os: "android".into(),
            sdk: "kotlin".into(),
            tier: IntegrityTier::Strong,
            charging: false,
            metered_network: true,
        };
        assert_eq!(DeviceCaps::from_bytes(&caps.to_bytes()).unwrap(), caps);

        let crit = SelectionCriteria {
            min_tier: IntegrityTier::Device,
            require_charging: true,
            allow_metered: false,
            os_allow: vec!["android".into(), "ios".into()],
        };
        assert_eq!(
            SelectionCriteria::from_bytes(&crit.to_bytes()).unwrap(),
            crit
        );

        let td = TaskDescriptor {
            task_id: 9,
            task_name: "spam".into(),
            app_name: "mail".into(),
            workflow_name: "train".into(),
            state: TaskState::Running,
            round: 3,
            total_rounds: 10,
        };
        assert_eq!(TaskDescriptor::from_bytes(&td.to_bytes()).unwrap(), td);

        let setup = SecAggSetup {
            vg_id: 2,
            roster: vec![(1, [7u8; 32]), (5, [9u8; 32])],
            quant_range: 4.0,
            quant_bits: 20,
            threshold: 2,
        };
        assert_eq!(SecAggSetup::from_bytes(&setup.to_bytes()).unwrap(), setup);

        let ri = RoundInstruction {
            round: 4,
            model_blob: Arc::new(vec![1, 2, 3]),
            train: TrainParams {
                preset: "tiny".into(),
                lr: 5e-4,
                prox_mu: 0.0,
            },
            secagg: Some(setup),
            deadline_ms: 12345,
        };
        assert_eq!(RoundInstruction::from_bytes(&ri.to_bytes()).unwrap(), ri);

        let um = UnmaskRequest {
            round: 4,
            vg_id: 1,
            dropped: vec![(2, vec![1, 2]), (3, vec![])],
        };
        assert_eq!(UnmaskRequest::from_bytes(&um.to_bytes()).unwrap(), um);
    }

    #[test]
    fn caps_json_roundtrip() {
        let caps = DeviceCaps::default();
        let j = caps.to_json();
        assert_eq!(DeviceCaps::from_json(&j).unwrap(), caps);
    }

    #[test]
    fn task_state_names() {
        assert_eq!(TaskState::Running.name(), "running");
        assert_eq!(TaskState::from_u8(3), Some(TaskState::Completed));
        assert_eq!(TaskState::from_u8(99), None);
    }

    #[test]
    fn proto_negotiation_clamps_both_ways() {
        assert_eq!(negotiate_proto(PROTO_V1), PROTO_V1);
        assert_eq!(negotiate_proto(PROTO_V2), PROTO_V2);
        // A future v3 client negotiates down; garbage 0 negotiates up.
        assert_eq!(negotiate_proto(99), PROTO_V2);
        assert_eq!(negotiate_proto(0), PROTO_V1);
    }

    #[test]
    fn device_profile_roundtrips_wire_and_json() {
        let p = DeviceProfile {
            compute_tier: ComputeTier::High,
            bandwidth: BandwidthClass::Constrained,
            avail_window_ms: 600_000,
        };
        assert_eq!(DeviceProfile::from_bytes(&p.to_bytes()).unwrap(), p);
        assert_eq!(DeviceProfile::from_json(&p.to_json()).unwrap(), p);
        assert_eq!(
            DeviceProfile::from_json(&DeviceProfile::default().to_json()).unwrap(),
            DeviceProfile::default()
        );
        // Tiers order low → high for capability-aware ranking.
        assert!(ComputeTier::Low < ComputeTier::Mid);
        assert!(ComputeTier::Mid < ComputeTier::High);
        assert_eq!(ComputeTier::from_u8(7), None);
        assert_eq!(BandwidthClass::from_u8(7), None);
        assert_eq!(ComputeTier::High.name(), "high");
        assert_eq!(BandwidthClass::Fast.name(), "fast");
    }

    #[test]
    fn load_hints_roundtrip_wire_and_json() {
        let h = LoadHints {
            load: 0.75,
            battery: 0.5,
            charging: false,
        };
        assert_eq!(LoadHints::from_bytes(&h.to_bytes()).unwrap(), h);
        assert_eq!(LoadHints::from_json(&h.to_json()).unwrap(), h);
        assert_eq!(
            LoadHints::from_json(&Json::obj()).unwrap(),
            LoadHints::default(),
            "hints fields are all optional in JSON"
        );
    }
}
