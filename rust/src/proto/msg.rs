//! The message set + frame codecs.
//!
//! Every message crosses the wire in the binary encoding (the "gRPC
//! path"). The JSON encoding (the "REST path", `isEndpointHttp1=True` in
//! the paper's sample client) covers the control plane and plaintext
//! uploads; secure-aggregation data-plane messages are binary-only — the
//! REST path targets thin clients that use server-trusted (confidential
//! container, §4.3) aggregation rather than MPC.
//!
//! Frame format: binary frames start with the message tag (>= 0x02);
//! JSON frames start with '{' (0x7b). `decode_frame` dispatches on the
//! first byte, so one listener serves both protocols — mirroring the
//! paper's dual gRPC/REST endpoint.

use crate::codec::{Reader, Wire, Writer};
use crate::crypto::attest::Verdict;
use crate::error::{Error, Result};
use crate::util::base64;
use crate::util::json::{parse as json_parse, Json};

use super::{
    DeviceCaps, DeviceProfile, LoadHints, RoundInstruction, RoundRole, TaskDescriptor,
    UnmaskRequest,
};

/// Which encoding a client speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    Binary,
    Json,
}

/// One encrypted Shamir share addressed to a peer.
#[derive(Clone, Debug, PartialEq)]
pub struct PeerShare {
    pub peer: u64,
    /// AES-CTR(pairwise key) over [x || y bytes].
    pub enc: Vec<u8>,
}

/// Plaintext share of a dropped peer's DH secret, returned by a survivor.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveredShare {
    pub dropped: u64,
    pub x: u8,
    pub y: Vec<u8>,
}

/// All platform messages (requests and replies share the enum).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    // ---- client → server -------------------------------------------------
    Register {
        device_id: String,
        verdict: Verdict,
        caps: DeviceCaps,
    },
    PollTask {
        client_id: u64,
        app_name: String,
        workflow_name: String,
    },
    JoinRound {
        client_id: u64,
        task_id: u64,
        dh_pubkey: [u8; 32],
    },
    FetchRound {
        client_id: u64,
        task_id: u64,
    },
    SecAggShares {
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<PeerShare>,
    },
    UploadPlain {
        client_id: u64,
        task_id: u64,
        round: u64,
        base_version: u64,
        delta: Vec<f32>,
        weight: f64,
        loss: f64,
    },
    UploadMasked {
        client_id: u64,
        task_id: u64,
        round: u64,
        vg_id: u32,
        masked: Vec<u32>,
        loss: f64,
    },
    UnmaskResponse {
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<RecoveredShare>,
    },
    GetTaskStatus {
        task_id: u64,
    },
    Heartbeat {
        client_id: u64,
    },
    /// Operator pull of the server telemetry snapshot, rendered in the
    /// requested `obs::export::FORMAT_*` encoding (admin surface, like
    /// `GetTaskStatus`).
    GetTelemetry {
        format: u32,
    },

    // ---- session protocol v2 (client → server) ---------------------------
    /// Open a negotiated session: attest + register + submit the device's
    /// heterogeneity profile + the highest protocol version the client
    /// speaks. Replaces the bare `Register` for v2 clients; v1 clients
    /// keep sending `Register` (negotiation fallback).
    SessionOpen {
        device_id: String,
        verdict: Verdict,
        caps: DeviceCaps,
        profile: DeviceProfile,
        proto_max: u32,
    },
    /// Renew the liveness lease, carrying load/battery hints.
    SessionHeartbeat {
        client_id: u64,
        token: u64,
        hints: LoadHints,
    },
    /// Release the lease early (graceful departure).
    SessionClose {
        client_id: u64,
        token: u64,
    },

    // ---- hierarchical aggregation, leaf → master -------------------------
    /// A leaf aggregator claims its slice of the current round's cohort.
    /// Leaves are trusted platform infrastructure (not registered
    /// devices), addressed by operator-assigned `leaf_id`; the slice is
    /// the `leaf_index`-th of `leaf_count` deterministic cohort chunks.
    LeafAssign {
        leaf_id: u64,
        task_id: u64,
        leaf_index: u32,
        leaf_count: u32,
    },
    /// A leaf forwards its merged partial accumulator (the exported
    /// `PartialFold` plus bookkeeping) to the master. `sum` stays f64 so
    /// the hop loses no accumulator precision; `members` lists the
    /// cohort ids folded in so the master can mark them reported
    /// without double-counting; `min_loss` carries the leaf's DGA
    /// anchor (`+inf` for strategies without one).
    ForwardPartial {
        leaf_id: u64,
        task_id: u64,
        round: u64,
        base_version: u64,
        members: Vec<u64>,
        sum: Vec<f64>,
        total_weight: f64,
        count: u64,
        loss_sum: f64,
        min_loss: f64,
    },

    // ---- server → client -------------------------------------------------
    RegisterAck {
        accepted: bool,
        client_id: u64,
        reason: String,
    },
    TaskOffer {
        task: Option<TaskDescriptor>,
    },
    JoinAck {
        accepted: bool,
        reason: String,
    },
    RoundPlan {
        role: RoundRole,
    },
    Ack {
        ok: bool,
        reason: String,
    },
    TaskStatus {
        task: TaskDescriptor,
        participants: u64,
        last_round_duration_ms: u64,
        last_accuracy: f64,
        last_loss: f64,
        epsilon: f64,
    },
    ErrorReply {
        message: String,
    },
    /// Answer to `GetTelemetry`: the rendered snapshot. `body` is opaque
    /// text in the echoed `format` (Prometheus exposition or JSON) — the
    /// wire does not re-model every instrument, so adding one never
    /// changes the protocol.
    TelemetryReport {
        format: u32,
        body: String,
    },

    // ---- session protocol v2 (server → client) ---------------------------
    /// Session handshake outcome: token + lease + the negotiated protocol
    /// version. A structured refusal (`accepted: false`) keeps its reason
    /// (attestation failures), mirroring `RegisterAck`.
    SessionGrant {
        accepted: bool,
        client_id: u64,
        token: u64,
        lease_ms: u64,
        proto: u32,
        reason: String,
    },
    /// Lease-renewal outcome. `renewed: false` is protocol data — the SDK
    /// reopens the session rather than treating it as an error.
    LeaseAck {
        renewed: bool,
        lease_ms: u64,
        reason: String,
    },

    // ---- hierarchical aggregation, master → leaf -------------------------
    /// Answer to `LeafAssign`: the member slice the leaf owns for this
    /// round, plus the base version its partial must be built against.
    /// `accepted: false` is protocol data (no open round, bad index).
    LeafAssignment {
        accepted: bool,
        round: u64,
        base_version: u64,
        members: Vec<u64>,
        reason: String,
    },
    /// Answer to `ForwardPartial`: `folded` echoes how many member
    /// updates the master credited from the partial.
    LeafAck {
        ok: bool,
        folded: u64,
        reason: String,
    },
}

// Message tags. 0x00/0x01 reserved; '{' = 0x7b must not collide (all < 0x30).
const T_REGISTER: u8 = 0x02;
const T_POLL_TASK: u8 = 0x03;
const T_JOIN_ROUND: u8 = 0x04;
const T_FETCH_ROUND: u8 = 0x05;
const T_SECAGG_SHARES: u8 = 0x06;
const T_UPLOAD_PLAIN: u8 = 0x07;
const T_UPLOAD_MASKED: u8 = 0x08;
const T_UNMASK_RESPONSE: u8 = 0x09;
const T_GET_TASK_STATUS: u8 = 0x0a;
const T_HEARTBEAT: u8 = 0x0b;
const T_SESSION_OPEN: u8 = 0x0c;
const T_SESSION_HEARTBEAT: u8 = 0x0d;
const T_SESSION_CLOSE: u8 = 0x0e;
const T_LEAF_ASSIGN: u8 = 0x0f;
const T_REGISTER_ACK: u8 = 0x10;
const T_TASK_OFFER: u8 = 0x11;
const T_JOIN_ACK: u8 = 0x12;
const T_ROUND_PLAN: u8 = 0x13;
const T_ACK: u8 = 0x14;
const T_TASK_STATUS: u8 = 0x15;
const T_ERROR: u8 = 0x16;
const T_SESSION_GRANT: u8 = 0x17;
const T_LEASE_ACK: u8 = 0x18;
const T_LEAF_ASSIGNMENT: u8 = 0x19;
const T_LEAF_ACK: u8 = 0x1a;
const T_FORWARD_PARTIAL: u8 = 0x20;
const T_GET_TELEMETRY: u8 = 0x21;
const T_TELEMETRY_REPORT: u8 = 0x22;

/// Marker byte of the optional binary trace trailer: a v2 frame may end
/// with `[TRACE_TRAILER][trace_id: 8-byte LE]` after the message body.
/// Absent trailer = no trace, so v1 frames are valid v2 frames.
const TRACE_TRAILER: u8 = 0x01;

// RoundRole sub-tags.
const R_WAIT: u8 = 0;
const R_NOT_SELECTED: u8 = 1;
const R_TRAIN: u8 = 2;
const R_UNMASK: u8 = 3;
const R_ROUND_DONE: u8 = 4;
const R_TASK_DONE: u8 = 5;

impl Msg {
    /// Approximate encoded size, dominated by the bulk payload if any.
    /// `encode_frame` preallocates the Writer from this so multi-MB
    /// frames (model blobs, deltas, masked vectors) don't grow through
    /// repeated buffer doublings on the hot path.
    pub fn size_hint(&self) -> usize {
        let payload = match self {
            Msg::UploadPlain { delta, .. } => delta.len() * 4,
            Msg::UploadMasked { masked, .. } => masked.len() * 4,
            Msg::RoundPlan {
                role: RoundRole::Train(ri),
            } => ri.model_blob.len(),
            Msg::SecAggShares { shares, .. } => shares.iter().map(|s| s.enc.len() + 16).sum(),
            Msg::UnmaskResponse { shares, .. } => shares.iter().map(|s| s.y.len() + 16).sum(),
            Msg::ForwardPartial { sum, members, .. } => sum.len() * 8 + members.len() * 9,
            Msg::LeafAssignment { members, .. } => members.len() * 9,
            Msg::TelemetryReport { body, .. } => body.len(),
            _ => 0,
        };
        payload + 64
    }

    fn tag(&self) -> u8 {
        match self {
            Msg::Register { .. } => T_REGISTER,
            Msg::PollTask { .. } => T_POLL_TASK,
            Msg::JoinRound { .. } => T_JOIN_ROUND,
            Msg::FetchRound { .. } => T_FETCH_ROUND,
            Msg::SecAggShares { .. } => T_SECAGG_SHARES,
            Msg::UploadPlain { .. } => T_UPLOAD_PLAIN,
            Msg::UploadMasked { .. } => T_UPLOAD_MASKED,
            Msg::UnmaskResponse { .. } => T_UNMASK_RESPONSE,
            Msg::GetTaskStatus { .. } => T_GET_TASK_STATUS,
            Msg::Heartbeat { .. } => T_HEARTBEAT,
            Msg::GetTelemetry { .. } => T_GET_TELEMETRY,
            Msg::SessionOpen { .. } => T_SESSION_OPEN,
            Msg::SessionHeartbeat { .. } => T_SESSION_HEARTBEAT,
            Msg::SessionClose { .. } => T_SESSION_CLOSE,
            Msg::LeafAssign { .. } => T_LEAF_ASSIGN,
            Msg::ForwardPartial { .. } => T_FORWARD_PARTIAL,
            Msg::RegisterAck { .. } => T_REGISTER_ACK,
            Msg::TaskOffer { .. } => T_TASK_OFFER,
            Msg::JoinAck { .. } => T_JOIN_ACK,
            Msg::RoundPlan { .. } => T_ROUND_PLAN,
            Msg::Ack { .. } => T_ACK,
            Msg::TaskStatus { .. } => T_TASK_STATUS,
            Msg::ErrorReply { .. } => T_ERROR,
            Msg::TelemetryReport { .. } => T_TELEMETRY_REPORT,
            Msg::SessionGrant { .. } => T_SESSION_GRANT,
            Msg::LeaseAck { .. } => T_LEASE_ACK,
            Msg::LeafAssignment { .. } => T_LEAF_ASSIGNMENT,
            Msg::LeafAck { .. } => T_LEAF_ACK,
        }
    }
}

impl Wire for Msg {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.tag());
        match self {
            Msg::Register {
                device_id,
                verdict,
                caps,
            } => {
                w.put_str(device_id);
                verdict.encode(w);
                caps.encode(w);
            }
            Msg::PollTask {
                client_id,
                app_name,
                workflow_name,
            } => {
                w.put_u64(*client_id);
                w.put_str(app_name);
                w.put_str(workflow_name);
            }
            Msg::JoinRound {
                client_id,
                task_id,
                dh_pubkey,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
                w.put_bytes(dh_pubkey);
            }
            Msg::FetchRound { client_id, task_id } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
            }
            Msg::SecAggShares {
                client_id,
                task_id,
                round,
                shares,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_varint(shares.len() as u64);
                for s in shares {
                    w.put_u64(s.peer);
                    w.put_bytes(&s.enc);
                }
            }
            Msg::UploadPlain {
                client_id,
                task_id,
                round,
                base_version,
                delta,
                weight,
                loss,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_u64(*base_version);
                w.put_f32s(delta);
                w.put_f64(*weight);
                w.put_f64(*loss);
            }
            Msg::UploadMasked {
                client_id,
                task_id,
                round,
                vg_id,
                masked,
                loss,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_u32(*vg_id);
                w.put_u32s(masked);
                w.put_f64(*loss);
            }
            Msg::UnmaskResponse {
                client_id,
                task_id,
                round,
                shares,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_varint(shares.len() as u64);
                for s in shares {
                    w.put_u64(s.dropped);
                    w.put_u8(s.x);
                    w.put_bytes(&s.y);
                }
            }
            Msg::GetTaskStatus { task_id } => w.put_u64(*task_id),
            Msg::Heartbeat { client_id } => w.put_u64(*client_id),
            Msg::GetTelemetry { format } => w.put_u32(*format),
            Msg::SessionOpen {
                device_id,
                verdict,
                caps,
                profile,
                proto_max,
            } => {
                w.put_str(device_id);
                verdict.encode(w);
                caps.encode(w);
                profile.encode(w);
                w.put_u32(*proto_max);
            }
            Msg::SessionHeartbeat {
                client_id,
                token,
                hints,
            } => {
                w.put_u64(*client_id);
                w.put_u64(*token);
                hints.encode(w);
            }
            Msg::SessionClose { client_id, token } => {
                w.put_u64(*client_id);
                w.put_u64(*token);
            }
            Msg::LeafAssign {
                leaf_id,
                task_id,
                leaf_index,
                leaf_count,
            } => {
                w.put_u64(*leaf_id);
                w.put_u64(*task_id);
                w.put_u32(*leaf_index);
                w.put_u32(*leaf_count);
            }
            Msg::ForwardPartial {
                leaf_id,
                task_id,
                round,
                base_version,
                members,
                sum,
                total_weight,
                count,
                loss_sum,
                min_loss,
            } => {
                w.put_u64(*leaf_id);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_u64(*base_version);
                w.put_varint(members.len() as u64);
                for m in members {
                    w.put_u64(*m);
                }
                w.put_f64s(sum);
                w.put_f64(*total_weight);
                w.put_u64(*count);
                w.put_f64(*loss_sum);
                w.put_f64(*min_loss);
            }
            Msg::RegisterAck {
                accepted,
                client_id,
                reason,
            } => {
                w.put_bool(*accepted);
                w.put_u64(*client_id);
                w.put_str(reason);
            }
            Msg::TaskOffer { task } => match task {
                None => w.put_bool(false),
                Some(t) => {
                    w.put_bool(true);
                    t.encode(w);
                }
            },
            Msg::JoinAck { accepted, reason } => {
                w.put_bool(*accepted);
                w.put_str(reason);
            }
            Msg::RoundPlan { role } => match role {
                RoundRole::Wait => w.put_u8(R_WAIT),
                RoundRole::NotSelected => w.put_u8(R_NOT_SELECTED),
                RoundRole::Train(ri) => {
                    w.put_u8(R_TRAIN);
                    ri.encode(w);
                }
                RoundRole::Unmask(ur) => {
                    w.put_u8(R_UNMASK);
                    ur.encode(w);
                }
                RoundRole::RoundDone => w.put_u8(R_ROUND_DONE),
                RoundRole::TaskDone => w.put_u8(R_TASK_DONE),
            },
            Msg::Ack { ok, reason } => {
                w.put_bool(*ok);
                w.put_str(reason);
            }
            Msg::TaskStatus {
                task,
                participants,
                last_round_duration_ms,
                last_accuracy,
                last_loss,
                epsilon,
            } => {
                task.encode(w);
                w.put_u64(*participants);
                w.put_u64(*last_round_duration_ms);
                w.put_f64(*last_accuracy);
                w.put_f64(*last_loss);
                w.put_f64(*epsilon);
            }
            Msg::ErrorReply { message } => w.put_str(message),
            Msg::TelemetryReport { format, body } => {
                w.put_u32(*format);
                w.put_str(body);
            }
            Msg::SessionGrant {
                accepted,
                client_id,
                token,
                lease_ms,
                proto,
                reason,
            } => {
                w.put_bool(*accepted);
                w.put_u64(*client_id);
                w.put_u64(*token);
                w.put_u64(*lease_ms);
                w.put_u32(*proto);
                w.put_str(reason);
            }
            Msg::LeaseAck {
                renewed,
                lease_ms,
                reason,
            } => {
                w.put_bool(*renewed);
                w.put_u64(*lease_ms);
                w.put_str(reason);
            }
            Msg::LeafAssignment {
                accepted,
                round,
                base_version,
                members,
                reason,
            } => {
                w.put_bool(*accepted);
                w.put_u64(*round);
                w.put_u64(*base_version);
                w.put_varint(members.len() as u64);
                for m in members {
                    w.put_u64(*m);
                }
                w.put_str(reason);
            }
            Msg::LeafAck { ok, folded, reason } => {
                w.put_bool(*ok);
                w.put_u64(*folded);
                w.put_str(reason);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<Msg> {
        let tag = r.get_u8()?;
        Ok(match tag {
            T_REGISTER => Msg::Register {
                device_id: r.get_str()?,
                verdict: Verdict::decode(r)?,
                caps: DeviceCaps::decode(r)?,
            },
            T_POLL_TASK => Msg::PollTask {
                client_id: r.get_u64()?,
                app_name: r.get_str()?,
                workflow_name: r.get_str()?,
            },
            T_JOIN_ROUND => Msg::JoinRound {
                client_id: r.get_u64()?,
                task_id: r.get_u64()?,
                dh_pubkey: r
                    .get_bytes()?
                    .try_into()
                    .map_err(|_| Error::Codec("pubkey not 32 bytes".into()))?,
            },
            T_FETCH_ROUND => Msg::FetchRound {
                client_id: r.get_u64()?,
                task_id: r.get_u64()?,
            },
            T_SECAGG_SHARES => {
                let client_id = r.get_u64()?;
                let task_id = r.get_u64()?;
                let round = r.get_u64()?;
                let n = r.get_varint()? as usize;
                if n > 4096 {
                    return Err(Error::Codec("too many shares".into()));
                }
                let mut shares = Vec::with_capacity(n);
                for _ in 0..n {
                    shares.push(PeerShare {
                        peer: r.get_u64()?,
                        enc: r.get_bytes()?,
                    });
                }
                Msg::SecAggShares {
                    client_id,
                    task_id,
                    round,
                    shares,
                }
            }
            T_UPLOAD_PLAIN => Msg::UploadPlain {
                client_id: r.get_u64()?,
                task_id: r.get_u64()?,
                round: r.get_u64()?,
                base_version: r.get_u64()?,
                delta: r.get_f32s()?,
                weight: r.get_f64()?,
                loss: r.get_f64()?,
            },
            T_UPLOAD_MASKED => Msg::UploadMasked {
                client_id: r.get_u64()?,
                task_id: r.get_u64()?,
                round: r.get_u64()?,
                vg_id: r.get_u32()?,
                masked: r.get_u32s()?,
                loss: r.get_f64()?,
            },
            T_UNMASK_RESPONSE => {
                let client_id = r.get_u64()?;
                let task_id = r.get_u64()?;
                let round = r.get_u64()?;
                let n = r.get_varint()? as usize;
                if n > 4096 {
                    return Err(Error::Codec("too many shares".into()));
                }
                let mut shares = Vec::with_capacity(n);
                for _ in 0..n {
                    shares.push(RecoveredShare {
                        dropped: r.get_u64()?,
                        x: r.get_u8()?,
                        y: r.get_bytes()?,
                    });
                }
                Msg::UnmaskResponse {
                    client_id,
                    task_id,
                    round,
                    shares,
                }
            }
            T_GET_TASK_STATUS => Msg::GetTaskStatus {
                task_id: r.get_u64()?,
            },
            T_HEARTBEAT => Msg::Heartbeat {
                client_id: r.get_u64()?,
            },
            T_GET_TELEMETRY => Msg::GetTelemetry {
                format: r.get_u32()?,
            },
            T_SESSION_OPEN => Msg::SessionOpen {
                device_id: r.get_str()?,
                verdict: Verdict::decode(r)?,
                caps: DeviceCaps::decode(r)?,
                profile: DeviceProfile::decode(r)?,
                proto_max: r.get_u32()?,
            },
            T_SESSION_HEARTBEAT => Msg::SessionHeartbeat {
                client_id: r.get_u64()?,
                token: r.get_u64()?,
                hints: LoadHints::decode(r)?,
            },
            T_SESSION_CLOSE => Msg::SessionClose {
                client_id: r.get_u64()?,
                token: r.get_u64()?,
            },
            T_LEAF_ASSIGN => Msg::LeafAssign {
                leaf_id: r.get_u64()?,
                task_id: r.get_u64()?,
                leaf_index: r.get_u32()?,
                leaf_count: r.get_u32()?,
            },
            T_FORWARD_PARTIAL => Msg::ForwardPartial {
                leaf_id: r.get_u64()?,
                task_id: r.get_u64()?,
                round: r.get_u64()?,
                base_version: r.get_u64()?,
                members: get_members(r)?,
                sum: r.get_f64s()?,
                total_weight: r.get_f64()?,
                count: r.get_u64()?,
                loss_sum: r.get_f64()?,
                min_loss: r.get_f64()?,
            },
            T_REGISTER_ACK => Msg::RegisterAck {
                accepted: r.get_bool()?,
                client_id: r.get_u64()?,
                reason: r.get_str()?,
            },
            T_TASK_OFFER => Msg::TaskOffer {
                task: if r.get_bool()? {
                    Some(TaskDescriptor::decode(r)?)
                } else {
                    None
                },
            },
            T_JOIN_ACK => Msg::JoinAck {
                accepted: r.get_bool()?,
                reason: r.get_str()?,
            },
            T_ROUND_PLAN => {
                let sub = r.get_u8()?;
                let role = match sub {
                    R_WAIT => RoundRole::Wait,
                    R_NOT_SELECTED => RoundRole::NotSelected,
                    R_TRAIN => RoundRole::Train(RoundInstruction::decode(r)?),
                    R_UNMASK => RoundRole::Unmask(UnmaskRequest::decode(r)?),
                    R_ROUND_DONE => RoundRole::RoundDone,
                    R_TASK_DONE => RoundRole::TaskDone,
                    v => return Err(Error::Codec(format!("bad round role {v}"))),
                };
                Msg::RoundPlan { role }
            }
            T_ACK => Msg::Ack {
                ok: r.get_bool()?,
                reason: r.get_str()?,
            },
            T_TASK_STATUS => Msg::TaskStatus {
                task: TaskDescriptor::decode(r)?,
                participants: r.get_u64()?,
                last_round_duration_ms: r.get_u64()?,
                last_accuracy: r.get_f64()?,
                last_loss: r.get_f64()?,
                epsilon: r.get_f64()?,
            },
            T_ERROR => Msg::ErrorReply {
                message: r.get_str()?,
            },
            T_TELEMETRY_REPORT => Msg::TelemetryReport {
                format: r.get_u32()?,
                body: r.get_str()?,
            },
            T_SESSION_GRANT => Msg::SessionGrant {
                accepted: r.get_bool()?,
                client_id: r.get_u64()?,
                token: r.get_u64()?,
                lease_ms: r.get_u64()?,
                proto: r.get_u32()?,
                reason: r.get_str()?,
            },
            T_LEASE_ACK => Msg::LeaseAck {
                renewed: r.get_bool()?,
                lease_ms: r.get_u64()?,
                reason: r.get_str()?,
            },
            T_LEAF_ASSIGNMENT => Msg::LeafAssignment {
                accepted: r.get_bool()?,
                round: r.get_u64()?,
                base_version: r.get_u64()?,
                members: get_members(r)?,
                reason: r.get_str()?,
            },
            T_LEAF_ACK => Msg::LeafAck {
                ok: r.get_bool()?,
                folded: r.get_u64()?,
                reason: r.get_str()?,
            },
            v => return Err(Error::Codec(format!("unknown message tag {v:#x}"))),
        })
    }
}

/// Length-prefixed cohort-member id list with a hostile-length guard
/// (each id is 8 bytes, so a claimed length beyond the frame is bogus).
fn get_members(r: &mut Reader) -> Result<Vec<u64>> {
    let n = r.get_varint()? as usize;
    if n > r.remaining() / 8 {
        return Err(Error::Codec(format!("member list length {n} exceeds frame")));
    }
    let mut members = Vec::with_capacity(n);
    for _ in 0..n {
        members.push(r.get_u64()?);
    }
    Ok(members)
}

// ---------------------------------------------------------------------------
// JSON ("REST") codec — control plane + plaintext uploads.
// ---------------------------------------------------------------------------

fn verdict_to_json(verdict: &Verdict) -> Json {
    Json::obj()
        .set("device_id", verdict.device_id.as_str())
        .set("tier", verdict.tier as u8 as u64)
        // u64 fields ride as strings: JSON numbers are f64 and would
        // corrupt values above 2^53, breaking the HMAC over the verdict.
        .set("nonce", verdict.nonce.to_string())
        .set("expires_ms", verdict.expires_ms.to_string())
        .set("sig", base64::encode(&verdict.sig))
}

fn verdict_from_json(j: &Json) -> Result<Verdict> {
    let v = j
        .get("verdict")
        .ok_or_else(|| Error::Codec("missing verdict".into()))?;
    let sig_v = base64::decode(v.req_str("sig").map_err(Error::Codec)?).map_err(Error::Codec)?;
    let parse_u64_str = |key: &str| -> Result<u64> {
        v.req_str(key)
            .map_err(Error::Codec)?
            .parse::<u64>()
            .map_err(|e| Error::Codec(format!("verdict.{key}: {e}")))
    };
    Ok(Verdict {
        device_id: v.req_str("device_id").map_err(Error::Codec)?.to_string(),
        tier: crate::crypto::attest::IntegrityTier::from_u8(
            v.req_usize("tier").map_err(Error::Codec)? as u8,
        )
        .ok_or_else(|| Error::Codec("bad tier".into()))?,
        nonce: parse_u64_str("nonce")?,
        expires_ms: parse_u64_str("expires_ms")?,
        sig: sig_v
            .try_into()
            .map_err(|_| Error::Codec("sig not 32 bytes".into()))?,
    })
}

/// Session tokens ride as strings (credentials must survive the full
/// u64 range; JSON numbers are f64). Absent field → 0 (no session).
fn token_from_json(j: &Json) -> Result<u64> {
    j.opt_str("token", "0")
        .parse::<u64>()
        .map_err(|e| Error::Codec(format!("token: {e}")))
}

/// A u64 carried in JSON: the string form every current encoder emits,
/// or the historical raw-number form (only exact below 2^53 — which is
/// exactly why encoders stopped emitting it).
fn parse_u64_value(v: &Json) -> Option<u64> {
    match v {
        Json::Str(s) => s.parse::<u64>().ok(),
        other => other.as_u64(),
    }
}

fn req_u64_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(parse_u64_value)
        .ok_or_else(|| Error::Codec(format!("missing/invalid u64 field {key:?}")))
}

fn opt_u64_field(j: &Json, key: &str, default: u64) -> u64 {
    j.get(key).and_then(parse_u64_value).unwrap_or(default)
}

fn task_descriptor_to_json(t: &TaskDescriptor) -> Json {
    Json::obj()
        // u64 ids/counters ride as strings through JSON (f64 corrupts
        // above 2^53); enforced repo-wide by the u64-as-json-number lint.
        .set("task_id", t.task_id.to_string())
        .set("task_name", t.task_name.as_str())
        .set("app_name", t.app_name.as_str())
        .set("workflow_name", t.workflow_name.as_str())
        .set("state", t.state as u8 as u64)
        .set("round", t.round.to_string())
        .set("total_rounds", t.total_rounds.to_string())
}

fn task_descriptor_from_json(t: &Json) -> Result<TaskDescriptor> {
    Ok(TaskDescriptor {
        task_id: req_u64_field(t, "task_id")?,
        task_name: t.req_str("task_name").map_err(Error::Codec)?.to_string(),
        app_name: t.req_str("app_name").map_err(Error::Codec)?.to_string(),
        workflow_name: t
            .req_str("workflow_name")
            .map_err(Error::Codec)?
            .to_string(),
        state: super::TaskState::from_u8(t.req_usize("state").map_err(Error::Codec)? as u8)
            .ok_or_else(|| Error::Codec("bad state".into()))?,
        round: req_u64_field(t, "round")?,
        total_rounds: req_u64_field(t, "total_rounds")?,
    })
}

impl Msg {
    /// JSON encoding; `Err` for binary-only (secagg data plane) messages.
    pub fn to_json(&self) -> Result<Json> {
        Ok(match self {
            Msg::Register {
                device_id,
                verdict,
                caps,
            } => Json::obj()
                .set("type", "register")
                .set("device_id", device_id.as_str())
                .set("verdict", verdict_to_json(verdict))
                .set("caps", caps.to_json()),
            Msg::SessionOpen {
                device_id,
                verdict,
                caps,
                profile,
                proto_max,
            } => Json::obj()
                .set("type", "session_open")
                .set("device_id", device_id.as_str())
                .set("verdict", verdict_to_json(verdict))
                .set("caps", caps.to_json())
                .set("profile", profile.to_json())
                .set("proto_max", *proto_max as u64),
            Msg::SessionHeartbeat {
                client_id,
                token,
                hints,
            } => Json::obj()
                .set("type", "session_heartbeat")
                .set("client_id", client_id.to_string())
                // Tokens are credentials: ride as strings (full u64
                // range) like the verdict nonce, not as lossy f64s.
                .set("token", token.to_string())
                .set("hints", hints.to_json()),
            Msg::SessionClose { client_id, token } => Json::obj()
                .set("type", "session_close")
                .set("client_id", client_id.to_string())
                .set("token", token.to_string()),
            Msg::SessionGrant {
                accepted,
                client_id,
                token,
                lease_ms,
                proto,
                reason,
            } => Json::obj()
                .set("type", "session_grant")
                .set("accepted", *accepted)
                .set("client_id", client_id.to_string())
                .set("token", token.to_string())
                .set("lease_ms", lease_ms.to_string())
                .set("proto", *proto as u64)
                .set("reason", reason.as_str()),
            Msg::LeaseAck {
                renewed,
                lease_ms,
                reason,
            } => Json::obj()
                .set("type", "lease_ack")
                .set("renewed", *renewed)
                .set("lease_ms", lease_ms.to_string())
                .set("reason", reason.as_str()),
            Msg::PollTask {
                client_id,
                app_name,
                workflow_name,
            } => Json::obj()
                .set("type", "poll_task")
                .set("client_id", client_id.to_string())
                .set("app_name", app_name.as_str())
                .set("workflow_name", workflow_name.as_str()),
            Msg::Heartbeat { client_id } => Json::obj()
                .set("type", "heartbeat")
                .set("client_id", client_id.to_string()),
            Msg::GetTaskStatus { task_id } => Json::obj()
                .set("type", "get_task_status")
                .set("task_id", task_id.to_string()),
            Msg::GetTelemetry { format } => Json::obj()
                .set("type", "get_telemetry")
                .set("format", *format as u64),
            Msg::TelemetryReport { format, body } => Json::obj()
                .set("type", "telemetry_report")
                .set("format", *format as u64)
                .set("body", body.as_str()),
            Msg::UploadPlain {
                client_id,
                task_id,
                round,
                base_version,
                delta,
                weight,
                loss,
            } => {
                let mut bytes = Vec::with_capacity(delta.len() * 4);
                for d in delta {
                    bytes.extend_from_slice(&d.to_le_bytes());
                }
                Json::obj()
                    .set("type", "upload_plain")
                    .set("client_id", client_id.to_string())
                    .set("task_id", task_id.to_string())
                    .set("round", round.to_string())
                    .set("base_version", base_version.to_string())
                    .set("delta_b64", base64::encode(&bytes))
                    .set("weight", *weight)
                    .set("loss", *loss)
            }
            Msg::RegisterAck {
                accepted,
                client_id,
                reason,
            } => Json::obj()
                .set("type", "register_ack")
                .set("accepted", *accepted)
                .set("client_id", client_id.to_string())
                .set("reason", reason.as_str()),
            Msg::TaskOffer { task } => {
                let t = match task {
                    None => Json::Null,
                    Some(t) => task_descriptor_to_json(t),
                };
                Json::obj().set("type", "task_offer").set("task", t)
            }
            Msg::TaskStatus {
                task,
                participants,
                last_round_duration_ms,
                last_accuracy,
                last_loss,
                epsilon,
            } => Json::obj()
                .set("type", "task_status")
                .set("task", task_descriptor_to_json(task))
                .set("participants", participants.to_string())
                .set("last_round_duration_ms", last_round_duration_ms.to_string())
                .set("last_accuracy", *last_accuracy)
                .set("last_loss", *last_loss)
                .set("epsilon", *epsilon),
            Msg::Ack { ok, reason } => Json::obj()
                .set("type", "ack")
                .set("ok", *ok)
                .set("reason", reason.as_str()),
            Msg::ErrorReply { message } => Json::obj()
                .set("type", "error")
                .set("message", message.as_str()),
            other => {
                return Err(Error::Codec(format!(
                    "message {:#x} is binary-only (secure-aggregation data plane \
                     requires the gRPC-path codec)",
                    other.tag()
                )))
            }
        })
    }

    /// Parse a JSON message.
    pub fn from_json(j: &Json) -> Result<Msg> {
        let ty = j.req_str("type").map_err(Error::Codec)?;
        Ok(match ty {
            "register" => Msg::Register {
                device_id: j.req_str("device_id").map_err(Error::Codec)?.to_string(),
                verdict: verdict_from_json(j)?,
                caps: DeviceCaps::from_json(
                    j.get("caps")
                        .ok_or_else(|| Error::Codec("missing caps".into()))?,
                )?,
            },
            "session_open" => Msg::SessionOpen {
                device_id: j.req_str("device_id").map_err(Error::Codec)?.to_string(),
                verdict: verdict_from_json(j)?,
                caps: DeviceCaps::from_json(
                    j.get("caps")
                        .ok_or_else(|| Error::Codec("missing caps".into()))?,
                )?,
                profile: DeviceProfile::from_json(
                    j.get("profile")
                        .ok_or_else(|| Error::Codec("missing profile".into()))?,
                )?,
                proto_max: j.req_usize("proto_max").map_err(Error::Codec)? as u32,
            },
            "session_heartbeat" => Msg::SessionHeartbeat {
                client_id: req_u64_field(j, "client_id")?,
                token: token_from_json(j)?,
                hints: match j.get("hints") {
                    Some(h) => LoadHints::from_json(h)?,
                    None => LoadHints::default(),
                },
            },
            "session_close" => Msg::SessionClose {
                client_id: req_u64_field(j, "client_id")?,
                token: token_from_json(j)?,
            },
            "session_grant" => Msg::SessionGrant {
                accepted: j.opt_bool("accepted", false),
                client_id: opt_u64_field(j, "client_id", 0),
                token: token_from_json(j)?,
                lease_ms: opt_u64_field(j, "lease_ms", 0),
                proto: j.opt_usize("proto", 0) as u32,
                reason: j.opt_str("reason", ""),
            },
            "lease_ack" => Msg::LeaseAck {
                renewed: j.opt_bool("renewed", false),
                lease_ms: opt_u64_field(j, "lease_ms", 0),
                reason: j.opt_str("reason", ""),
            },
            "poll_task" => Msg::PollTask {
                client_id: req_u64_field(j, "client_id")?,
                app_name: j.req_str("app_name").map_err(Error::Codec)?.to_string(),
                workflow_name: j
                    .req_str("workflow_name")
                    .map_err(Error::Codec)?
                    .to_string(),
            },
            "heartbeat" => Msg::Heartbeat {
                client_id: req_u64_field(j, "client_id")?,
            },
            "get_task_status" => Msg::GetTaskStatus {
                task_id: req_u64_field(j, "task_id")?,
            },
            "get_telemetry" => Msg::GetTelemetry {
                format: j.opt_usize("format", 0) as u32,
            },
            "telemetry_report" => Msg::TelemetryReport {
                format: j.opt_usize("format", 0) as u32,
                body: j.opt_str("body", ""),
            },
            "upload_plain" => {
                let bytes = base64::decode(j.req_str("delta_b64").map_err(Error::Codec)?)
                    .map_err(Error::Codec)?;
                if bytes.len() % 4 != 0 {
                    return Err(Error::Codec("delta not f32-aligned".into()));
                }
                let delta = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Msg::UploadPlain {
                    client_id: req_u64_field(j, "client_id")?,
                    task_id: req_u64_field(j, "task_id")?,
                    round: req_u64_field(j, "round")?,
                    base_version: opt_u64_field(j, "base_version", 0),
                    delta,
                    weight: j.opt_f64("weight", 1.0),
                    loss: j.opt_f64("loss", 0.0),
                }
            }
            "register_ack" => Msg::RegisterAck {
                accepted: j.opt_bool("accepted", false),
                client_id: opt_u64_field(j, "client_id", 0),
                reason: j.opt_str("reason", ""),
            },
            "task_offer" => {
                let task = match j.get("task") {
                    None | Some(Json::Null) => None,
                    Some(t) => Some(task_descriptor_from_json(t)?),
                };
                Msg::TaskOffer { task }
            }
            "task_status" => Msg::TaskStatus {
                task: task_descriptor_from_json(
                    j.get("task")
                        .ok_or_else(|| Error::Codec("missing task".into()))?,
                )?,
                participants: opt_u64_field(j, "participants", 0),
                last_round_duration_ms: opt_u64_field(j, "last_round_duration_ms", 0),
                last_accuracy: j.opt_f64("last_accuracy", 0.0),
                last_loss: j.opt_f64("last_loss", 0.0),
                epsilon: j.opt_f64("epsilon", 0.0),
            },
            "ack" => Msg::Ack {
                ok: j.opt_bool("ok", false),
                reason: j.opt_str("reason", ""),
            },
            "error" => Msg::ErrorReply {
                message: j.opt_str("message", ""),
            },
            other => return Err(Error::Codec(format!("unknown json message type {other:?}"))),
        })
    }
}

/// Encode a message into a frame for the given codec.
pub fn encode_frame(msg: &Msg, codec: WireCodec) -> Result<Vec<u8>> {
    encode_frame_traced(msg, codec, None)
}

/// Encode a message, optionally attaching a trace context. Binary frames
/// carry it as the `[TRACE_TRAILER][id LE]` suffix; JSON frames as a
/// top-level `"trace_id"` string field (ignored by v1 decoders, which
/// skip unknown keys). `Some(0)` means no trace — 0 is the reserved
/// "untraced" id.
pub fn encode_frame_traced(msg: &Msg, codec: WireCodec, trace_id: Option<u64>) -> Result<Vec<u8>> {
    let trace = trace_id.filter(|id| *id != 0);
    match codec {
        WireCodec::Binary => {
            let mut w = Writer::with_capacity(msg.size_hint() + 9);
            msg.encode(&mut w);
            if let Some(id) = trace {
                w.put_u8(TRACE_TRAILER);
                w.put_u64(id);
            }
            Ok(w.into_bytes())
        }
        WireCodec::Json => {
            let mut j = msg.to_json()?;
            if let Some(id) = trace {
                // Full-range u64 id: rides as a string like every other
                // u64 in the JSON codec.
                j = j.set("trace_id", id.to_string());
            }
            Ok(j.to_string().into_bytes())
        }
    }
}

/// Decode a frame, auto-detecting the codec from the first byte. Any
/// trace context is dropped — the router path uses
/// [`decode_frame_traced`].
pub fn decode_frame(frame: &[u8]) -> Result<(Msg, WireCodec)> {
    decode_frame_traced(frame).map(|(msg, codec, _)| (msg, codec))
}

/// Decode a frame and its optional trace context. An absent trailer /
/// `"trace_id"` field means no trace, so every v1 frame decodes with
/// `None`; trailing bytes that are not exactly one trace trailer are
/// still a codec error (no silent truncation).
pub fn decode_frame_traced(frame: &[u8]) -> Result<(Msg, WireCodec, Option<u64>)> {
    match frame.first() {
        Some(b'{') => {
            let text = std::str::from_utf8(frame)
                .map_err(|e| Error::Codec(format!("bad utf8 json frame: {e}")))?;
            let j = json_parse(text).map_err(Error::Codec)?;
            let trace = j.get("trace_id").and_then(parse_u64_value).filter(|id| *id != 0);
            Ok((Msg::from_json(&j)?, WireCodec::Json, trace))
        }
        Some(_) => {
            let mut r = Reader::new(frame);
            let msg = Msg::decode(&mut r)?;
            let trace = match r.remaining() {
                0 => None,
                9 => {
                    if r.get_u8()? != TRACE_TRAILER {
                        return Err(Error::Codec("bad frame trailer marker".into()));
                    }
                    let id = r.get_u64()?;
                    if id == 0 {
                        None
                    } else {
                        Some(id)
                    }
                }
                n => {
                    return Err(Error::Codec(format!(
                        "{n} trailing bytes after message"
                    )))
                }
            };
            Ok((msg, WireCodec::Binary, trace))
        }
        None => Err(Error::Codec("empty frame".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::attest::{Authority, IntegrityTier};
    use crate::proto::{TaskState, TrainParams};

    // florida-lint: corpus(binary-roundtrip, json-roundtrip)
    fn sample_register() -> Msg {
        let auth = Authority::new(b"k");
        Msg::Register {
            device_id: "dev-1".into(),
            verdict: auth.issue("dev-1", IntegrityTier::Device, 7, 99),
            caps: DeviceCaps::default(),
        }
    }

    // florida-lint: corpus(binary-roundtrip, json-roundtrip)
    fn sample_session_frames() -> Vec<Msg> {
        use crate::proto::{BandwidthClass, ComputeTier, DeviceProfile, LoadHints, PROTO_V2};
        let auth = Authority::new(b"k");
        vec![
            Msg::SessionOpen {
                device_id: "dev-2".into(),
                verdict: auth.issue("dev-2", IntegrityTier::Strong, 8, 99),
                caps: DeviceCaps::default(),
                profile: DeviceProfile {
                    compute_tier: ComputeTier::High,
                    bandwidth: BandwidthClass::Constrained,
                    avail_window_ms: 120_000,
                },
                proto_max: PROTO_V2,
            },
            Msg::SessionHeartbeat {
                client_id: 4,
                token: 17,
                hints: LoadHints {
                    load: 0.25,
                    battery: 0.5,
                    charging: false,
                },
            },
            Msg::SessionClose {
                client_id: 4,
                token: 17,
            },
            Msg::SessionGrant {
                accepted: true,
                client_id: 4,
                token: 17,
                lease_ms: 30_000,
                proto: PROTO_V2,
                reason: String::new(),
            },
            Msg::SessionGrant {
                accepted: false,
                client_id: 0,
                token: 0,
                lease_ms: 0,
                proto: 0,
                reason: "attestation rejected".into(),
            },
            Msg::LeaseAck {
                renewed: false,
                lease_ms: 0,
                reason: "no live session".into(),
            },
            Msg::LeaseAck {
                renewed: true,
                lease_ms: 30_000,
                reason: String::new(),
            },
        ]
    }

    // florida-lint: corpus(binary-roundtrip)
    fn all_binary_samples() -> Vec<Msg> {
        let mut v = vec![
            sample_register(),
            Msg::PollTask {
                client_id: 1,
                app_name: "app".into(),
                workflow_name: "wf".into(),
            },
            Msg::JoinRound {
                client_id: 1,
                task_id: 2,
                dh_pubkey: [5u8; 32],
            },
            Msg::FetchRound {
                client_id: 1,
                task_id: 2,
            },
            Msg::SecAggShares {
                client_id: 1,
                task_id: 2,
                round: 3,
                shares: vec![PeerShare {
                    peer: 9,
                    enc: vec![1, 2, 3],
                }],
            },
            Msg::UploadPlain {
                client_id: 1,
                task_id: 2,
                round: 3,
                base_version: 4,
                delta: vec![0.5, -1.0],
                weight: 67.0,
                loss: 0.69,
            },
            Msg::UploadMasked {
                client_id: 1,
                task_id: 2,
                round: 3,
                vg_id: 0,
                masked: vec![17, 0xffff_ffff],
                loss: 0.5,
            },
            Msg::UnmaskResponse {
                client_id: 1,
                task_id: 2,
                round: 3,
                shares: vec![RecoveredShare {
                    dropped: 4,
                    x: 2,
                    y: vec![9, 8],
                }],
            },
            Msg::GetTaskStatus { task_id: 2 },
            Msg::Heartbeat { client_id: 1 },
            Msg::GetTelemetry { format: 1 },
            Msg::TelemetryReport {
                format: 1,
                body: "# TYPE florida_rounds_committed counter\n".into(),
            },
            Msg::RegisterAck {
                accepted: true,
                client_id: 42,
                reason: String::new(),
            },
            Msg::TaskOffer { task: None },
            Msg::TaskOffer {
                task: Some(TaskDescriptor {
                    task_id: 1,
                    task_name: "t".into(),
                    app_name: "a".into(),
                    workflow_name: "w".into(),
                    state: TaskState::Running,
                    round: 1,
                    total_rounds: 10,
                }),
            },
            Msg::JoinAck {
                accepted: false,
                reason: "full".into(),
            },
            Msg::RoundPlan {
                role: RoundRole::Wait,
            },
            Msg::RoundPlan {
                role: RoundRole::Train(RoundInstruction {
                    round: 1,
                    model_blob: std::sync::Arc::new(vec![3, 2, 1]),
                    train: TrainParams {
                        preset: "tiny".into(),
                        lr: 5e-4,
                        prox_mu: 0.1,
                    },
                    secagg: None,
                    deadline_ms: 10,
                }),
            },
            Msg::RoundPlan {
                role: RoundRole::Unmask(UnmaskRequest {
                    round: 1,
                    vg_id: 0,
                    dropped: vec![(7, vec![1])],
                }),
            },
            Msg::RoundPlan {
                role: RoundRole::TaskDone,
            },
            Msg::Ack {
                ok: true,
                reason: String::new(),
            },
            Msg::TaskStatus {
                task: TaskDescriptor {
                    task_id: 1,
                    task_name: "t".into(),
                    app_name: "a".into(),
                    workflow_name: "w".into(),
                    state: TaskState::Completed,
                    round: 10,
                    total_rounds: 10,
                },
                participants: 32,
                last_round_duration_ms: 1234,
                last_accuracy: 0.97,
                last_loss: 0.1,
                epsilon: 2.0,
            },
            Msg::ErrorReply {
                message: "boom".into(),
            },
            Msg::LeafAssign {
                leaf_id: 100,
                task_id: 2,
                leaf_index: 1,
                leaf_count: 4,
            },
            Msg::ForwardPartial {
                leaf_id: 100,
                task_id: 2,
                round: 3,
                base_version: 4,
                members: vec![5, 6, 7],
                sum: vec![1.5, -2.25],
                total_weight: 3.0,
                count: 3,
                loss_sum: 0.9,
                min_loss: f64::INFINITY,
            },
            Msg::LeafAssignment {
                accepted: true,
                round: 3,
                base_version: 4,
                members: vec![5, 6, 7],
                reason: String::new(),
            },
            Msg::LeafAssignment {
                accepted: false,
                round: 0,
                base_version: 0,
                members: vec![],
                reason: "no open round".into(),
            },
            Msg::LeafAck {
                ok: true,
                folded: 3,
                reason: String::new(),
            },
        ];
        v.extend(sample_session_frames());
        v
    }

    #[test]
    fn binary_roundtrip_all_variants() {
        for msg in all_binary_samples() {
            let frame = encode_frame(&msg, WireCodec::Binary).unwrap();
            let (back, codec) = decode_frame(&frame).unwrap();
            assert_eq!(codec, WireCodec::Binary);
            assert_eq!(back, msg, "{msg:?}");
        }
    }

    /// Ids/counters above 2^53 — exact in the binary codec, and only
    /// exact through JSON because u64 fields ride as strings.
    const BIG: u64 = (1u64 << 60) + 7;

    // florida-lint: corpus(json-roundtrip)
    fn all_json_samples() -> Vec<Msg> {
        let mut v = vec![
            Msg::PollTask {
                client_id: BIG,
                app_name: "python-app".into(),
                workflow_name: "python-workflow".into(),
            },
            Msg::Heartbeat { client_id: 3 },
            Msg::GetTaskStatus { task_id: BIG },
            Msg::GetTelemetry { format: 0 },
            Msg::TelemetryReport {
                format: 0,
                body: "{\"counters\":{}}".into(),
            },
            Msg::UploadPlain {
                client_id: BIG,
                task_id: BIG + 1,
                round: BIG + 2,
                base_version: BIG + 3,
                delta: vec![0.25, -0.5, 1e-3],
                weight: 8.0,
                loss: 0.4,
            },
            Msg::RegisterAck {
                accepted: true,
                client_id: 3,
                reason: String::new(),
            },
            Msg::TaskOffer { task: None },
            Msg::TaskOffer {
                task: Some(TaskDescriptor {
                    task_id: BIG,
                    task_name: "t".into(),
                    app_name: "a".into(),
                    workflow_name: "w".into(),
                    state: TaskState::Running,
                    round: BIG,
                    total_rounds: BIG + 9,
                }),
            },
            Msg::TaskStatus {
                task: TaskDescriptor {
                    task_id: BIG,
                    task_name: "t".into(),
                    app_name: "a".into(),
                    workflow_name: "w".into(),
                    state: TaskState::Completed,
                    round: 10,
                    total_rounds: 10,
                },
                participants: BIG,
                last_round_duration_ms: 1234,
                last_accuracy: 0.97,
                last_loss: 0.1,
                epsilon: 2.0,
            },
            Msg::Ack {
                ok: false,
                reason: "deadline".into(),
            },
            Msg::ErrorReply {
                message: "x".into(),
            },
        ];
        v.push(sample_register());
        v.extend(sample_session_frames());
        v
    }

    #[test]
    fn json_roundtrip_all_json_capable_variants() {
        for msg in all_json_samples() {
            let frame = encode_frame(&msg, WireCodec::Json).unwrap();
            assert_eq!(frame[0], b'{');
            let (back, codec) = decode_frame(&frame).unwrap();
            assert_eq!(codec, WireCodec::Json);
            assert_eq!(back, msg, "{msg:?}");
        }
    }

    #[test]
    fn task_status_roundtrips_both_codecs_with_large_ids() {
        let msg = Msg::TaskStatus {
            task: TaskDescriptor {
                task_id: BIG,
                task_name: "big".into(),
                app_name: "a".into(),
                workflow_name: "w".into(),
                state: TaskState::Running,
                round: BIG,
                total_rounds: BIG + 1,
            },
            participants: BIG + 2,
            last_round_duration_ms: BIG + 3,
            last_accuracy: 0.5,
            last_loss: 0.25,
            epsilon: 1.0,
        };
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let frame = encode_frame(&msg, codec).unwrap();
            let (back, got) = decode_frame(&frame).unwrap();
            assert_eq!(got, codec);
            assert_eq!(back, msg, "via {codec:?}");
        }
    }

    #[test]
    fn json_decode_accepts_historical_number_form() {
        // Pre-string frames carried u64 fields as raw JSON numbers;
        // the tolerant decoder must still admit them (below 2^53).
        let j = Json::obj()
            .set("type", "heartbeat")
            .set("client_id", 42u64);
        let (msg, codec) = decode_frame(j.to_string().as_bytes()).unwrap();
        assert_eq!(codec, WireCodec::Json);
        assert_eq!(msg, Msg::Heartbeat { client_id: 42 });

        let j = Json::obj()
            .set("type", "session_grant")
            .set("accepted", true)
            .set("client_id", 7u64)
            .set("token", "1152921504606846983")
            .set("lease_ms", 30_000u64)
            .set("proto", 2u64)
            .set("reason", "");
        let (msg, _) = decode_frame(j.to_string().as_bytes()).unwrap();
        assert_eq!(
            msg,
            Msg::SessionGrant {
                accepted: true,
                client_id: 7,
                token: (1u64 << 60) + 7,
                lease_ms: 30_000,
                proto: 2,
                reason: String::new(),
            }
        );
    }

    #[test]
    fn json_u64_fields_are_encoded_as_strings() {
        let frame = encode_frame(&Msg::Heartbeat { client_id: BIG }, WireCodec::Json).unwrap();
        let text = String::from_utf8(frame).unwrap();
        assert!(
            text.contains(&format!("\"{BIG}\"")),
            "client_id must ride as a string: {text}"
        );
    }

    #[test]
    fn session_frames_roundtrip_both_codecs() {
        // The v2 surface is control plane: every session frame must
        // survive the binary ("gRPC") AND JSON ("REST") paths.
        for msg in sample_session_frames() {
            for codec in [WireCodec::Binary, WireCodec::Json] {
                let frame = encode_frame(&msg, codec).unwrap();
                let (back, got) = decode_frame(&frame).unwrap();
                assert_eq!(got, codec);
                assert_eq!(back, msg, "{msg:?} via {codec:?}");
            }
        }
    }

    #[test]
    fn secagg_messages_are_binary_only() {
        let m = Msg::UploadMasked {
            client_id: 1,
            task_id: 1,
            round: 1,
            vg_id: 0,
            masked: vec![1],
            loss: 0.0,
        };
        assert!(encode_frame(&m, WireCodec::Json).is_err());
        assert!(encode_frame(&m, WireCodec::Binary).is_ok());
    }

    #[test]
    fn leaf_messages_are_binary_only() {
        // The leaf↔master hop is platform-internal data plane, like the
        // secagg frames — the REST path never carries it.
        let m = Msg::ForwardPartial {
            leaf_id: 1,
            task_id: 1,
            round: 1,
            base_version: 1,
            members: vec![2],
            sum: vec![0.5],
            total_weight: 1.0,
            count: 1,
            loss_sum: 0.1,
            min_loss: f64::INFINITY,
        };
        assert!(encode_frame(&m, WireCodec::Json).is_err());
        assert!(encode_frame(&m, WireCodec::Binary).is_ok());
    }

    #[test]
    fn forward_partial_hostile_member_length_rejected() {
        // Claim a huge member list inside a tiny frame: decode must
        // error before allocating.
        let mut w = Writer::new();
        w.put_u8(0x20); // T_FORWARD_PARTIAL
        for _ in 0..4 {
            w.put_u64(1); // leaf, task, round, base_version
        }
        w.put_varint(u32::MAX as u64);
        w.put_u64(0);
        let buf = w.into_bytes();
        assert!(decode_frame(&buf).is_err());
    }

    #[test]
    fn attested_register_survives_both_codecs() {
        let auth = Authority::new(b"authority");
        let msg = sample_register();
        // Signature must verify after a JSON round trip.
        let frame = encode_frame(&msg, WireCodec::Json).unwrap();
        let (back, _) = decode_frame(&frame).unwrap();
        if let (Msg::Register { verdict: v1, .. }, Msg::Register { verdict: v2, .. }) =
            (&msg, &back)
        {
            assert_eq!(v1, v2);
            let auth_k = Authority::new(b"k");
            assert!(auth_k.verify(v2));
            assert!(!auth.verify(v2));
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn trace_trailer_roundtrips_both_codecs() {
        // v2 compat (satellite of the tracing layer): any traceable
        // message round-trips with and without a trace id, both codecs.
        let msgs = [
            Msg::Heartbeat { client_id: 4 },
            Msg::UploadPlain {
                client_id: 1,
                task_id: 2,
                round: 3,
                base_version: 4,
                delta: vec![0.5],
                weight: 1.0,
                loss: 0.1,
            },
        ];
        for msg in &msgs {
            for codec in [WireCodec::Binary, WireCodec::Json] {
                for trace in [None, Some(0xDEAD_BEEF_DEAD_BEEFu64)] {
                    let frame = encode_frame_traced(msg, codec, trace).unwrap();
                    let (back, got, tid) = decode_frame_traced(&frame).unwrap();
                    assert_eq!(got, codec);
                    assert_eq!(&back, msg);
                    assert_eq!(tid, trace, "{msg:?} via {codec:?}");
                }
            }
        }
    }

    #[test]
    fn trace_id_zero_means_untraced() {
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let traced = encode_frame_traced(&Msg::Heartbeat { client_id: 1 }, codec, Some(0))
                .unwrap();
            let plain = encode_frame(&Msg::Heartbeat { client_id: 1 }, codec).unwrap();
            assert_eq!(traced, plain, "0 must encode as no trailer ({codec:?})");
        }
    }

    #[test]
    fn v1_decoder_accepts_traced_frames_and_drops_the_trace() {
        // A v1 server (plain decode_frame) must interop with a tracing
        // client: the trailer parses cleanly and is simply discarded.
        for codec in [WireCodec::Binary, WireCodec::Json] {
            let frame =
                encode_frame_traced(&Msg::Heartbeat { client_id: 9 }, codec, Some(77)).unwrap();
            let (msg, got) = decode_frame(&frame).unwrap();
            assert_eq!(got, codec);
            assert_eq!(msg, Msg::Heartbeat { client_id: 9 });
        }
        // And a v1 client's untraced frame decodes with trace = None.
        let frame = encode_frame(&Msg::Heartbeat { client_id: 9 }, WireCodec::Binary).unwrap();
        let (_, _, tid) = decode_frame_traced(&frame).unwrap();
        assert_eq!(tid, None);
    }

    #[test]
    fn json_from_json_ignores_trace_id_like_any_unknown_key() {
        let j = Json::obj()
            .set("type", "heartbeat")
            .set("client_id", "5")
            .set("trace_id", "123456789");
        assert_eq!(Msg::from_json(&j).unwrap(), Msg::Heartbeat { client_id: 5 });
    }

    #[test]
    fn corrupt_trace_trailers_are_rejected() {
        let plain = encode_frame(&Msg::Heartbeat { client_id: 1 }, WireCodec::Binary).unwrap();
        // Wrong trailer length (not 0, not 9).
        let mut short = plain.clone();
        short.push(TRACE_TRAILER);
        assert!(decode_frame_traced(&short).is_err());
        // Right length, wrong marker byte.
        let mut bad_marker = plain;
        bad_marker.push(0x7F);
        bad_marker.extend_from_slice(&77u64.to_le_bytes());
        assert!(decode_frame_traced(&bad_marker).is_err());
    }

    #[test]
    fn telemetry_rpc_roundtrips_both_codecs() {
        let msgs = [
            Msg::GetTelemetry { format: 1 },
            Msg::TelemetryReport {
                format: 0,
                body: "{\"histograms\":{\"round_phase_training_ms\":{}}}".into(),
            },
        ];
        for msg in &msgs {
            for codec in [WireCodec::Binary, WireCodec::Json] {
                let frame = encode_frame(msg, codec).unwrap();
                let (back, got) = decode_frame(&frame).unwrap();
                assert_eq!(got, codec);
                assert_eq!(&back, msg, "via {codec:?}");
            }
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_frame(&[]).is_err());
        assert!(decode_frame(&[0xee, 1, 2]).is_err());
        assert!(decode_frame(b"{not json").is_err());
        assert!(decode_frame(br#"{"type":"wat"}"#).is_err());
    }
}
