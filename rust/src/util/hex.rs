//! Hex encoding/decoding for ids, keys, and wire debugging.

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// Decode a hex string (case-insensitive). Errors on odd length or
/// non-hex characters.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err(format!("odd hex length {}", s.len()));
    }
    let nib = |c: u8| -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("bad hex char {:?}", c as char)),
        }
    };
    let b = s.as_bytes();
    (0..s.len() / 2)
        .map(|i| Ok(nib(b[2 * i])? << 4 | nib(b[2 * i + 1])?))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_vector() {
        assert_eq!(encode(b"\x00\xff\x10"), "00ff10");
        assert_eq!(decode("00FF10").unwrap(), vec![0, 255, 16]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(decode("abc").is_err());
        assert!(decode("zz").is_err());
    }
}
