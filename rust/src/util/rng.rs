//! Deterministic, fast, seedable PRNG (xoshiro256**), plus distributions.
//!
//! The platform needs reproducible randomness in many places (client
//! selection, data synthesis, simulated latency, DP noise in tests), and
//! the offline crate set has no `rand` — so we carry our own. xoshiro256**
//! is the same generator family `rand` uses for `SmallRng`; it is not
//! cryptographically secure (crypto paths use `crypto::prg` instead).

/// xoshiro256** seedable PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller transform (§Perf: the
    /// transform produces a sin/cos pair; discarding half doubled the
    /// cost of Gaussian-noise injection on DP uploads).
    normal_spare: Option<f64>,
}

impl Rng {
    /// Create from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s, normal_spare: None }
    }

    /// Seed from the OS monotonic clock — for non-reproducible paths.
    pub fn from_entropy() -> Self {
        // florida-lint: allow(wall-clock-in-core): entropy seeding is non-reproducible by design
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        Self::new(t.as_nanos() as u64 ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n). Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (pair-cached: each transform
    /// yields two independent normals; we serve the spare next call).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        let (sin, cos) = theta.sin_cos();
        self.normal_spare = Some(r * sin);
        r * cos
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Log-normal with underlying N(mu, sigma^2) — heterogeneity model for
    /// simulated device speed/latency.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_scaled(mu, sigma).exp()
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang; used by `dirichlet`.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        if shape < 1.0 {
            // Boost via Johnk-style transform.
            let u = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * scale;
            }
        }
    }

    /// Dirichlet(alpha) over `k` categories — used for non-IID data splits.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha, 1.0)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in g.iter_mut() {
            *x /= s;
        }
        g
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (rejection
    /// sampling) — used to synthesize natural-language-like token streams.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF over precomputed harmonic would be faster but needs
        // state; rejection is fine for data-gen (build path, not hot path).
        debug_assert!(n >= 1);
        let nf = n as f64;
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = (nf.powf(1.0 - s).mul_add(u, 1.0 - u)).powf(1.0 / (1.0 - s));
            let k = x.floor();
            if k < 1.0 || k > nf {
                continue;
            }
            let ratio = (k / x).powf(s) * x / k;
            if v * ratio <= 1.0 {
                return k as usize - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a child RNG with an independent stream (for per-client seeds).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 8);
            assert_eq!(p.len(), 8);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(13);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            let k = r.zipf(50, 1.1);
            assert!(k < 50);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
