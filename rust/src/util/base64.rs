//! Base64 (standard alphabet, padded) — used by the JSON ("REST") codec to
//! carry binary payloads (model blobs, masked vectors, keys).

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes to standard padded base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity((data.len() + 2) / 3 * 4);
    for chunk in data.chunks(3) {
        let b = [
            chunk[0],
            *chunk.get(1).unwrap_or(&0),
            *chunk.get(2).unwrap_or(&0),
        ];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

/// Decode standard base64 (padding required, no whitespace).
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    let b = s.as_bytes();
    if b.len() % 4 != 0 {
        return Err(format!("base64 length {} not multiple of 4", b.len()));
    }
    let val = |c: u8| -> Result<u32, String> {
        match c {
            b'A'..=b'Z' => Ok((c - b'A') as u32),
            b'a'..=b'z' => Ok((c - b'a' + 26) as u32),
            b'0'..=b'9' => Ok((c - b'0' + 52) as u32),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => Err(format!("bad base64 char {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    for (i, chunk) in b.chunks(4).enumerate() {
        let last = (i + 1) * 4 == b.len();
        let pad = if last {
            chunk.iter().rev().take_while(|&&c| c == b'=').count()
        } else {
            0
        };
        if pad > 2 || (!last && chunk.contains(&b'=')) {
            return Err("bad padding".into());
        }
        let mut n = 0u32;
        for (j, &c) in chunk.iter().enumerate() {
            let v = if j >= 4 - pad { 0 } else { val(c)? };
            n = (n << 6) | v;
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        let cases = [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ];
        for (plain, b64) in cases {
            assert_eq!(encode(plain.as_bytes()), b64);
            assert_eq!(decode(b64).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn roundtrip_all_bytes() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode("a").is_err());
        assert!(decode("ab=c").is_err());
        assert!(decode("====").is_err());
        assert!(decode("a!cd").is_err());
    }
}
