//! Minimal RAII temporary directory (the offline crate set has no
//! `tempfile`). Used by the durability tests/benches and the CLI churn
//! scenario; the directory and its contents are removed on drop.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely named directory under the system temp dir, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `florida-<tag>-<pid>-<n>` under `std::env::temp_dir()`.
    pub fn new(tag: &str) -> Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "florida-{tag}-{}-{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_unique_dirs_and_cleans_up() {
        let a = TempDir::new("t").unwrap();
        let b = TempDir::new("t").unwrap();
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir());
        let kept = a.path().to_path_buf();
        std::fs::write(kept.join("f"), b"x").unwrap();
        drop(a);
        assert!(!kept.exists());
    }
}
