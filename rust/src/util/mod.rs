//! Shared utilities: deterministic RNG, JSON, hex, thread pool, stats,
//! and the micro-benchmark harness (criterion is unavailable offline).

pub mod base64;
pub mod bench;
pub mod hex;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod tempdir;

pub use pool::ThreadPool;
pub use rng::Rng;
pub use tempdir::TempDir;
