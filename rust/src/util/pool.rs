//! Fixed-size worker thread pool.
//!
//! The offline crate set has no tokio; the platform's concurrency is built
//! on OS threads. Services own a `ThreadPool` for request handling, and the
//! device simulator schedules thousands of client sessions as short tasks
//! over a bounded pool instead of a thread per device.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<TaskQueue>,
    available: Condvar,
    outstanding: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

struct TaskQueue {
    tasks: std::collections::VecDeque<Task>,
    shutdown: bool,
}

/// A fixed-size pool of worker threads executing boxed closures.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(TaskQueue {
                tasks: std::collections::VecDeque::new(),
                shutdown: false,
            }),
            available: Condvar::new(),
            outstanding: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("florida-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a task.
    pub fn execute(&self, f: impl FnOnce() + Send + 'static) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.tasks.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Block until every enqueued task has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.outstanding.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Parallel map: run `f` over `items` on the pool, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|x| x.unwrap()).collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let task = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break t;
                }
                if q.shutdown {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        task();
        if sh.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_lock.lock().unwrap();
            sh.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
