//! Minimal JSON value + parser + serializer.
//!
//! The offline crate set has no serde, so the platform carries its own
//! JSON for (a) the REST wire path, (b) `artifacts/manifest.json`,
//! (c) task configs, and (d) metrics export. Covers the full JSON grammar
//! except unicode escapes beyond BMP surrogate pairs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object — builder use).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed getters with error messages — config-parsing helpers.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing/invalid string field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing/invalid number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("missing/invalid integer field '{key}'"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn opt_str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
    } else {
        let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue; // unicode_escape advanced self.i itself
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        // self.i points at 'u'
        let hex4 = |b: &[u8], i: usize| -> Result<u32, String> {
            if i + 4 > b.len() {
                return Err("short \\u escape".into());
            }
            let s = std::str::from_utf8(&b[i..i + 4]).map_err(|e| e.to_string())?;
            u32::from_str_radix(s, 16).map_err(|e| e.to_string())
        };
        let hi = hex4(self.b, self.i + 1)?;
        self.i += 5;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                let lo = hex4(self.b, self.i + 2)?;
                self.i += 6;
                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(cp).ok_or_else(|| "bad surrogate".into());
            }
            return Err("lone high surrogate".into());
        }
        char::from_u32(hi).ok_or_else(|| "bad codepoint".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::obj()
            .set("name", "spam-task")
            .set("rounds", 10u64)
            .set("lr", 0.0005)
            .set("dp", Json::obj().set("sigma", 0.08).set("clip", 0.5))
            .set("tags", Json::Arr(vec!["a".into(), "b".into()]))
            .set("active", true)
            .set("nothing", Json::Null);
        let text = doc.to_string();
        assert_eq!(parse(&text).unwrap(), doc);
        let pretty = doc.pretty();
        assert_eq!(parse(&pretty).unwrap(), doc);
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let v = parse(" { \"a\" : [ 1 , 2.5 , -3e2 ] , \"b\" : { } } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v, Json::Str("a\n\t\"\\Aé".to_string()));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v, Json::Str("😀".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"abc", "{}x"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn numbers_roundtrip() {
        for n in [0.0, 1.0, -1.5, 1e-9, 12345678.0, -0.0005] {
            let t = Json::Num(n).to_string();
            assert_eq!(parse(&t).unwrap().as_f64().unwrap(), n, "{t}");
        }
    }

    #[test]
    fn typed_getters() {
        let v = parse(r#"{"s":"x","n":3,"b":true}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.opt_f64("n", 9.0), 3.0);
        assert_eq!(v.opt_f64("zz", 9.0), 9.0);
        assert!(v.opt_bool("b", false));
    }

    #[test]
    fn control_chars_escaped_on_write() {
        let s = Json::Str("\u{1}".into()).to_string();
        assert_eq!(s, "\"\\u0001\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("\u{1}".into()));
    }
}
