//! Descriptive statistics helpers for metrics and the bench harness.

/// Running mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile by linear interpolation over a sorted copy (q in [0,100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    let mut w = Welford::default();
    for &x in xs {
        w.push(x);
    }
    w.std()
}

/// L2 norm of an f32 slice (f64 accumulation).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn l2() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(l2_norm(&[]), 0.0);
    }
}
