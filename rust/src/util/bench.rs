//! Micro-benchmark harness (criterion replacement for the offline build).
//!
//! `cargo bench` targets use `harness = false` and drive this module. It
//! provides warmup, timed iterations, basic statistics, throughput
//! reporting, and aligned table output so every paper table/figure bench
//! prints the same style of rows.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
    /// Optional bytes processed per iteration (for GB/s reporting).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns) // bytes/ns == GB/s
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1500),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Quick-mode bencher for long end-to-end cases.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(1),
            min_iters: 1,
            max_iters: 3,
        }
    }

    /// Default bencher, honoring `FLORIDA_BENCH_QUICK=1` (CI snapshot
    /// mode: short measure windows so `scripts/check.sh` can emit a
    /// `BENCH_*.json` trajectory point without a full bench run).
    pub fn from_env() -> Self {
        if std::env::var("FLORIDA_BENCH_QUICK").is_ok() {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(60),
                min_iters: 3,
                max_iters: 100_000,
            }
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, per-iteration. Returns stats over individual iterations.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || (samples_ns.len() as u64) < self.min_iters)
            && (samples_ns.len() as u64) < self.max_iters
        {
            let t = Instant::now();
            f();
            samples_ns.push(t.elapsed().as_nanos() as f64);
        }
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            std_ns: stats::std(&samples_ns),
            bytes_per_iter: None,
        }
    }

    /// Like `run`, annotating bytes/iteration for throughput output.
    pub fn run_bytes<F: FnMut()>(&self, name: &str, bytes: u64, f: F) -> BenchResult {
        let mut r = self.run(name, f);
        r.bytes_per_iter = Some(bytes);
        r
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print one result row (aligned).
pub fn report(r: &BenchResult) {
    let tput = r
        .throughput_gbs()
        .map(|g| format!("  {g:.2} GB/s"))
        .unwrap_or_default();
    println!(
        "  {:<44} {:>12}  p50 {:>12}  p95 {:>12}  ({} iters){}",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        r.iters,
        tput
    );
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Accumulates results for a machine-readable snapshot — the perf
/// trajectory `scripts/check.sh` appends to on every CI run.
#[derive(Default)]
pub struct Snapshot {
    results: Vec<BenchResult>,
}

impl Snapshot {
    pub fn new() -> Snapshot {
        Snapshot::default()
    }

    pub fn record(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Print the row (as [`report`]) and record it for the snapshot.
    pub fn report(&mut self, r: BenchResult) {
        report(&r);
        self.record(r);
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut j = Json::obj()
                    .set("name", r.name.as_str())
                    .set("iters", r.iters)
                    .set("mean_ns", r.mean_ns)
                    .set("p50_ns", r.p50_ns)
                    .set("p95_ns", r.p95_ns)
                    .set("std_ns", r.std_ns);
                if let Some(b) = r.bytes_per_iter {
                    j = j.set("bytes_per_iter", b);
                }
                if let Some(g) = r.throughput_gbs() {
                    j = j.set("gb_per_s", g);
                }
                j
            })
            .collect();
        Json::obj().set("cases", Json::Arr(cases))
    }

    /// Write the snapshot to the path named by `env_var`, if set.
    pub fn write_if_env(&self, env_var: &str) -> std::io::Result<()> {
        if let Ok(path) = std::env::var(env_var) {
            if !path.is_empty() {
                std::fs::write(&path, self.to_json().to_string())?;
                println!("\nwrote bench snapshot: {path} ({} cases)", self.len());
            }
        }
        Ok(())
    }
}

/// Print a table of (label, value) series — used for figure reproduction.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n--- {title} ---");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::from("  ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        println!("{s}");
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 10_000,
        };
        let r = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            p50_ns: 1000.0,
            p95_ns: 1000.0,
            std_ns: 0.0,
            bytes_per_iter: Some(2000),
        };
        assert!((r.throughput_gbs().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_serializes_cases() {
        let mut snap = Snapshot::new();
        snap.record(BenchResult {
            name: "case_a".into(),
            iters: 10,
            mean_ns: 100.0,
            p50_ns: 90.0,
            p95_ns: 150.0,
            std_ns: 5.0,
            bytes_per_iter: Some(1000),
        });
        assert_eq!(snap.len(), 1);
        assert!(!snap.is_empty());
        let text = snap.to_json().to_string();
        let back = crate::util::json::parse(&text).unwrap();
        let cases = back.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").unwrap().as_str().unwrap(), "case_a");
        assert!(cases[0].get("gb_per_s").is_some());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains('s'));
    }
}
