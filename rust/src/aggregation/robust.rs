//! Byzantine-robust aggregation: coordinate-wise trimmed mean and
//! median (Yin et al. 2018), with a norm-bounding pre-filter.
//!
//! Unlike the streaming built-ins in the parent module, robust
//! estimators are **not** functions of a running weighted sum: trimming
//! and medians need every contribution at hand, so these folds buffer
//! O(cohort × dim) by necessity. The pre-filter runs in two stages:
//!
//! * **Zero-scoring at `accept`**: wrong-dimension deltas, non-finite
//!   components/weights, and deltas whose L2 norm exceeds
//!   [`HARD_NORM_LIMIT`] are rejected outright, leaving the fold
//!   unchanged — the upload bounces as `Ack { ok: false }`, which the
//!   admission policy engine counts against the sender's reputation.
//! * **Norm clipping at `finish`**: surviving deltas above the clip
//!   bound are scaled down onto the bound (fixed `clip_norm`, or
//!   adaptively [`ADAPTIVE_CLIP_FACTOR`]× the cohort's median norm), so
//!   a finite magnitude-bomb cannot dominate even the untrimmed tails.
//!
//! **Tree composition**: a trimmed mean/median over a union is not a
//! function of per-leaf sums, so robust folds cannot ride the
//! `PartialFold` seam. `export` returns an *empty* partial (every
//! `absorb` implementation rejects empties) and `absorb` refuses — a
//! mis-wired leaf can only fail loudly, never silently skew the
//! reduction. The round engine refuses leaf assignments for robust
//! tasks up front ([`crate::orchestrator::RoundEngine::leaf_slice`]),
//! so robust reduction happens at the root only.

use crate::error::{Error, Result};

use super::{Aggregator, AggregatorFold, PartialFold, UpdateStats};

/// Deltas with an L2 norm beyond this are discarded (zero-scored) at
/// `accept` — no plausible pseudo-gradient gets near it, and clipping
/// such a value would still let its direction through at full credit.
pub const HARD_NORM_LIMIT: f64 = 1e12;

/// Adaptive clip bound: this multiple of the cohort's median delta
/// norm, used when `clip_norm` is 0 (the config default).
pub const ADAPTIVE_CLIP_FACTOR: f64 = 3.0;

/// Knobs shared by the robust strategies, surfaced as `TaskConfig`
/// fields (`trim_fraction`, `clip_norm`).
#[derive(Clone, Copy, Debug)]
pub struct RobustParams {
    /// Fraction of updates trimmed from *each* end per coordinate by
    /// the trimmed mean (ignored by the median). Must sit in [0, 0.5).
    pub trim_fraction: f32,
    /// Fixed L2 clip bound; 0 selects the adaptive median-norm bound.
    pub clip_norm: f32,
}

impl Default for RobustParams {
    fn default() -> Self {
        RobustParams {
            trim_fraction: 0.2,
            clip_norm: 0.0,
        }
    }
}

impl RobustParams {
    pub fn validate(&self) -> Result<()> {
        if !self.trim_fraction.is_finite()
            || self.trim_fraction < 0.0
            || self.trim_fraction >= 0.5
        {
            return Err(Error::Config(format!(
                "trim_fraction {} must be in [0, 0.5)",
                self.trim_fraction
            )));
        }
        if !self.clip_norm.is_finite() || self.clip_norm < 0.0 {
            return Err(Error::Config(format!(
                "clip_norm {} must be finite and >= 0",
                self.clip_norm
            )));
        }
        Ok(())
    }
}

/// Which robust center estimate `finish` computes.
#[derive(Clone, Copy, Debug)]
enum RobustKind {
    TrimmedMean,
    Median,
}

/// Buffering fold behind both robust strategies. Each accepted update
/// is held as `(delta, weight, norm)`; the reduction happens once, at
/// `finish`.
struct RobustFold {
    dim: usize,
    kind: RobustKind,
    params: RobustParams,
    updates: Vec<(Vec<f32>, f64, f64)>,
}

impl RobustFold {
    /// The L2 clip bound for this cohort: the configured `clip_norm`,
    /// or [`ADAPTIVE_CLIP_FACTOR`]× the median delta norm when 0.
    fn clip_bound(&self) -> f64 {
        if self.params.clip_norm > 0.0 {
            return self.params.clip_norm as f64;
        }
        let mut norms: Vec<f64> = self.updates.iter().map(|(_, _, n)| *n).collect();
        norms.sort_unstable_by(|a, b| a.total_cmp(b));
        ADAPTIVE_CLIP_FACTOR * norms[(norms.len() - 1) / 2]
    }
}

impl AggregatorFold for RobustFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        // Full zero-scoring pass before any mutation: a rejected
        // update must leave the fold unchanged.
        if delta.len() != self.dim {
            return Err(Error::Model(format!(
                "dim mismatch {} vs {}",
                delta.len(),
                self.dim
            )));
        }
        if !stats.weight.is_finite() || stats.weight <= 0.0 {
            return Err(Error::Model(format!(
                "non-positive weight {}",
                stats.weight
            )));
        }
        let mut sq = 0.0f64;
        for &v in delta {
            if !v.is_finite() {
                return Err(Error::Model(format!(
                    "non-finite delta component {v}"
                )));
            }
            sq += v as f64 * v as f64;
        }
        let norm = sq.sqrt();
        if norm > HARD_NORM_LIMIT {
            return Err(Error::Model(format!(
                "delta norm {norm:.3e} exceeds hard limit {HARD_NORM_LIMIT:.0e}"
            )));
        }
        self.updates.push((delta.to_vec(), stats.weight, norm));
        Ok(())
    }

    fn count(&self) -> usize {
        self.updates.len()
    }

    /// A robust reduction is not a function of a linear partial sum, so
    /// there is nothing faithful to export. Return an *empty* partial:
    /// every `absorb` implementation (including this fold's) rejects
    /// empties, so a mis-wired leaf fails loudly instead of silently
    /// bypassing the trim.
    fn export(&self) -> PartialFold {
        PartialFold {
            sum: Vec::new(),
            total_weight: 0.0,
            count: 0,
            min_loss: f64::INFINITY,
        }
    }

    fn absorb(&mut self, _part: &PartialFold) -> Result<()> {
        Err(Error::Model(
            "robust strategies reduce at the root only — leaf partials are refused".into(),
        ))
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        if self.updates.is_empty() {
            return Err(Error::Model("empty robust fold".into()));
        }
        let bound = self.clip_bound();
        // Per-update clip factor (1.0 when under the bound or when the
        // cohort's bound collapsed to 0, i.e. all-zero deltas).
        let factors: Vec<f64> = self
            .updates
            .iter()
            .map(|(_, _, norm)| {
                if bound > 0.0 && *norm > bound {
                    bound / *norm
                } else {
                    1.0
                }
            })
            .collect();
        let n = self.updates.len();
        let trim = match self.kind {
            RobustKind::TrimmedMean => {
                let k = (self.params.trim_fraction as f64 * n as f64).floor() as usize;
                k.min((n - 1) / 2)
            }
            RobustKind::Median => 0,
        };
        let mut out = vec![0.0f32; self.dim];
        // (value, weight) scratch reused across coordinates.
        let mut col: Vec<(f64, f64)> = Vec::with_capacity(n);
        for (j, slot) in out.iter_mut().enumerate() {
            col.clear();
            for ((delta, weight, _), factor) in self.updates.iter().zip(&factors) {
                col.push((delta[j] as f64 * factor, *weight));
            }
            // Ties ordered by weight so the reduction is a function of
            // the multiset of updates, not of arrival order.
            col.sort_unstable_by(|a, b| {
                a.0.total_cmp(&b.0).then_with(|| a.1.total_cmp(&b.1))
            });
            *slot = match self.kind {
                RobustKind::TrimmedMean => {
                    let kept = &col[trim..n - trim];
                    let (mut sum, mut wsum) = (0.0f64, 0.0f64);
                    for &(v, w) in kept {
                        sum += v * w;
                        wsum += w;
                    }
                    (sum / wsum) as f32
                }
                RobustKind::Median => {
                    // Lower weighted median: the first value whose
                    // cumulative weight reaches half the total.
                    let total: f64 = col.iter().map(|&(_, w)| w).sum();
                    let mut cum = 0.0f64;
                    let mut med = col[n - 1].0;
                    for &(v, w) in &col {
                        cum += w;
                        if cum >= total / 2.0 {
                            med = v;
                            break;
                        }
                    }
                    med as f32
                }
            };
        }
        Ok(out)
    }
}

/// Coordinate-wise trimmed mean: per coordinate, drop the
/// `trim_fraction` lowest and highest values, weighted-average the
/// rest. Tolerates up to `trim_fraction` Byzantine contributors.
pub struct TrimmedMean {
    pub params: RobustParams,
}

impl Aggregator for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        self.params.validate()?;
        Ok(Box::new(RobustFold {
            dim,
            kind: RobustKind::TrimmedMean,
            params: self.params,
            updates: Vec::new(),
        }))
    }
}

/// Coordinate-wise (weighted) median: the classic ½-breakdown robust
/// center — any minority of colluding clients moves it only within the
/// honest values' span.
pub struct Median {
    pub params: RobustParams,
}

impl Aggregator for Median {
    fn name(&self) -> &'static str {
        "median"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        self.params.validate()?;
        Ok(Box::new(RobustFold {
            dim,
            kind: RobustKind::Median,
            params: self.params,
            updates: Vec::new(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, ClientUpdate, FedAvg};
    use super::*;

    fn upd(id: u64, delta: Vec<f32>, weight: f64) -> ClientUpdate {
        ClientUpdate {
            client_id: id,
            delta,
            weight,
            loss: 0.1,
            staleness: 0,
        }
    }

    fn honest(n: u64, v: f32) -> Vec<ClientUpdate> {
        (1..=n).map(|i| upd(i, vec![v, -v], 1.0)).collect()
    }

    #[test]
    fn trimmed_mean_discards_outliers() {
        let mut ups = honest(8, 1.0);
        ups.push(upd(9, vec![1e6, -1e6], 1.0));
        ups.push(upd(10, vec![-1e6, 1e6], 1.0));
        let got = TrimmedMean {
            params: RobustParams {
                trim_fraction: 0.2,
                clip_norm: f32::MAX, // isolate trimming from clipping
            },
        }
        .aggregate(&ups)
        .unwrap();
        assert!((got[0] - 1.0).abs() < 1e-6, "{}", got[0]);
        assert!((got[1] + 1.0).abs() < 1e-6, "{}", got[1]);
    }

    #[test]
    fn median_ignores_minority_bombs() {
        let mut ups = honest(7, 0.5);
        ups.push(upd(8, vec![1e9, 1e9], 100.0));
        ups.push(upd(9, vec![-1e9, -1e9], 100.0));
        let got = Median {
            params: RobustParams::default(),
        }
        .aggregate(&ups)
        .unwrap();
        assert!((got[0] - 0.5).abs() < 1e-6, "{}", got[0]);
        assert!((got[1] + 0.5).abs() < 1e-6, "{}", got[1]);
    }

    #[test]
    fn adaptive_clip_bounds_untrimmed_bomb() {
        // trim_fraction 0 → the bomb survives trimming; the adaptive
        // norm clip must still bound its contribution.
        let mut ups = honest(4, 1.0);
        ups.push(upd(5, vec![1e8, 0.0], 1.0));
        let got = TrimmedMean {
            params: RobustParams {
                trim_fraction: 0.0,
                clip_norm: 0.0,
            },
        }
        .aggregate(&ups)
        .unwrap();
        // Bomb clipped to 3× the median honest norm (≈ √2): its share
        // of the mean is at most ~3·√2/5 ≈ 0.85, not 2e7.
        assert!(got[0] < 2.0, "{}", got[0]);
    }

    #[test]
    fn zero_scores_nonfinite_and_oversized_without_mutation() {
        for name in ["trimmed_mean", "median"] {
            let agg = by_name(name, 0.0).unwrap();
            let mut fold = agg.begin(2).unwrap();
            fold.accept(&[1.0, 1.0], &upd(1, vec![], 1.0).stats()).unwrap();
            for (delta, weight) in [
                (vec![f32::NAN, 0.0], 1.0),
                (vec![f32::INFINITY, 0.0], 1.0),
                (vec![1.0, 2.0, 3.0], 1.0),                // wrong dim
                (vec![1.0, 1.0], f64::NAN),                // bad weight
                (vec![1.0, 1.0], 0.0),                     // bad weight
                (vec![1e38, 1e38], 1.0),                   // > hard norm limit
            ] {
                let r = fold.accept(
                    &delta,
                    &UpdateStats {
                        client_id: 9,
                        weight,
                        loss: 0.1,
                        staleness: 0,
                    },
                );
                assert!(r.is_err(), "{name}: {delta:?} w={weight} accepted");
                assert_eq!(fold.count(), 1, "{name}: rejected update mutated fold");
            }
            let got = fold.finish().unwrap();
            assert!((got[0] - 1.0).abs() < 1e-6, "{name}: {}", got[0]);
        }
    }

    #[test]
    fn robust_partials_refused_and_export_is_inert() {
        let agg = by_name("median", 0.0).unwrap();
        let mut fold = agg.begin(1).unwrap();
        fold.accept(&[2.0], &upd(1, vec![], 1.0).stats()).unwrap();
        // absorb refuses even a well-formed plain partial.
        assert!(fold
            .absorb(&PartialFold {
                sum: vec![4.0],
                total_weight: 2.0,
                count: 2,
                min_loss: f64::INFINITY,
            })
            .is_err());
        // export yields an empty partial no fold will absorb — a
        // mis-wired leaf fails loudly rather than skewing the result.
        let part = fold.export();
        assert_eq!(part.count, 0);
        let mut mean = FedAvg.begin(1).unwrap();
        assert!(mean.absorb(&part).is_err());
        assert!((fold.finish().unwrap()[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn f0_identical_updates_match_fedavg() {
        // No outliers, identical deltas with varying weights: both
        // robust centers coincide with the FedAvg mean exactly.
        let ups: Vec<ClientUpdate> = (1..=6)
            .map(|i| upd(i, vec![0.25, -1.5], i as f64))
            .collect();
        let reference = FedAvg.aggregate(&ups).unwrap();
        for name in ["trimmed_mean", "median"] {
            let got = by_name(name, 0.0).unwrap().aggregate(&ups).unwrap();
            for (g, r) in got.iter().zip(&reference) {
                assert!((g - r).abs() < 1e-6, "{name}: {g} vs {r}");
            }
        }
    }

    #[test]
    fn robust_folds_are_order_independent() {
        let ups = vec![
            upd(1, vec![1.0, -0.5], 1.0),
            upd(2, vec![0.5, 0.25], 2.0),
            upd(3, vec![-2.0, 4.0], 1.5),
            upd(4, vec![0.75, -1.0], 3.0),
            upd(5, vec![100.0, -100.0], 1.0),
        ];
        let mut rev = ups.clone();
        rev.reverse();
        for name in ["trimmed_mean", "median"] {
            let agg = by_name(name, 0.0).unwrap();
            let a = agg.aggregate(&ups).unwrap();
            let b = agg.aggregate(&rev).unwrap();
            assert_eq!(a, b, "{name} depends on arrival order");
        }
    }
}
