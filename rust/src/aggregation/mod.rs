//! Aggregation strategies ("user-defined logic" of the Master Aggregator,
//! §3.1.3): FedAvg, FedProx, DGA, and the buffered-async (Papaya/FedBuff)
//! rule used by asynchronous tasks (§4.3, §5.1).
//!
//! The paper uploads the aggregation recipe as a script/executable; here
//! strategies are a trait with built-ins selected by name from the task
//! config — custom strategies implement [`Aggregator`].
//!
//! Ingest is **streaming** (§Perf): a strategy opens an
//! [`AggregatorFold`] with `begin(dim)`, the round engine folds each
//! upload in at arrival with `accept(delta, stats)`, and `finish()`
//! yields the combined pseudo-gradient. The linear built-ins keep
//! O(dim) state (a [`DeltaAccumulator`]) plus scalars — the server
//! never buffers a cohort's worth of deltas. The Byzantine-robust
//! strategies in [`robust`] are the documented exception: trimmed
//! mean/median need every contribution at hand, so their folds buffer
//! O(cohort × dim) and refuse the leaf-tree `export`/`absorb` seam
//! (robust reduction happens at the root only).
//! [`Aggregator::aggregate`] is the batch convenience over the same
//! fold (tests, one-shot callers).

pub mod robust;

use crate::error::{Error, Result};
use crate::model::DeltaAccumulator;

pub use robust::{Median, RobustParams, TrimmedMean};

/// Per-update scalar metadata accompanying a delta on the ingest path.
#[derive(Clone, Copy, Debug)]
pub struct UpdateStats {
    pub client_id: u64,
    /// Example-count weight (paper: FedAvg weighting).
    pub weight: f64,
    /// Mean local training loss (drives DGA weighting).
    pub loss: f64,
    /// Global versions elapsed since the client fetched its base model
    /// (0 for synchronous rounds; > 0 under async).
    pub staleness: u64,
}

/// One client's contribution held as a value — the batch-call container
/// (tests, VG interims); the live ingest path never materializes these.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub client_id: u64,
    /// Pseudo-gradient (local params − global params at round start).
    pub delta: Vec<f32>,
    pub weight: f64,
    pub loss: f64,
    pub staleness: u64,
}

impl ClientUpdate {
    pub fn stats(&self) -> UpdateStats {
        UpdateStats {
            client_id: self.client_id,
            weight: self.weight,
            loss: self.loss,
            staleness: self.staleness,
        }
    }
}

/// A fold's exportable state — what a leaf aggregator ships up the
/// tree (§Hierarchical aggregation). The weighted sum stays f64 so a
/// leaf→master hop loses no precision versus folding at the root.
///
/// `min_loss` is the leaf's running DGA anchor (`+inf` for strategies
/// that don't track one): the master needs it to re-anchor the leaf's
/// softmax terms onto the global minimum before merging.
#[derive(Clone, Debug)]
pub struct PartialFold {
    pub sum: Vec<f64>,
    pub total_weight: f64,
    pub count: usize,
    pub min_loss: f64,
}

impl PartialFold {
    pub fn dim(&self) -> usize {
        self.sum.len()
    }
}

/// In-progress aggregation state: one fold per round (sync) or buffer
/// epoch (async). Implementations must stay O(dim) + O(1) per update.
///
/// Folds are **associative**: `export`/`absorb` split a cohort across
/// leaf folds whose merged result equals the flat fold of the same
/// updates (bit-identical when the f64 sums are exact; within f64
/// re-association error otherwise). `absorb` is O(dim) regardless of
/// how many updates the partial folded — the leaf-tree scaling lever.
pub trait AggregatorFold: Send {
    /// Fold one update in. Errors (dim mismatch, non-positive weight)
    /// leave the fold unchanged.
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()>;

    /// Updates folded in so far.
    fn count(&self) -> usize;

    /// Snapshot this fold's state for forwarding to a parent fold.
    fn export(&self) -> PartialFold;

    /// Merge a child fold's exported state. Errors (dim mismatch,
    /// empty or non-finite partial) leave the fold unchanged.
    fn absorb(&mut self, part: &PartialFold) -> Result<()>;

    /// Combined pseudo-gradient; error if nothing was folded.
    fn finish(self: Box<Self>) -> Result<Vec<f32>>;
}

/// Shared export for strategies whose merge is plain addition (any
/// per-update reweighting was already baked into the weights at
/// `accept` time — FedAvg/FedProx, and FedBuff's staleness discount).
fn plain_export(acc: &DeltaAccumulator) -> PartialFold {
    PartialFold {
        sum: acc.sum().to_vec(),
        total_weight: acc.total_weight(),
        count: acc.count(),
        min_loss: f64::INFINITY,
    }
}

fn plain_absorb(acc: &mut DeltaAccumulator, part: &PartialFold) -> Result<()> {
    if part.count == 0 {
        return Err(Error::Model("empty partial".into()));
    }
    acc.merge_scaled(&part.sum, part.total_weight, part.count, 1.0)
}

/// An aggregation strategy: a factory of per-round streaming folds.
pub trait Aggregator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Open a fold for updates of dimensionality `dim`.
    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>>;

    /// Batch convenience over the streaming fold.
    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        let first = updates
            .first()
            .ok_or_else(|| Error::Other("no updates to aggregate".into()))?;
        let mut fold = self.begin(first.delta.len())?;
        for u in updates {
            fold.accept(&u.delta, &u.stats())?;
        }
        fold.finish()
    }
}

/// Weighted running mean — the fold behind FedAvg/FedProx, and the base
/// for the reweighting strategies.
struct MeanFold {
    acc: DeltaAccumulator,
}

impl AggregatorFold for MeanFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        self.acc.add(delta, stats.weight)
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn export(&self) -> PartialFold {
        plain_export(&self.acc)
    }

    fn absorb(&mut self, part: &PartialFold) -> Result<()> {
        plain_absorb(&mut self.acc, part)
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

/// Weighted Federated Averaging (McMahan et al. 2017).
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        Ok(Box::new(MeanFold {
            acc: DeltaAccumulator::new(dim),
        }))
    }
}

/// FedProx (Li et al. 2018). Server-side combination is FedAvg; the
/// proximal μ‖θ−θ_g‖² term acts client-side and is carried to devices via
/// `TrainParams::prox_mu` (baked into the L2 train artifact).
pub struct FedProx {
    pub mu: f32,
}

impl Aggregator for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        FedAvg.begin(dim)
    }
}

/// Dynamic Gradient Aggregation (Dimitriadis et al. 2021): reweight
/// updates by training-loss quality — lower-loss clients count more,
/// via a softmax over −loss with temperature `temp`.
pub struct Dga {
    pub temp: f64,
}

impl Default for Dga {
    fn default() -> Self {
        Dga { temp: 1.0 }
    }
}

impl Aggregator for Dga {
    fn name(&self) -> &'static str {
        "dga"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        if !self.temp.is_finite() || self.temp <= 0.0 {
            return Err(Error::Other("dga temperature must be > 0".into()));
        }
        Ok(Box::new(DgaFold {
            acc: DeltaAccumulator::new(dim),
            temp: self.temp,
            min_loss: f64::INFINITY,
        }))
    }
}

/// Streaming DGA: qualities are softmax terms `exp(-(loss - min)/temp)`
/// relative to the running minimum loss. When a new minimum arrives,
/// everything folded so far is rescaled by `exp((new - old)/temp)` — the
/// shift cancels in the weighted mean, so one pass matches the two-pass
/// batch formula without ever re-reading a delta. Anchoring at the
/// minimum keeps every exponent ≤ 0 (no overflow for outlier losses).
struct DgaFold {
    acc: DeltaAccumulator,
    temp: f64,
    min_loss: f64,
}

impl AggregatorFold for DgaFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        // Validate before touching min_loss or rescaling: a rejected
        // update must leave the fold unchanged. A -inf loss would
        // otherwise rescale the accumulator by exp(-inf) = 0, wiping
        // every previously folded contribution.
        self.acc.validate(delta, stats.weight)?;
        if !stats.loss.is_finite() {
            return Err(Error::Model(format!("non-finite loss {}", stats.loss)));
        }
        if stats.loss < self.min_loss {
            if self.min_loss.is_finite() {
                self.acc.scale(((stats.loss - self.min_loss) / self.temp).exp());
            }
            self.min_loss = stats.loss;
        }
        let quality = (-(stats.loss - self.min_loss) / self.temp).exp();
        self.acc.add(delta, (stats.weight * quality).max(1e-12))
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn export(&self) -> PartialFold {
        PartialFold {
            sum: self.acc.sum().to_vec(),
            total_weight: self.acc.total_weight(),
            count: self.acc.count(),
            min_loss: self.min_loss,
        }
    }

    /// Merge a leaf's partial by re-anchoring its softmax terms. The
    /// leaf folded relative to its local min-loss; multiplying both
    /// sides by `exp(-(anchor_gap)/temp)` puts them on one reference
    /// point, so the merged fold matches the flat fold of the union.
    fn absorb(&mut self, part: &PartialFold) -> Result<()> {
        // Validate everything before the irreversible rescale — a
        // rejected partial must leave the fold unchanged.
        if part.count == 0 || !part.min_loss.is_finite() {
            return Err(Error::Model("empty or non-finite DGA partial".into()));
        }
        if part.dim() != self.acc.dim() {
            return Err(Error::Model(format!(
                "dim mismatch {} vs {}",
                part.dim(),
                self.acc.dim()
            )));
        }
        if !part.total_weight.is_finite() || part.total_weight <= 0.0 {
            return Err(Error::Model(format!(
                "non-positive partial weight {}",
                part.total_weight
            )));
        }
        if part.min_loss < self.min_loss {
            // Partial brings a new global minimum: rescale what we hold
            // (mirrors the streaming accept path), then fold the
            // partial at factor 1.0 — it is already on the new anchor.
            if self.min_loss.is_finite() {
                self.acc
                    .scale(((part.min_loss - self.min_loss) / self.temp).exp());
            }
            self.min_loss = part.min_loss;
            self.acc
                .merge_scaled(&part.sum, part.total_weight, part.count, 1.0)
        } else {
            // Our anchor stays; discount the partial by its anchor gap.
            // Clamp like `accept`'s 1e-12 weight floor so a far-off
            // leaf underflowing exp() can't zero the merge factor.
            let factor = ((-(part.min_loss - self.min_loss) / self.temp).exp()).max(1e-300);
            self.acc
                .merge_scaled(&part.sum, part.total_weight, part.count, factor)
        }
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

/// Buffered asynchronous aggregation (Papaya / FedBuff): combine a buffer
/// of K updates with staleness discount `1/(1+s)^alpha`.
pub struct FedBuff {
    pub staleness_alpha: f64,
}

impl Default for FedBuff {
    fn default() -> Self {
        FedBuff {
            staleness_alpha: 0.5,
        }
    }
}

struct FedBuffFold {
    acc: DeltaAccumulator,
    staleness_alpha: f64,
}

impl AggregatorFold for FedBuffFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        let discount = 1.0 / (1.0 + stats.staleness as f64).powf(self.staleness_alpha);
        self.acc.add(delta, stats.weight * discount)
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn export(&self) -> PartialFold {
        // The staleness discount is baked into each weight at accept,
        // so FedBuff partials merge by plain addition.
        plain_export(&self.acc)
    }

    fn absorb(&mut self, part: &PartialFold) -> Result<()> {
        plain_absorb(&mut self.acc, part)
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

impl Aggregator for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        Ok(Box::new(FedBuffFold {
            acc: DeltaAccumulator::new(dim),
            staleness_alpha: self.staleness_alpha,
        }))
    }
}

/// Look up a built-in strategy by config name (robust strategies get
/// default [`RobustParams`]; use [`for_task`] to thread config knobs).
pub fn by_name(name: &str, prox_mu: f32) -> Result<Box<dyn Aggregator>> {
    for_task(name, prox_mu, RobustParams::default())
}

/// Strategies whose reduction cannot ride the linear `PartialFold`
/// seam: the round engine refuses leaf assignments for these, so the
/// robust reduction happens at the root only.
pub fn is_robust(name: &str) -> bool {
    matches!(name, "trimmed_mean" | "median")
}

/// Look up a built-in strategy with the task's robustness knobs.
pub fn for_task(name: &str, prox_mu: f32, robust: RobustParams) -> Result<Box<dyn Aggregator>> {
    Ok(match name {
        "fedavg" => Box::new(FedAvg),
        "fedprox" => Box::new(FedProx { mu: prox_mu }),
        "dga" => Box::new(Dga::default()),
        "fedbuff" => Box::new(FedBuff::default()),
        "trimmed_mean" => {
            robust.validate()?;
            Box::new(TrimmedMean { params: robust })
        }
        "median" => {
            robust.validate()?;
            Box::new(Median { params: robust })
        }
        other => {
            return Err(Error::Config(format!(
                "unknown aggregation strategy {other:?} \
                 (expected fedavg|fedprox|dga|fedbuff|trimmed_mean|median)"
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: u64, delta: Vec<f32>, weight: f64, loss: f64, staleness: u64) -> ClientUpdate {
        ClientUpdate {
            client_id: id,
            delta,
            weight,
            loss,
            staleness,
        }
    }

    #[test]
    fn fedavg_weighted_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![1.0, 0.0], 1.0, 0.5, 0),
                upd(2, vec![0.0, 2.0], 3.0, 0.5, 0),
            ])
            .unwrap();
        assert!((got[0] - 0.25).abs() < 1e-6);
        assert!((got[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_equal_weights_is_plain_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![2.0], 5.0, 0.0, 0),
                upd(2, vec![4.0], 5.0, 0.0, 0),
            ])
            .unwrap();
        assert!((got[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn fedprox_server_side_matches_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, -1.0], 2.0, 0.3, 0),
            upd(2, vec![3.0, 5.0], 1.0, 0.9, 0),
        ];
        assert_eq!(
            FedProx { mu: 0.1 }.aggregate(&ups).unwrap(),
            FedAvg.aggregate(&ups).unwrap()
        );
    }

    #[test]
    fn dga_prefers_low_loss() {
        // Two clients, equal weights, very different losses: result must
        // lean strongly towards the low-loss client's delta.
        let got = Dga { temp: 0.1 }
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.1, 0),
                upd(2, vec![-1.0], 1.0, 5.0, 0),
            ])
            .unwrap();
        assert!(got[0] > 0.99, "{}", got[0]);
    }

    #[test]
    fn dga_equal_losses_reduces_to_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, 2.0], 2.0, 0.7, 0),
            upd(2, vec![-1.0, 0.0], 1.0, 0.7, 0),
        ];
        let a = Dga::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn dga_order_independent_min_rescaling() {
        // The streaming rescale must make arrival order irrelevant: the
        // minimum loss arriving last exercises the `scale` path.
        let asc = vec![
            upd(1, vec![1.0, 0.0], 1.0, 0.2, 0),
            upd(2, vec![0.0, 1.0], 2.0, 1.7, 0),
            upd(3, vec![-1.0, 2.0], 1.5, 3.0, 0),
        ];
        let mut desc = asc.clone();
        desc.reverse();
        let a = Dga { temp: 0.7 }.aggregate(&asc).unwrap();
        let b = Dga { temp: 0.7 }.aggregate(&desc).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn dga_rejected_update_leaves_fold_unchanged() {
        let mut fold = Dga { temp: 1.0 }.begin(1).unwrap();
        fold.accept(&[1.0], &upd(1, vec![], 1.0, 0.5, 0).stats())
            .unwrap();
        // Wrong-dim update with a far lower loss: rejected, and it must
        // not have rescaled the fold or moved the running minimum.
        let bad = fold.accept(&[1.0, 2.0], &upd(2, vec![], 1.0, -100.0, 0).stats());
        assert!(bad.is_err());
        // A -inf loss would rescale the accumulator by exp(-inf) = 0;
        // it must be rejected before any mutation.
        let inf = fold.accept(&[5.0], &upd(4, vec![], 1.0, f64::NEG_INFINITY, 0).stats());
        assert!(inf.is_err());
        fold.accept(&[3.0], &upd(3, vec![], 1.0, 0.5, 0).stats())
            .unwrap();
        // Equal losses ⇒ plain mean; a poisoned minimum would have
        // collapsed one side to the 1e-12 clamp instead.
        let got = fold.finish().unwrap();
        assert!((got[0] - 2.0).abs() < 1e-5, "{}", got[0]);
    }

    #[test]
    fn fedbuff_discounts_stale() {
        // Fresh vs very stale update with opposite directions: fresh wins.
        let got = FedBuff {
            staleness_alpha: 1.0,
        }
        .aggregate(&[
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![-1.0], 1.0, 0.0, 99),
        ])
        .unwrap();
        assert!(got[0] > 0.9, "{}", got[0]);
    }

    #[test]
    fn fedbuff_zero_staleness_is_fedavg() {
        let ups = vec![
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![3.0], 1.0, 0.0, 0),
        ];
        let a = FedBuff::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn fold_counts_and_streams_incrementally() {
        let mut fold = FedAvg.begin(2).unwrap();
        assert_eq!(fold.count(), 0);
        fold.accept(&[1.0, 0.0], &upd(1, vec![], 1.0, 0.0, 0).stats())
            .unwrap();
        fold.accept(&[0.0, 1.0], &upd(2, vec![], 3.0, 0.0, 0).stats())
            .unwrap();
        assert_eq!(fold.count(), 2);
        let m = fold.finish().unwrap();
        assert!((m[0] - 0.25).abs() < 1e-6);
        assert!((m[1] - 0.75).abs() < 1e-6);
    }

    /// Fold `ups` flat, and split across `splits` leaf folds merged
    /// into a master fold — return both means.
    fn tree_vs_flat(
        agg: &dyn Aggregator,
        ups: &[ClientUpdate],
        splits: &[std::ops::Range<usize>],
    ) -> (Vec<f32>, Vec<f32>) {
        let dim = ups[0].delta.len();
        let flat = agg.aggregate(ups).unwrap();
        let mut master = agg.begin(dim).unwrap();
        for r in splits {
            let mut leaf = agg.begin(dim).unwrap();
            for u in &ups[r.clone()] {
                leaf.accept(&u.delta, &u.stats()).unwrap();
            }
            master.absorb(&leaf.export()).unwrap();
        }
        assert_eq!(master.count(), ups.len());
        (master.finish().unwrap(), flat)
    }

    #[test]
    fn tree_fold_matches_flat_fedavg_bitwise() {
        // Dyadic inputs: every f64 partial sum is exact, so any
        // association of the adds yields bit-identical results.
        let ups = vec![
            upd(1, vec![1.0, 0.5], 1.0, 0.0, 0),
            upd(2, vec![0.25, 2.0], 2.0, 0.0, 0),
            upd(3, vec![-1.5, 4.0], 1.0, 0.0, 0),
            upd(4, vec![0.125, -8.0], 4.0, 0.0, 0),
        ];
        let (tree, flat) = tree_vs_flat(&FedAvg, &ups, &[0..2, 2..4]);
        assert_eq!(tree, flat);
    }

    #[test]
    fn tree_fold_matches_flat_dga_any_leaf_holds_min() {
        // The global min-loss landing on the first or the last leaf
        // exercises both absorb branches (re-anchor vs discount).
        let ups = vec![
            upd(1, vec![1.0, -2.0], 1.0, 0.2, 0),
            upd(2, vec![0.5, 1.0], 2.0, 1.3, 0),
            upd(3, vec![-1.0, 3.0], 1.5, 0.9, 0),
            upd(4, vec![2.0, 0.0], 1.0, 2.4, 0),
        ];
        let dga = Dga { temp: 0.7 };
        for splits in [&[0..2, 2..4][..], &[0..1, 1..3, 3..4][..]] {
            let (tree, flat) = tree_vs_flat(&dga, &ups, splits);
            for (x, y) in tree.iter().zip(&flat) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
        let mut rev = ups;
        rev.reverse(); // min loss now in the last leaf
        let (tree, flat) = tree_vs_flat(&dga, &rev, &[0..2, 2..4]);
        for (x, y) in tree.iter().zip(&flat) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn tree_fold_matches_flat_fedbuff() {
        let ups = vec![
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![-1.0], 1.0, 0.0, 7),
            upd(3, vec![3.0], 2.0, 0.0, 2),
        ];
        let (tree, flat) = tree_vs_flat(&FedBuff::default(), &ups, &[0..1, 1..3]);
        for (x, y) in tree.iter().zip(&flat) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn absorb_rejects_bad_partials_without_mutation() {
        let mut fold = Dga { temp: 1.0 }.begin(2).unwrap();
        fold.accept(&[1.0, 1.0], &upd(1, vec![], 1.0, 0.5, 0).stats())
            .unwrap();
        // Empty partial.
        assert!(fold
            .absorb(&PartialFold {
                sum: vec![0.0; 2],
                total_weight: 0.0,
                count: 0,
                min_loss: f64::INFINITY,
            })
            .is_err());
        // Dim mismatch with a would-be new minimum: must not rescale.
        assert!(fold
            .absorb(&PartialFold {
                sum: vec![1.0; 3],
                total_weight: 1.0,
                count: 1,
                min_loss: -100.0,
            })
            .is_err());
        let got = fold.finish().unwrap();
        assert!((got[0] - 1.0).abs() < 1e-6, "{}", got[0]);
        // Plain folds reject empties too.
        let mut mean = FedAvg.begin(1).unwrap();
        assert!(mean
            .absorb(&PartialFold {
                sum: vec![0.0],
                total_weight: 0.0,
                count: 0,
                min_loss: f64::INFINITY,
            })
            .is_err());
    }

    #[test]
    fn registry_lookup() {
        for name in ["fedavg", "fedprox", "dga", "fedbuff", "trimmed_mean", "median"] {
            assert_eq!(by_name(name, 0.1).unwrap().name(), name);
        }
        assert!(by_name("magic", 0.0).is_err());
        // Robust knobs are validated at construction time.
        assert!(for_task(
            "trimmed_mean",
            0.0,
            RobustParams {
                trim_fraction: 0.5,
                clip_norm: 0.0
            }
        )
        .is_err());
        assert_eq!(
            ["fedavg", "fedprox", "dga", "fedbuff", "trimmed_mean", "median"]
                .iter()
                .filter(|n| is_robust(n))
                .count(),
            2
        );
    }

    #[test]
    fn errors_on_empty_or_mismatched() {
        assert!(FedAvg.aggregate(&[]).is_err());
        assert!(FedAvg
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.0, 0),
                upd(2, vec![1.0, 2.0], 1.0, 0.0, 0),
            ])
            .is_err());
        assert!(FedAvg.begin(1).unwrap().finish().is_err());
        assert!(Dga { temp: 0.0 }.begin(1).is_err());
    }
}
