//! Aggregation strategies ("user-defined logic" of the Master Aggregator,
//! §3.1.3): FedAvg, FedProx, DGA, and the buffered-async (Papaya/FedBuff)
//! rule used by asynchronous tasks (§4.3, §5.1).
//!
//! The paper uploads the aggregation recipe as a script/executable; here
//! strategies are a trait with built-ins selected by name from the task
//! config — custom strategies implement [`Aggregator`].
//!
//! Ingest is **streaming** (§Perf): a strategy opens an
//! [`AggregatorFold`] with `begin(dim)`, the round engine folds each
//! upload in at arrival with `accept(delta, stats)`, and `finish()`
//! yields the combined pseudo-gradient. All built-ins keep O(dim)
//! state (a [`DeltaAccumulator`]) plus scalars — the server never
//! buffers a cohort's worth of deltas. [`Aggregator::aggregate`] is the
//! batch convenience over the same fold (tests, one-shot callers).

use crate::error::{Error, Result};
use crate::model::DeltaAccumulator;

/// Per-update scalar metadata accompanying a delta on the ingest path.
#[derive(Clone, Copy, Debug)]
pub struct UpdateStats {
    pub client_id: u64,
    /// Example-count weight (paper: FedAvg weighting).
    pub weight: f64,
    /// Mean local training loss (drives DGA weighting).
    pub loss: f64,
    /// Global versions elapsed since the client fetched its base model
    /// (0 for synchronous rounds; > 0 under async).
    pub staleness: u64,
}

/// One client's contribution held as a value — the batch-call container
/// (tests, VG interims); the live ingest path never materializes these.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub client_id: u64,
    /// Pseudo-gradient (local params − global params at round start).
    pub delta: Vec<f32>,
    pub weight: f64,
    pub loss: f64,
    pub staleness: u64,
}

impl ClientUpdate {
    pub fn stats(&self) -> UpdateStats {
        UpdateStats {
            client_id: self.client_id,
            weight: self.weight,
            loss: self.loss,
            staleness: self.staleness,
        }
    }
}

/// In-progress aggregation state: one fold per round (sync) or buffer
/// epoch (async). Implementations must stay O(dim) + O(1) per update.
pub trait AggregatorFold: Send {
    /// Fold one update in. Errors (dim mismatch, non-positive weight)
    /// leave the fold unchanged.
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()>;

    /// Updates folded in so far.
    fn count(&self) -> usize;

    /// Combined pseudo-gradient; error if nothing was folded.
    fn finish(self: Box<Self>) -> Result<Vec<f32>>;
}

/// An aggregation strategy: a factory of per-round streaming folds.
pub trait Aggregator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Open a fold for updates of dimensionality `dim`.
    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>>;

    /// Batch convenience over the streaming fold.
    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        let first = updates
            .first()
            .ok_or_else(|| Error::Other("no updates to aggregate".into()))?;
        let mut fold = self.begin(first.delta.len())?;
        for u in updates {
            fold.accept(&u.delta, &u.stats())?;
        }
        fold.finish()
    }
}

/// Weighted running mean — the fold behind FedAvg/FedProx, and the base
/// for the reweighting strategies.
struct MeanFold {
    acc: DeltaAccumulator,
}

impl AggregatorFold for MeanFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        self.acc.add(delta, stats.weight)
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

/// Weighted Federated Averaging (McMahan et al. 2017).
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        Ok(Box::new(MeanFold {
            acc: DeltaAccumulator::new(dim),
        }))
    }
}

/// FedProx (Li et al. 2018). Server-side combination is FedAvg; the
/// proximal μ‖θ−θ_g‖² term acts client-side and is carried to devices via
/// `TrainParams::prox_mu` (baked into the L2 train artifact).
pub struct FedProx {
    pub mu: f32,
}

impl Aggregator for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        FedAvg.begin(dim)
    }
}

/// Dynamic Gradient Aggregation (Dimitriadis et al. 2021): reweight
/// updates by training-loss quality — lower-loss clients count more,
/// via a softmax over −loss with temperature `temp`.
pub struct Dga {
    pub temp: f64,
}

impl Default for Dga {
    fn default() -> Self {
        Dga { temp: 1.0 }
    }
}

impl Aggregator for Dga {
    fn name(&self) -> &'static str {
        "dga"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        if !self.temp.is_finite() || self.temp <= 0.0 {
            return Err(Error::Other("dga temperature must be > 0".into()));
        }
        Ok(Box::new(DgaFold {
            acc: DeltaAccumulator::new(dim),
            temp: self.temp,
            min_loss: f64::INFINITY,
        }))
    }
}

/// Streaming DGA: qualities are softmax terms `exp(-(loss - min)/temp)`
/// relative to the running minimum loss. When a new minimum arrives,
/// everything folded so far is rescaled by `exp((new - old)/temp)` — the
/// shift cancels in the weighted mean, so one pass matches the two-pass
/// batch formula without ever re-reading a delta. Anchoring at the
/// minimum keeps every exponent ≤ 0 (no overflow for outlier losses).
struct DgaFold {
    acc: DeltaAccumulator,
    temp: f64,
    min_loss: f64,
}

impl AggregatorFold for DgaFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        // Validate before touching min_loss or rescaling: a rejected
        // update must leave the fold unchanged. A -inf loss would
        // otherwise rescale the accumulator by exp(-inf) = 0, wiping
        // every previously folded contribution.
        self.acc.validate(delta, stats.weight)?;
        if !stats.loss.is_finite() {
            return Err(Error::Model(format!("non-finite loss {}", stats.loss)));
        }
        if stats.loss < self.min_loss {
            if self.min_loss.is_finite() {
                self.acc.scale(((stats.loss - self.min_loss) / self.temp).exp());
            }
            self.min_loss = stats.loss;
        }
        let quality = (-(stats.loss - self.min_loss) / self.temp).exp();
        self.acc.add(delta, (stats.weight * quality).max(1e-12))
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

/// Buffered asynchronous aggregation (Papaya / FedBuff): combine a buffer
/// of K updates with staleness discount `1/(1+s)^alpha`.
pub struct FedBuff {
    pub staleness_alpha: f64,
}

impl Default for FedBuff {
    fn default() -> Self {
        FedBuff {
            staleness_alpha: 0.5,
        }
    }
}

struct FedBuffFold {
    acc: DeltaAccumulator,
    staleness_alpha: f64,
}

impl AggregatorFold for FedBuffFold {
    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        let discount = 1.0 / (1.0 + stats.staleness as f64).powf(self.staleness_alpha);
        self.acc.add(delta, stats.weight * discount)
    }

    fn count(&self) -> usize {
        self.acc.count()
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        self.acc.mean()
    }
}

impl Aggregator for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn begin(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        Ok(Box::new(FedBuffFold {
            acc: DeltaAccumulator::new(dim),
            staleness_alpha: self.staleness_alpha,
        }))
    }
}

/// Look up a built-in strategy by config name.
pub fn by_name(name: &str, prox_mu: f32) -> Result<Box<dyn Aggregator>> {
    Ok(match name {
        "fedavg" => Box::new(FedAvg),
        "fedprox" => Box::new(FedProx { mu: prox_mu }),
        "dga" => Box::new(Dga::default()),
        "fedbuff" => Box::new(FedBuff::default()),
        other => {
            return Err(Error::Config(format!(
                "unknown aggregation strategy {other:?} \
                 (expected fedavg|fedprox|dga|fedbuff)"
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: u64, delta: Vec<f32>, weight: f64, loss: f64, staleness: u64) -> ClientUpdate {
        ClientUpdate {
            client_id: id,
            delta,
            weight,
            loss,
            staleness,
        }
    }

    #[test]
    fn fedavg_weighted_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![1.0, 0.0], 1.0, 0.5, 0),
                upd(2, vec![0.0, 2.0], 3.0, 0.5, 0),
            ])
            .unwrap();
        assert!((got[0] - 0.25).abs() < 1e-6);
        assert!((got[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_equal_weights_is_plain_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![2.0], 5.0, 0.0, 0),
                upd(2, vec![4.0], 5.0, 0.0, 0),
            ])
            .unwrap();
        assert!((got[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn fedprox_server_side_matches_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, -1.0], 2.0, 0.3, 0),
            upd(2, vec![3.0, 5.0], 1.0, 0.9, 0),
        ];
        assert_eq!(
            FedProx { mu: 0.1 }.aggregate(&ups).unwrap(),
            FedAvg.aggregate(&ups).unwrap()
        );
    }

    #[test]
    fn dga_prefers_low_loss() {
        // Two clients, equal weights, very different losses: result must
        // lean strongly towards the low-loss client's delta.
        let got = Dga { temp: 0.1 }
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.1, 0),
                upd(2, vec![-1.0], 1.0, 5.0, 0),
            ])
            .unwrap();
        assert!(got[0] > 0.99, "{}", got[0]);
    }

    #[test]
    fn dga_equal_losses_reduces_to_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, 2.0], 2.0, 0.7, 0),
            upd(2, vec![-1.0, 0.0], 1.0, 0.7, 0),
        ];
        let a = Dga::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn dga_order_independent_min_rescaling() {
        // The streaming rescale must make arrival order irrelevant: the
        // minimum loss arriving last exercises the `scale` path.
        let asc = vec![
            upd(1, vec![1.0, 0.0], 1.0, 0.2, 0),
            upd(2, vec![0.0, 1.0], 2.0, 1.7, 0),
            upd(3, vec![-1.0, 2.0], 1.5, 3.0, 0),
        ];
        let mut desc = asc.clone();
        desc.reverse();
        let a = Dga { temp: 0.7 }.aggregate(&asc).unwrap();
        let b = Dga { temp: 0.7 }.aggregate(&desc).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn dga_rejected_update_leaves_fold_unchanged() {
        let mut fold = Dga { temp: 1.0 }.begin(1).unwrap();
        fold.accept(&[1.0], &upd(1, vec![], 1.0, 0.5, 0).stats())
            .unwrap();
        // Wrong-dim update with a far lower loss: rejected, and it must
        // not have rescaled the fold or moved the running minimum.
        let bad = fold.accept(&[1.0, 2.0], &upd(2, vec![], 1.0, -100.0, 0).stats());
        assert!(bad.is_err());
        // A -inf loss would rescale the accumulator by exp(-inf) = 0;
        // it must be rejected before any mutation.
        let inf = fold.accept(&[5.0], &upd(4, vec![], 1.0, f64::NEG_INFINITY, 0).stats());
        assert!(inf.is_err());
        fold.accept(&[3.0], &upd(3, vec![], 1.0, 0.5, 0).stats())
            .unwrap();
        // Equal losses ⇒ plain mean; a poisoned minimum would have
        // collapsed one side to the 1e-12 clamp instead.
        let got = fold.finish().unwrap();
        assert!((got[0] - 2.0).abs() < 1e-5, "{}", got[0]);
    }

    #[test]
    fn fedbuff_discounts_stale() {
        // Fresh vs very stale update with opposite directions: fresh wins.
        let got = FedBuff {
            staleness_alpha: 1.0,
        }
        .aggregate(&[
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![-1.0], 1.0, 0.0, 99),
        ])
        .unwrap();
        assert!(got[0] > 0.9, "{}", got[0]);
    }

    #[test]
    fn fedbuff_zero_staleness_is_fedavg() {
        let ups = vec![
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![3.0], 1.0, 0.0, 0),
        ];
        let a = FedBuff::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn fold_counts_and_streams_incrementally() {
        let mut fold = FedAvg.begin(2).unwrap();
        assert_eq!(fold.count(), 0);
        fold.accept(&[1.0, 0.0], &upd(1, vec![], 1.0, 0.0, 0).stats())
            .unwrap();
        fold.accept(&[0.0, 1.0], &upd(2, vec![], 3.0, 0.0, 0).stats())
            .unwrap();
        assert_eq!(fold.count(), 2);
        let m = fold.finish().unwrap();
        assert!((m[0] - 0.25).abs() < 1e-6);
        assert!((m[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn registry_lookup() {
        for name in ["fedavg", "fedprox", "dga", "fedbuff"] {
            assert_eq!(by_name(name, 0.1).unwrap().name(), name);
        }
        assert!(by_name("magic", 0.0).is_err());
    }

    #[test]
    fn errors_on_empty_or_mismatched() {
        assert!(FedAvg.aggregate(&[]).is_err());
        assert!(FedAvg
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.0, 0),
                upd(2, vec![1.0, 2.0], 1.0, 0.0, 0),
            ])
            .is_err());
        assert!(FedAvg.begin(1).unwrap().finish().is_err());
        assert!(Dga { temp: 0.0 }.begin(1).is_err());
    }
}
