//! Aggregation strategies ("user-defined logic" of the Master Aggregator,
//! §3.1.3): FedAvg, FedProx, DGA, and the buffered-async (Papaya/FedBuff)
//! rule used by asynchronous tasks (§4.3, §5.1).
//!
//! The paper uploads the aggregation recipe as a script/executable; here
//! strategies are a trait with built-ins selected by name from the task
//! config — custom strategies implement [`Aggregator`].

use crate::error::{Error, Result};
use crate::model::DeltaAccumulator;

/// One client's contribution to an aggregation step.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub client_id: u64,
    /// Pseudo-gradient (local params − global params at round start).
    pub delta: Vec<f32>,
    /// Example-count weight (paper: FedAvg weighting).
    pub weight: f64,
    /// Mean local training loss (drives DGA weighting).
    pub loss: f64,
    /// Global versions elapsed since the client fetched its base model
    /// (0 for synchronous rounds; > 0 under async).
    pub staleness: u64,
}

/// An aggregation strategy: combine updates into one pseudo-gradient.
pub trait Aggregator: Send + Sync {
    fn name(&self) -> &'static str;
    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>>;
}

/// Weighted Federated Averaging (McMahan et al. 2017).
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        let dim = check_dims(updates)?;
        let mut acc = DeltaAccumulator::new(dim);
        for u in updates {
            acc.add(&u.delta, u.weight)?;
        }
        acc.mean()
    }
}

/// FedProx (Li et al. 2018). Server-side combination is FedAvg; the
/// proximal μ‖θ−θ_g‖² term acts client-side and is carried to devices via
/// `TrainParams::prox_mu` (baked into the L2 train artifact).
pub struct FedProx {
    pub mu: f32,
}

impl Aggregator for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        FedAvg.aggregate(updates)
    }
}

/// Dynamic Gradient Aggregation (Dimitriadis et al. 2021): reweight
/// updates by training-loss quality — lower-loss clients count more,
/// via a softmax over −loss with temperature `temp`.
pub struct Dga {
    pub temp: f64,
}

impl Default for Dga {
    fn default() -> Self {
        Dga { temp: 1.0 }
    }
}

impl Aggregator for Dga {
    fn name(&self) -> &'static str {
        "dga"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        let dim = check_dims(updates)?;
        if !(self.temp > 0.0) {
            return Err(Error::Other("dga temperature must be > 0".into()));
        }
        let min_loss = updates
            .iter()
            .map(|u| u.loss)
            .fold(f64::INFINITY, f64::min);
        let mut acc = DeltaAccumulator::new(dim);
        for u in updates {
            let quality = (-(u.loss - min_loss) / self.temp).exp();
            acc.add(&u.delta, (u.weight * quality).max(1e-12))?;
        }
        acc.mean()
    }
}

/// Buffered asynchronous aggregation (Papaya / FedBuff): combine a buffer
/// of K updates with staleness discount `1/(1+s)^alpha`.
pub struct FedBuff {
    pub staleness_alpha: f64,
}

impl Default for FedBuff {
    fn default() -> Self {
        FedBuff {
            staleness_alpha: 0.5,
        }
    }
}

impl Aggregator for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Result<Vec<f32>> {
        let dim = check_dims(updates)?;
        let mut acc = DeltaAccumulator::new(dim);
        for u in updates {
            let discount = 1.0 / (1.0 + u.staleness as f64).powf(self.staleness_alpha);
            acc.add(&u.delta, u.weight * discount)?;
        }
        acc.mean()
    }
}

/// Look up a built-in strategy by config name.
pub fn by_name(name: &str, prox_mu: f32) -> Result<Box<dyn Aggregator>> {
    Ok(match name {
        "fedavg" => Box::new(FedAvg),
        "fedprox" => Box::new(FedProx { mu: prox_mu }),
        "dga" => Box::new(Dga::default()),
        "fedbuff" => Box::new(FedBuff::default()),
        other => {
            return Err(Error::Config(format!(
                "unknown aggregation strategy {other:?} \
                 (expected fedavg|fedprox|dga|fedbuff)"
            )))
        }
    })
}

fn check_dims(updates: &[ClientUpdate]) -> Result<usize> {
    let first = updates
        .first()
        .ok_or_else(|| Error::Other("no updates to aggregate".into()))?;
    let dim = first.delta.len();
    for u in updates {
        if u.delta.len() != dim {
            return Err(Error::Model(format!(
                "update dim mismatch: client {} has {} want {dim}",
                u.client_id,
                u.delta.len()
            )));
        }
    }
    Ok(dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: u64, delta: Vec<f32>, weight: f64, loss: f64, staleness: u64) -> ClientUpdate {
        ClientUpdate {
            client_id: id,
            delta,
            weight,
            loss,
            staleness,
        }
    }

    #[test]
    fn fedavg_weighted_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![1.0, 0.0], 1.0, 0.5, 0),
                upd(2, vec![0.0, 2.0], 3.0, 0.5, 0),
            ])
            .unwrap();
        assert!((got[0] - 0.25).abs() < 1e-6);
        assert!((got[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_equal_weights_is_plain_mean() {
        let got = FedAvg
            .aggregate(&[
                upd(1, vec![2.0], 5.0, 0.0, 0),
                upd(2, vec![4.0], 5.0, 0.0, 0),
            ])
            .unwrap();
        assert!((got[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn fedprox_server_side_matches_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, -1.0], 2.0, 0.3, 0),
            upd(2, vec![3.0, 5.0], 1.0, 0.9, 0),
        ];
        assert_eq!(
            FedProx { mu: 0.1 }.aggregate(&ups).unwrap(),
            FedAvg.aggregate(&ups).unwrap()
        );
    }

    #[test]
    fn dga_prefers_low_loss() {
        // Two clients, equal weights, very different losses: result must
        // lean strongly towards the low-loss client's delta.
        let got = Dga { temp: 0.1 }
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.1, 0),
                upd(2, vec![-1.0], 1.0, 5.0, 0),
            ])
            .unwrap();
        assert!(got[0] > 0.99, "{}", got[0]);
    }

    #[test]
    fn dga_equal_losses_reduces_to_fedavg() {
        let ups = vec![
            upd(1, vec![1.0, 2.0], 2.0, 0.7, 0),
            upd(2, vec![-1.0, 0.0], 1.0, 0.7, 0),
        ];
        let a = Dga::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn fedbuff_discounts_stale() {
        // Fresh vs very stale update with opposite directions: fresh wins.
        let got = FedBuff {
            staleness_alpha: 1.0,
        }
        .aggregate(&[
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![-1.0], 1.0, 0.0, 99),
        ])
        .unwrap();
        assert!(got[0] > 0.9, "{}", got[0]);
    }

    #[test]
    fn fedbuff_zero_staleness_is_fedavg() {
        let ups = vec![
            upd(1, vec![1.0], 1.0, 0.0, 0),
            upd(2, vec![3.0], 1.0, 0.0, 0),
        ];
        let a = FedBuff::default().aggregate(&ups).unwrap();
        let b = FedAvg.aggregate(&ups).unwrap();
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn registry_lookup() {
        for name in ["fedavg", "fedprox", "dga", "fedbuff"] {
            assert_eq!(by_name(name, 0.1).unwrap().name(), name);
        }
        assert!(by_name("magic", 0.0).is_err());
    }

    #[test]
    fn errors_on_empty_or_mismatched() {
        assert!(FedAvg.aggregate(&[]).is_err());
        assert!(FedAvg
            .aggregate(&[
                upd(1, vec![1.0], 1.0, 0.0, 0),
                upd(2, vec![1.0, 2.0], 1.0, 0.0, 0),
            ])
            .is_err());
    }
}
