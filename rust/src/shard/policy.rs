//! Client-id-sharded admission policy: N independent [`PolicyEngine`]s
//! behind the [`ShardRouter`] seam.
//!
//! A routed request takes at most two shard locks, each briefly and in
//! a fixed order: the *client* half (reputation floor + token bucket)
//! on the principal's home shard, then — for `PollTask` discovery
//! only — the *tenant* half (quota window) on the app name's home
//! shard. Uploads and heartbeats therefore contend only with clients
//! that hash to the same shard, never with the whole fleet. With one
//! shard both halves land on the same engine in the same order as the
//! pre-shard `PolicyEngine::admit`, so N=1 behavior is unchanged.

use crate::config::PolicyConfig;
use crate::error::Result;
use crate::proto::{rpc, Msg};
use crate::services::policy::PolicyEngine;
use crate::services::router::RequestCtx;

use super::ShardRouter;

/// N policy engines keyed by stable hash: client state by client id,
/// tenant quota windows by app name. The method surface mirrors
/// [`PolicyEngine`] so server call sites are shard-count agnostic.
pub struct ShardedPolicy {
    router: ShardRouter,
    engines: Vec<PolicyEngine>,
}

impl ShardedPolicy {
    /// Single-shard constructor: today's engine, verbatim.
    pub fn new(cfg: PolicyConfig) -> ShardedPolicy {
        ShardedPolicy::with_shards(cfg, 1)
    }

    pub fn with_shards(cfg: PolicyConfig, shards: usize) -> ShardedPolicy {
        let router = ShardRouter::new(shards);
        ShardedPolicy {
            router,
            engines: (0..router.shards()).map(|_| PolicyEngine::new(cfg)).collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    fn client_engine(&self, client_id: u64) -> &PolicyEngine {
        &self.engines[self.router.client_shard(client_id)]
    }

    /// The admission decision for one routed request: client gate on
    /// the principal's home shard, then tenant quota on the app name's
    /// home shard. Same halves, same order as the single engine.
    pub fn admit(&self, msg: &Msg, ctx: &RequestCtx) -> Result<()> {
        if let Some(id) = ctx.principal.or_else(|| rpc::client_id_of(msg)) {
            self.client_engine(id).admit_principal(id, ctx.now_ms)?;
        }
        if let Msg::PollTask { app_name, .. } = msg {
            self.engines[self.router.tenant_shard(app_name)].admit_tenant(msg, ctx.now_ms)?;
        }
        Ok(())
    }

    /// The client half alone — the poll-gate primitive the scale
    /// scenarios hammer (one shard lock, no message needed).
    pub fn admit_principal(&self, client_id: u64, now_ms: u64) -> Result<()> {
        self.client_engine(client_id).admit_principal(client_id, now_ms)
    }

    /// Swap the active configuration on every shard (validated once).
    pub fn set_config(&self, cfg: PolicyConfig) -> Result<()> {
        cfg.validate()?;
        for e in &self.engines {
            e.set_config(cfg)?;
        }
        Ok(())
    }

    /// The active configuration (shards never diverge: `set_config`
    /// fans out to all of them).
    pub fn config(&self) -> PolicyConfig {
        self.engines[0].config()
    }

    /// Requests refused by policy since boot, summed across shards.
    pub fn rejections(&self) -> u64 {
        self.engines.iter().map(PolicyEngine::rejections).sum()
    }

    /// A client's current reputation, from its home shard.
    pub fn reputation_of(&self, client_id: u64) -> Option<f64> {
        self.client_engine(client_id).reputation_of(client_id)
    }

    /// Charge one offense against a client on its home shard.
    pub fn record_offense(&self, client_id: u64, now_ms: u64, what: &str) {
        self.client_engine(client_id).record_offense(client_id, now_ms, what);
    }

    /// Session-sweep feedback: each evicted client is penalized on its
    /// home shard (the batch arrives after every registry lock dropped,
    /// via the tick mailbox).
    pub fn record_evictions(&self, evicted: &[u64], now_ms: u64) {
        for &id in evicted {
            self.client_engine(id).record_offense(id, now_ms, "lease eviction");
        }
    }

    /// Sheds broken down by refusal reason, summed across shards.
    /// Lock-free (the per-engine counters are relaxed atomics).
    pub fn shed_counters(&self) -> Vec<(&'static str, u64)> {
        let mut merged: Vec<(&'static str, u64)> = Vec::new();
        for e in &self.engines {
            for (i, (name, v)) in e.shed_counters().into_iter().enumerate() {
                match merged.get_mut(i) {
                    Some(slot) => slot.1 += v,
                    None => merged.push((name, v)),
                }
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::services::router::ServiceKind;

    fn ctx(now_ms: u64, principal: Option<u64>) -> RequestCtx {
        RequestCtx {
            now_ms,
            service: ServiceKind::Task,
            method: "fetch_round",
            principal,
            trace_id: None,
        }
    }

    fn strict() -> PolicyConfig {
        PolicyConfig {
            enabled: true,
            bucket_capacity: 2.0,
            refill_per_sec: 1.0,
            tenant_quota: 3,
            quota_window_ms: 1_000,
            min_reputation: 0.5,
            reputation_penalty: 0.3,
            reputation_recovery_per_sec: 0.1,
        }
    }

    fn heartbeat(id: u64) -> Msg {
        Msg::Heartbeat { client_id: id }
    }

    fn poll(id: u64, app: &str) -> Msg {
        Msg::PollTask {
            client_id: id,
            app_name: app.into(),
            workflow_name: "w".into(),
        }
    }

    #[test]
    fn buckets_are_per_client_regardless_of_shard_count() {
        for shards in [1usize, 4] {
            let p = ShardedPolicy::with_shards(strict(), shards);
            p.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
            p.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
            let err = p.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap_err();
            assert!(err.to_string().contains("rate limit"), "{err}");
            // A different client (any shard) has its own bucket.
            p.admit(&heartbeat(2), &ctx(0, Some(2))).unwrap();
            assert_eq!(p.rejections(), 1, "shards={shards}");
        }
    }

    #[test]
    fn tenant_quota_is_global_per_app_across_client_shards() {
        let p = ShardedPolicy::with_shards(strict(), 8);
        // Distinct clients land on different shards, but the tenant
        // window lives on the app name's home shard: the fourth poll
        // overflows no matter who sends it.
        for id in 0..3 {
            p.admit(&poll(id, "mail"), &ctx(0, None)).unwrap();
        }
        let err = p.admit(&poll(3, "mail"), &ctx(0, None)).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        p.admit(&poll(4, "keyboard"), &ctx(0, None)).unwrap();
    }

    #[test]
    fn evictions_and_offenses_route_to_the_home_shard() {
        let p = ShardedPolicy::with_shards(strict(), 4);
        p.record_evictions(&[8, 9], 0);
        assert!((p.reputation_of(8).unwrap() - 0.7).abs() < 1e-9);
        assert!((p.reputation_of(9).unwrap() - 0.7).abs() < 1e-9);
        assert_eq!(p.reputation_of(10), None);
        p.record_offense(8, 0, "test");
        let err = p.admit(&heartbeat(8), &ctx(0, Some(8))).unwrap_err();
        assert!(err.to_string().contains("reputation"), "{err}");
    }

    #[test]
    fn shed_counters_sum_across_shards() {
        let p = ShardedPolicy::with_shards(strict(), 4);
        // Drain two different clients' buckets (likely different shards).
        for id in [1u64, 2] {
            p.admit(&heartbeat(id), &ctx(0, Some(id))).unwrap();
            p.admit(&heartbeat(id), &ctx(0, Some(id))).unwrap();
            assert!(p.admit(&heartbeat(id), &ctx(0, Some(id))).is_err());
        }
        let shed: std::collections::HashMap<&str, u64> =
            p.shed_counters().into_iter().collect();
        assert_eq!(shed["policy_shed_rate"], 2);
        assert_eq!(shed["policy_shed_reputation"], 0);
        assert_eq!(p.rejections(), 2);
    }

    #[test]
    fn config_fans_out_and_reads_back() {
        let p = ShardedPolicy::with_shards(PolicyConfig::default(), 4);
        assert!(!p.config().enabled);
        p.admit(&heartbeat(3), &ctx(0, Some(3))).unwrap();
        p.set_config(strict()).unwrap();
        assert!(p.config().enabled);
        // Every shard enforces the new config.
        for id in 0..8u64 {
            p.admit_principal(id, 0).unwrap();
            p.admit_principal(id, 0).unwrap();
            assert!(p.admit_principal(id, 0).is_err(), "client {id}");
        }
    }
}
