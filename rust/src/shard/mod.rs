//! Sharded data plane: one server, a million concurrent sessions.
//!
//! Every poll/upload/heartbeat used to funnel through three coarse
//! per-registry locks on the [`crate::services::FloridaServer`] — the
//! session registry, the policy engine, and the management engine map —
//! so the orchestrator saturated one core long before the NIC. This
//! module partitions that per-client mutable state across N worker
//! shards keyed by a stable hash:
//!
//! | state                           | shard key            | home                      |
//! |---------------------------------|----------------------|---------------------------|
//! | session leases + profiles       | client id            | [`ShardedSessions`]       |
//! | policy buckets + reputation     | client id            | [`ShardedPolicy`]         |
//! | tenant quota windows            | app name             | [`ShardedPolicy`]         |
//! | streaming upload partials       | client id             | [`ShardIngestPlane`]      |
//! | round engines (task residency)  | task id              | `ManagementService`       |
//!
//! Invariants:
//!
//! * **No global lock on the hot path.** A poll, upload or heartbeat
//!   touches exactly one shard's mutex (plus relaxed atomics for
//!   instruments). The florida-lint `global-lock-on-hot-path` rule
//!   pins this shape.
//! * **N=1 is bit-identical to the unsharded server.** With one shard
//!   every registry degenerates to exactly the pre-shard layout and
//!   every fold sees updates in the same order, so committed weights
//!   match bit-for-bit (pinned by `shard_determinism` tests).
//! * **Commit-time merge.** Uploads fold shard-locally into streaming
//!   [`crate::aggregation::PartialFold`] accumulators; the partials
//!   merge on the engine's home shard via the associative
//!   `export`/`absorb` seam from the aggregation tree. Robust
//!   strategies (trimmed_mean | median) and async tasks refuse the
//!   seam and ingest directly at the root, exactly as leaf aggregators
//!   do.
//! * **Evictions fan out through a mailbox.** Each shard's lease sweep
//!   posts its evicted ids to a [`Mailbox`] batch; engines are
//!   notified only after every shard lock is dropped — never while
//!   registry state is held (the `lock-across-send` shape).

pub mod ingest;
pub mod mailbox;
pub mod policy;
pub mod sessions;

pub use ingest::ShardIngestPlane;
pub use mailbox::Mailbox;
pub use policy::ShardedPolicy;
pub use sessions::ShardedSessions;

/// Upper bound on worker shards: past this, per-shard sweep overhead
/// dominates and the fan-out stops paying for itself.
pub const MAX_SHARDS: usize = 256;

/// Stable shard assignment: splitmix64 finalizer over the key, reduced
/// mod `shards`. Deterministic across processes and runs (no per-boot
/// seed), so a client's home shard never moves while N is fixed.
pub fn shard_of(key: u64, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut x = key.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    (x % shards as u64) as usize
}

/// FNV-1a over the bytes, for string-keyed state (tenant quota
/// windows). Stable across runs for the same reason as [`shard_of`].
pub fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// The shard-routing seam: owns the shard count and the key → shard
/// maps. Every sharded registry embeds one, so the partition rule
/// cannot drift between sessions, policy and ingest.
#[derive(Clone, Copy, Debug)]
pub struct ShardRouter {
    shards: usize,
}

impl ShardRouter {
    /// Clamps to `1..=MAX_SHARDS` — zero shards is not a topology.
    pub fn new(shards: usize) -> ShardRouter {
        ShardRouter {
            shards: shards.clamp(1, MAX_SHARDS),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Home shard for per-client state (sessions, buckets, uploads).
    pub fn client_shard(&self, client_id: u64) -> usize {
        shard_of(client_id, self.shards)
    }

    /// Home shard for a round engine (task residency).
    pub fn task_shard(&self, task_id: u64) -> usize {
        shard_of(task_id, self.shards)
    }

    /// Home shard for a tenant's quota window.
    pub fn tenant_shard(&self, app_name: &str) -> usize {
        shard_of(hash_str(app_name), self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_maps_everything_to_zero() {
        for key in [0u64, 1, 42, u64::MAX] {
            assert_eq!(shard_of(key, 1), 0);
            assert_eq!(shard_of(key, 0), 0, "degenerate count clamps to one shard");
        }
    }

    #[test]
    fn assignment_is_stable_and_in_range() {
        for shards in [2usize, 4, 8, 256] {
            for key in 0..1000u64 {
                let s = shard_of(key, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(key, shards), "same key, same shard");
            }
        }
    }

    #[test]
    fn distribution_is_roughly_uniform() {
        let shards = 8;
        let n = 64_000u64;
        let mut counts = vec![0usize; shards];
        for key in 0..n {
            counts[shard_of(key, shards)] += 1;
        }
        let expect = n as usize / shards;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > expect / 2 && c < expect * 2,
                "shard {i} holds {c} of {n} keys (expected ~{expect})"
            );
        }
    }

    #[test]
    fn router_clamps_and_routes_consistently() {
        let r = ShardRouter::new(0);
        assert_eq!(r.shards(), 1);
        let r = ShardRouter::new(100_000);
        assert_eq!(r.shards(), MAX_SHARDS);
        let r = ShardRouter::new(4);
        assert_eq!(r.client_shard(77), shard_of(77, 4));
        assert_eq!(r.task_shard(3), shard_of(3, 4));
        assert_eq!(r.tenant_shard("mail"), shard_of(hash_str("mail"), 4));
        // String hashing is content-addressed, not pointer-addressed.
        assert_eq!(r.tenant_shard("mail"), r.tenant_shard(&String::from("mail")));
        assert_ne!(hash_str("mail"), hash_str("keyboard"));
    }
}
