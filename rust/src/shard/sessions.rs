//! Client-id-sharded session registry: N independent
//! [`SessionRegistry`] slices behind the [`ShardRouter`] seam.
//!
//! A poll/heartbeat touches exactly one slice's mutex — the slice its
//! client id hashes to — so lease renewals stop convoying on one
//! registry lock at fleet scale. With one shard this *is* the old
//! registry (same single lock, same token sequence, same sweep
//! output), which is what pins the N=1 bit-identity invariant.

use crate::error::Result;
use crate::proto::{DeviceProfile, LoadHints};
use crate::services::sessions::{Session, SessionRegistry};

use super::ShardRouter;

/// N session-registry slices keyed by stable client-id hash. The
/// method surface mirrors [`SessionRegistry`] so server and router
/// call sites are agnostic to the shard count.
pub struct ShardedSessions {
    router: ShardRouter,
    slices: Vec<SessionRegistry>,
}

impl ShardedSessions {
    /// Single-shard constructor: today's server, verbatim.
    pub fn new(lease_ms: u64) -> ShardedSessions {
        ShardedSessions::with_shards(lease_ms, 1)
    }

    pub fn with_shards(lease_ms: u64, shards: usize) -> ShardedSessions {
        let router = ShardRouter::new(shards);
        ShardedSessions {
            router,
            slices: (0..router.shards())
                .map(|_| SessionRegistry::new(lease_ms))
                .collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.slices.len()
    }

    fn slice_of(&self, client_id: u64) -> &SessionRegistry {
        &self.slices[self.router.client_shard(client_id)]
    }

    /// Lease every slice grants (slices never diverge: `set_lease_ms`
    /// fans out to all of them).
    pub fn lease_ms(&self) -> u64 {
        self.slices[0].lease_ms()
    }

    pub fn set_lease_ms(&self, lease_ms: u64) {
        for s in &self.slices {
            s.set_lease_ms(lease_ms);
        }
    }

    /// Open (or replace) the client's session on its home shard.
    /// Returns `(token, lease_ms)`.
    pub fn open(
        &self,
        client_id: u64,
        profile: DeviceProfile,
        proto: u32,
        now_ms: u64,
    ) -> (u64, u64) {
        self.slice_of(client_id).open(client_id, profile, proto, now_ms)
    }

    /// Renew the lease; the token must match the live session.
    pub fn renew(&self, client_id: u64, token: u64, hints: LoadHints, now_ms: u64) -> Result<u64> {
        self.slice_of(client_id).renew(client_id, token, hints, now_ms)
    }

    /// v1 compatibility: renew/open the client's *implicit* session.
    pub fn touch_v1(&self, client_id: u64, now_ms: u64) {
        self.slice_of(client_id).touch_v1(client_id, now_ms)
    }

    /// Release a session early; `false` on a stale token.
    pub fn close(&self, client_id: u64, token: u64) -> bool {
        self.slice_of(client_id).close(client_id, token)
    }

    /// Evict every expired lease across all shards; returns the merged
    /// evicted ids, globally sorted — byte-identical to the unsharded
    /// sweep over the same fleet. Each slice's lock is taken and
    /// dropped in turn; nothing is held across slices.
    pub fn sweep(&self, now_ms: u64) -> Vec<u64> {
        let mut evicted = Vec::new();
        for (_, batch) in self.sweep_shards(now_ms) {
            evicted.extend(batch);
        }
        evicted.sort_unstable();
        evicted
    }

    /// Per-shard sweep batches `(shard, evicted ids)` for callers that
    /// fan out through a [`super::Mailbox`] (the server tick). Empty
    /// shards are omitted; ids within a batch are sorted.
    pub fn sweep_shards(&self, now_ms: u64) -> Vec<(usize, Vec<u64>)> {
        self.slices
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                let batch = s.sweep(now_ms);
                (!batch.is_empty()).then_some((i, batch))
            })
            .collect()
    }

    pub fn get(&self, client_id: u64) -> Option<Session> {
        self.slice_of(client_id).get(client_id)
    }

    pub fn profile_of(&self, client_id: u64) -> Option<DeviceProfile> {
        self.slice_of(client_id).profile_of(client_id)
    }

    /// Live sessions across every shard (O(shards) lock acquisitions —
    /// an observability read, not a hot-path one).
    pub fn live_count(&self) -> usize {
        self.slices.iter().map(SessionRegistry::live_count).sum()
    }

    /// Live sessions on one shard (per-shard gauge export).
    pub fn live_count_of(&self, shard: usize) -> usize {
        self.slices[shard].live_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::PROTO_V2;
    use crate::shard::shard_of;

    #[test]
    fn routes_clients_to_their_home_shard_only() {
        let reg = ShardedSessions::with_shards(1000, 4);
        for id in 0..64u64 {
            reg.open(id, DeviceProfile::default(), PROTO_V2, 0);
        }
        assert_eq!(reg.live_count(), 64);
        let per_shard: usize = (0..4).map(|s| reg.live_count_of(s)).sum();
        assert_eq!(per_shard, 64);
        for id in 0..64u64 {
            let home = shard_of(id, 4);
            assert_eq!(reg.live_count_of(home), {
                (0..64u64).filter(|&c| shard_of(c, 4) == home).count()
            });
            assert!(reg.get(id).is_some());
        }
    }

    #[test]
    fn sweep_merges_sorted_across_shards() {
        let reg = ShardedSessions::with_shards(100, 4);
        for id in [9u64, 2, 5, 31, 17] {
            reg.open(id, DeviceProfile::default(), PROTO_V2, 0);
        }
        assert_eq!(reg.sweep(100), vec![2, 5, 9, 17, 31]);
        assert_eq!(reg.live_count(), 0);
    }

    #[test]
    fn sweep_shards_batches_per_home_shard() {
        let reg = ShardedSessions::with_shards(100, 4);
        for id in 0..32u64 {
            reg.open(id, DeviceProfile::default(), PROTO_V2, 0);
        }
        let batches = reg.sweep_shards(100);
        let mut all: Vec<u64> = Vec::new();
        for (shard, batch) in &batches {
            for id in batch {
                assert_eq!(shard_of(*id, 4), *shard, "id {id} in a foreign batch");
                all.push(*id);
            }
            let mut sorted = batch.clone();
            sorted.sort_unstable();
            assert_eq!(&sorted, batch, "per-shard batches are sorted");
        }
        all.sort_unstable();
        assert_eq!(all, (0..32u64).collect::<Vec<_>>());
    }

    #[test]
    fn single_shard_matches_flat_registry_token_for_token() {
        let flat = SessionRegistry::new(500);
        let sharded = ShardedSessions::new(500);
        for id in [3u64, 11, 42] {
            let (t_flat, l_flat) = flat.open(id, DeviceProfile::default(), PROTO_V2, 0);
            let (t_shard, l_shard) = sharded.open(id, DeviceProfile::default(), PROTO_V2, 0);
            assert_eq!(t_flat, t_shard, "token sequence must match at N=1");
            assert_eq!(l_flat, l_shard);
        }
        assert_eq!(flat.sweep(500), sharded.sweep(500));
    }

    #[test]
    fn lease_config_fans_out_to_every_shard() {
        let reg = ShardedSessions::with_shards(1000, 8);
        reg.set_lease_ms(250);
        assert_eq!(reg.lease_ms(), 250);
        for id in 0..16u64 {
            let (_, lease) = reg.open(id, DeviceProfile::default(), PROTO_V2, 0);
            assert_eq!(lease, 250, "client {id} granted a stale lease");
        }
        assert_eq!(reg.sweep(249).len(), 0);
        assert_eq!(reg.sweep(250).len(), 16);
    }

    #[test]
    fn renew_and_close_respect_tokens_across_shards() {
        let reg = ShardedSessions::with_shards(1000, 4);
        let (token, _) = reg.open(7, DeviceProfile::default(), PROTO_V2, 0);
        assert!(reg.renew(7, token, LoadHints::default(), 10).is_ok());
        assert!(reg.renew(7, token + 1, LoadHints::default(), 10).is_err());
        assert!(!reg.close(7, token + 1));
        assert!(reg.close(7, token));
        assert_eq!(reg.live_count(), 0);
    }
}
