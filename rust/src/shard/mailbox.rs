//! Batch mailbox: the eviction fan-out seam between per-shard sweeps
//! and the engine layer.
//!
//! The pre-shard `tick()` fanned lease evictions out to every round
//! engine *while the session registry lock was held* — the exact
//! `lock-across-send` shape florida-lint exists for, and a global
//! convoy once sweeps went per-shard. The mailbox inverts it: each
//! shard's sweep posts its evicted ids here (brief queue lock, nothing
//! else held), and the caller drains one merged batch *after* every
//! registry lock is dropped, then notifies engines.

use std::sync::Mutex;

/// A many-producer batch queue. Locks are held only around the queue
/// push/swap itself — never across downstream calls.
pub struct Mailbox<T> {
    queue: Mutex<Vec<T>>,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Mailbox::new()
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Mailbox<T> {
        Mailbox {
            queue: Mutex::new(Vec::new()),
        }
    }

    /// Lock the queue, recovering from poisoning: both mutations here
    /// are single-step vector ops, so a guard abandoned by a panicking
    /// poster still holds a structurally intact queue — dropping every
    /// later eviction batch on the floor would be strictly worse.
    fn locked(&self) -> std::sync::MutexGuard<'_, Vec<T>> {
        self.queue.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Post one item.
    pub fn post(&self, item: T) {
        self.locked().push(item);
    }

    /// Post a whole batch (one lock acquisition, preserving order).
    pub fn post_batch(&self, batch: impl IntoIterator<Item = T>) {
        self.locked().extend(batch);
    }

    /// Take everything posted so far, in posting order.
    pub fn drain(&self) -> Vec<T> {
        std::mem::take(&mut *self.locked())
    }

    pub fn len(&self) -> usize {
        self.locked().len()
    }

    pub fn is_empty(&self) -> bool {
        self.locked().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn post_and_drain_preserve_order() {
        let m = Mailbox::new();
        assert!(m.is_empty());
        m.post(1u64);
        m.post_batch([2, 3]);
        assert_eq!(m.len(), 3);
        assert_eq!(m.drain(), vec![1, 2, 3]);
        assert!(m.is_empty());
        assert!(m.drain().is_empty(), "drain is destructive");
    }

    #[test]
    fn concurrent_posts_all_arrive() {
        let m = Arc::new(Mailbox::new());
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        m.post(t * 1000 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = m.drain();
        got.sort_unstable();
        assert_eq!(got.len(), 400);
        got.dedup();
        assert_eq!(got.len(), 400, "no item lost or duplicated");
    }
}
