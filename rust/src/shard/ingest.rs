//! Shard-local upload ingest: streaming partial accumulators that fold
//! on the uploader's home shard and merge at commit time.
//!
//! The unsharded upload path funnels every device delta through the
//! round engine's single fold — one mutex, one O(dim) accumulate, per
//! upload, all serialized. This plane reuses the aggregation tree's
//! leaf machinery as *in-process lanes*: each shard owns a
//! [`LeafAggregator`] whose slice is the subset of the cohort that
//! hashes to it, uploads fold behind that lane's mutex only, and at
//! commit each lane exports one `ForwardPartial` that the engine
//! absorbs through the associative `export`/`absorb` seam.
//!
//! N=1 bit-identity: with one lane every upload folds into a single
//! accumulator in arrival order — the identical op sequence the flat
//! engine fold would run — and the root's absorb of that single partial
//! is bitwise addition onto a zeroed fold. So one-shard commits match
//! the unsharded server bit-for-bit (pinned by `shard_determinism`).
//!
//! Composition limits are inherited from the tree, not re-decided
//! here: robust strategies (trimmed_mean | median), async (fedbuff)
//! tasks and secure aggregation refuse the partial seam at
//! `begin_round`/`accept_partial`, so those tasks simply never get a
//! sharded ingest plane — their uploads keep going to the root.

use std::sync::Mutex;

use crate::aggtree::{LeafAggregator, LeafConfig};
use crate::error::{Error, Result};
use crate::proto::rpc;
use crate::services::management::ManagementService;

use super::ShardRouter;

/// Leaf ids for in-process shard lanes live far above any configured
/// external leaf fleet, so journal attribution stays unambiguous.
const LANE_LEAF_ID_BASE: u64 = 1 << 48;

/// Per-task sharded ingest: one fold lane per shard, keyed by the
/// uploader's client-id hash. Lanes lock independently; nothing global
/// sits on the upload path.
pub struct ShardIngestPlane {
    task_id: u64,
    router: ShardRouter,
    aggregator: String,
    prox_mu: f32,
    lanes: Vec<Mutex<Option<LeafAggregator>>>,
}

impl ShardIngestPlane {
    pub fn new(task_id: u64, aggregator: &str, prox_mu: f32, shards: usize) -> ShardIngestPlane {
        let router = ShardRouter::new(shards);
        ShardIngestPlane {
            task_id,
            router,
            aggregator: aggregator.to_string(),
            prox_mu,
            lanes: (0..router.shards()).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn task_id(&self) -> u64 {
        self.task_id
    }

    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    /// Lock one lane, recovering from poisoning: every mutation behind
    /// this lock is a leaf-aggregator call that leaves the leaf valid
    /// even on error return, so an abandoned guard holds usable state.
    fn lane(&self, shard: usize) -> std::sync::MutexGuard<'_, Option<LeafAggregator>> {
        self.lanes[shard].lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Open lanes for the engine's current round: fetch the full cohort
    /// through the leaf-assignment seam (index 0 of 1 — the whole
    /// cohort), then partition it across lanes by client-id hash. Tasks
    /// that refuse leaf assignments (robust, async, secagg, not
    /// Running) surface that refusal as `Err` here.
    pub fn begin_round(&self, mgmt: &ManagementService, dim: usize) -> Result<usize> {
        let a = mgmt.leaf_assignment(self.task_id, 0, 1)?;
        if !a.accepted {
            return Err(Error::Task(format!(
                "task {} refuses sharded ingest: {}",
                self.task_id, a.reason
            )));
        }
        self.begin_local(a.round, a.base_version, &a.members, dim)
    }

    /// Open lanes for a known round/cohort without a management seam —
    /// the standalone form the scale simulator drives. Returns the
    /// number of non-empty lanes opened.
    pub fn begin_local(
        &self,
        round: u64,
        base_version: u64,
        members: &[u64],
        dim: usize,
    ) -> Result<usize> {
        let shards = self.lanes.len();
        let mut slices: Vec<Vec<u64>> = vec![Vec::new(); shards];
        for &id in members {
            slices[self.router.client_shard(id)].push(id);
        }
        let mut opened = 0;
        for (shard, slice) in slices.into_iter().enumerate() {
            let mut lane = self.lane(shard);
            if slice.is_empty() {
                // No member hashes here this round: the lane must not
                // keep a stale round that would accept late uploads.
                *lane = None;
                continue;
            }
            let mut leaf = LeafAggregator::new(LeafConfig {
                leaf_id: LANE_LEAF_ID_BASE + shard as u64,
                leaf_index: shard as u32,
                leaf_count: shards as u32,
                aggregator: self.aggregator.clone(),
                prox_mu: self.prox_mu,
            });
            leaf.begin_round(
                &rpc::LeafAssignment {
                    accepted: true,
                    round,
                    base_version,
                    members: slice,
                    reason: String::new(),
                },
                dim,
            )?;
            *lane = Some(leaf);
            opened += 1;
        }
        Ok(opened)
    }

    /// Fold one upload on the uploader's home shard. Exactly one lane
    /// mutex is taken; refusals are structured `(false, reason)` like
    /// the root ingest so devices can retry or fall back.
    pub fn accept(
        &self,
        client_id: u64,
        round: u64,
        delta: &[f32],
        weight: f64,
        loss: f64,
    ) -> Result<(bool, String)> {
        let mut lane = self.lane(self.router.client_shard(client_id));
        match lane.as_mut() {
            Some(leaf) => leaf.accept(client_id, round, delta, weight, loss),
            None => Ok((false, "no round open on this shard".into())),
        }
    }

    /// Merge at commit: drain every lane in shard order, forward each
    /// non-empty partial through the engine's `accept_partial` seam,
    /// and return how many member updates the engine absorbed. Lanes
    /// are taken one at a time; no lane lock is held across the engine
    /// call (the engine has its own lock — holding both would be the
    /// `lock-across-send` shape).
    pub fn commit(&self, mgmt: &ManagementService, now_ms: u64) -> Result<u64> {
        let mut folded = 0u64;
        for shard in 0..self.lanes.len() {
            let leaf = self.lane(shard).take();
            let Some(mut leaf) = leaf else { continue };
            if !leaf.members().is_empty() && leaf.pending() == leaf.members().len() {
                continue; // nothing folded on this lane — nothing to forward
            }
            let req = leaf.forward_request(self.task_id)?;
            let (ok, _, reason) = mgmt.accept_partial(
                req.leaf_id,
                req.task_id,
                req.round,
                req.base_version,
                &req.members,
                req.sum,
                req.total_weight,
                req.count,
                req.loss_sum,
                req.min_loss,
                now_ms,
            )?;
            if !ok {
                return Err(Error::Server(format!(
                    "shard {shard} partial refused: {reason}"
                )));
            }
            folded += req.count;
        }
        Ok(folded)
    }

    /// Export every lane's partial without a management seam — the
    /// standalone form for simulators/tests that merge into their own
    /// root fold. Drains the lanes (commit semantics).
    pub fn export_partials(&self) -> Result<Vec<rpc::ForwardPartial>> {
        let mut parts = Vec::new();
        for shard in 0..self.lanes.len() {
            let leaf = self.lane(shard).take();
            let Some(mut leaf) = leaf else { continue };
            if !leaf.members().is_empty() && leaf.pending() == leaf.members().len() {
                continue;
            }
            parts.push(leaf.forward_request(self.task_id)?);
        }
        Ok(parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::{self, PartialFold, UpdateStats};

    fn dyadic(i: u64, d: usize) -> Vec<f32> {
        // Multiples of 2^-10: exactly representable, so f64 sums are
        // order-independent and cross-shard comparisons can be bitwise.
        (0..d)
            .map(|j| ((i * 7 + j as u64 * 3) % 2048) as f32 / 1024.0 - 1.0)
            .collect()
    }

    #[test]
    fn single_lane_matches_flat_fold_bitwise() {
        let dim = 4;
        let members: Vec<u64> = (1..=16).collect();
        let plane = ShardIngestPlane::new(9, "fedavg", 0.0, 1);
        assert_eq!(plane.begin_local(0, 0, &members, dim).unwrap(), 1);

        let agg = aggregation::by_name("fedavg", 0.0).unwrap();
        let mut flat = agg.begin(dim).unwrap();
        for &id in &members {
            let delta = dyadic(id, dim);
            let (ok, why) = plane.accept(id, 0, &delta, 1.0, 0.25).unwrap();
            assert!(ok, "{why}");
            flat.accept(
                &delta,
                &UpdateStats {
                    client_id: id,
                    weight: 1.0,
                    loss: 0.25,
                    staleness: 0,
                },
            )
            .unwrap();
        }
        let parts = plane.export_partials().unwrap();
        assert_eq!(parts.len(), 1);
        let mut root = agg.begin(dim).unwrap();
        root.absorb(&PartialFold {
            sum: parts[0].sum.clone(),
            total_weight: parts[0].total_weight,
            count: parts[0].count as usize,
            min_loss: parts[0].min_loss,
        })
        .unwrap();
        let got = root.finish().unwrap();
        let want = flat.finish().unwrap();
        assert_eq!(got, want, "one lane must be the flat fold, bit for bit");
    }

    #[test]
    fn lanes_partition_members_and_refuse_strangers() {
        let plane = ShardIngestPlane::new(9, "fedavg", 0.0, 4);
        let members: Vec<u64> = (1..=32).collect();
        plane.begin_local(3, 0, &members, 2).unwrap();
        for &id in &members {
            let (ok, why) = plane.accept(id, 3, &[0.5, -0.5], 1.0, 0.1).unwrap();
            assert!(ok, "member {id}: {why}");
        }
        // Not in the cohort: its home lane refuses it.
        let (ok, why) = plane.accept(999, 3, &[0.5, -0.5], 1.0, 0.1).unwrap();
        assert!(!ok, "{why}");
        // Stale round.
        let (ok, why) = plane.accept(1, 2, &[0.5, -0.5], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("stale"), "{why}");
        // Duplicate.
        let (ok, why) = plane.accept(1, 3, &[0.5, -0.5], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("duplicate"), "{why}");
        let parts = plane.export_partials().unwrap();
        let covered: u64 = parts.iter().map(|p| p.count).sum();
        assert_eq!(covered, 32, "every member folded on exactly one lane");
    }

    #[test]
    fn sharded_partials_match_flat_fold_on_dyadic_inputs() {
        let dim = 3;
        let members: Vec<u64> = (1..=40).collect();
        let agg = aggregation::by_name("fedavg", 0.0).unwrap();
        let mut flat = agg.begin(dim).unwrap();
        for &id in &members {
            flat.accept(
                &dyadic(id, dim),
                &UpdateStats {
                    client_id: id,
                    weight: 1.0,
                    loss: 0.5,
                    staleness: 0,
                },
            )
            .unwrap();
        }
        let want = flat.finish().unwrap();

        for shards in [2usize, 4, 8] {
            let plane = ShardIngestPlane::new(9, "fedavg", 0.0, shards);
            plane.begin_local(0, 0, &members, dim).unwrap();
            for &id in &members {
                let (ok, why) = plane.accept(id, 0, &dyadic(id, dim), 1.0, 0.5).unwrap();
                assert!(ok, "{why}");
            }
            let mut root = agg.begin(dim).unwrap();
            for p in plane.export_partials().unwrap() {
                root.absorb(&PartialFold {
                    sum: p.sum.clone(),
                    total_weight: p.total_weight,
                    count: p.count as usize,
                    min_loss: p.min_loss,
                })
                .unwrap();
            }
            let got = root.finish().unwrap();
            assert_eq!(got, want, "{shards} shards: dyadic deltas must merge exactly");
        }
    }

    #[test]
    fn robust_strategy_refuses_the_plane() {
        let plane = ShardIngestPlane::new(9, "trimmed_mean", 0.0, 2);
        let err = plane.begin_local(0, 0, &[1, 2, 3], 2).unwrap_err();
        assert!(err.to_string().contains("root only"), "{err}");
    }

    #[test]
    fn reopening_clears_lanes_that_lost_their_members() {
        let plane = ShardIngestPlane::new(9, "fedavg", 0.0, 4);
        plane.begin_local(0, 0, &(1..=32).collect::<Vec<_>>(), 1).unwrap();
        // Next round's cohort is one client: every other lane must drop
        // its stale round instead of accepting round-0 stragglers.
        plane.begin_local(1, 1, &[5], 1).unwrap();
        let (ok, why) = plane.accept(6, 0, &[1.0], 1.0, 0.1).unwrap();
        assert!(!ok, "{why}");
        let (ok, why) = plane.accept(5, 1, &[1.0], 1.0, 0.1).unwrap();
        assert!(ok, "{why}");
        let parts = plane.export_partials().unwrap();
        assert_eq!(parts.iter().map(|p| p.count).sum::<u64>(), 1);
    }
}
