//! Hierarchical aggregation: the leaf side of the leaf/master tree.
//!
//! At cross-device scale a single master aggregator is the fan-in
//! bottleneck: every device upload lands on one ingest surface and one
//! streaming fold. This module multiplies the fan-in by putting a layer
//! of **leaf aggregators** between devices and the master:
//!
//! ```text
//!   devices ──► LeafAggregator 0 ─┐
//!   devices ──► LeafAggregator 1 ─┼─► ForwardPartial ─► master fold
//!   devices ──► LeafAggregator k ─┘      (O(dim) merge at the root)
//! ```
//!
//! Each leaf claims a deterministic slice of the open round's cohort
//! ([`rpc::LeafAssign`]), folds its members' uploads locally through the
//! exact same streaming [`AggregatorFold`] the master uses, and forwards
//! one O(dim) [`rpc::ForwardPartial`] frame. The master merges partials
//! via [`AggregatorFold::absorb`], so the tree result is the same fold —
//! *bit-identical* for plain-addition strategies over dyadic inputs —
//! and the per-upload cost at the root collapses from O(cohort · dim)
//! to O(leaves · dim).
//!
//! Composition rules enforced by the server seam (`RoundEngine`):
//! - **Secure aggregation** rounds refuse leaf assignments: masked sums
//!   must reach the root unmerged so mask cancellation and unmasking
//!   happen in one place.
//! - **Robust strategies** (trimmed_mean | median) refuse leaf
//!   assignments too: a trimmed mean/median is not a function of
//!   per-leaf sums, so a leaf could neither export its buffered fold
//!   through the linear [`rpc::ForwardPartial`] frame nor have it
//!   absorbed faithfully. Robust reduction happens at the root only —
//!   [`LeafAggregator::begin_round`] refuses the strategy locally, the
//!   engine's `leaf_slice`/`accept_partial` refuse it at the server,
//!   and a robust fold's own `export`/`absorb` fail loudly as the last
//!   line of defense.
//! - **DP noise** composes only at the root (the master's commit path);
//!   leaves never add noise, so the privacy accounting is unchanged.
//! - A leaf that dies mid-round simply never reports its members; the
//!   root's pacing deadline fails the round and the retry starts from a
//!   clean fold — no update can be double-counted.

use std::collections::BTreeSet;

use crate::aggregation::{self, AggregatorFold, UpdateStats};
use crate::client::FloridaClient;
use crate::error::{Error, Result};
use crate::proto::rpc;

/// Static identity + strategy of one leaf aggregator.
#[derive(Clone, Debug)]
pub struct LeafConfig {
    /// Infrastructure identity (not a device principal).
    pub leaf_id: u64,
    /// Which slice of the cohort this leaf owns.
    pub leaf_index: u32,
    /// Total leaves splitting the cohort this round.
    pub leaf_count: u32,
    /// Must match the task's aggregator so leaf folds and the master
    /// merge compose associatively (enforced numerically, not by name —
    /// a mismatched strategy shows up as a divergent model).
    pub aggregator: String,
    pub prox_mu: f32,
}

/// In-flight state for the round a leaf currently owns.
struct LeafRound {
    round: u64,
    base_version: u64,
    members: Vec<u64>,
    /// Same ids as `members`, set-shaped: membership checks on the
    /// upload path must not scan the slice (shard-ingest lanes carry
    /// fleet-scale slices, where a linear probe per upload is O(n²)).
    member_set: BTreeSet<u64>,
    reported: BTreeSet<u64>,
    fold: Box<dyn AggregatorFold>,
    loss_sum: f64,
}

/// One leaf of the aggregation tree: owns a cohort slice, folds member
/// uploads locally, forwards a single partial accumulator to the master.
pub struct LeafAggregator {
    cfg: LeafConfig,
    open: Option<LeafRound>,
}

impl LeafAggregator {
    pub fn new(cfg: LeafConfig) -> LeafAggregator {
        LeafAggregator { cfg, open: None }
    }

    pub fn leaf_id(&self) -> u64 {
        self.cfg.leaf_id
    }

    /// The round currently being folded, if any.
    pub fn round(&self) -> Option<u64> {
        self.open.as_ref().map(|r| r.round)
    }

    /// Members of the current slice (empty when no round is open).
    pub fn members(&self) -> &[u64] {
        self.open.as_ref().map(|r| r.members.as_slice()).unwrap_or(&[])
    }

    /// Members that have not reported yet (stragglers at deadline).
    pub fn pending(&self) -> usize {
        self.open
            .as_ref()
            .map(|r| r.members.len() - r.reported.len())
            .unwrap_or(0)
    }

    /// Every assigned member's update has been folded.
    pub fn complete(&self) -> bool {
        self.open
            .as_ref()
            .map(|r| r.reported.len() == r.members.len())
            .unwrap_or(false)
    }

    /// Open a round from a granted assignment. A refused assignment is
    /// an error here — callers inspect `accepted` first and back off.
    /// Re-opening replaces any stale previous round (the master already
    /// failed it, or this leaf missed the deadline).
    pub fn begin_round(&mut self, a: &rpc::LeafAssignment, dim: usize) -> Result<()> {
        if !a.accepted {
            return Err(Error::Task(format!("assignment refused: {}", a.reason)));
        }
        if a.members.is_empty() {
            return Err(Error::Task("assignment carries no members".into()));
        }
        if aggregation::is_robust(&self.cfg.aggregator) {
            // The engine refuses these assignments too; refusing locally
            // keeps a mis-configured fleet driver from buffering folds
            // it could never forward (robust export is inert by design).
            return Err(Error::Task(format!(
                "robust strategy {:?} reduces at the root only — leaves refuse",
                self.cfg.aggregator
            )));
        }
        let fold = aggregation::by_name(&self.cfg.aggregator, self.cfg.prox_mu)?.begin(dim)?;
        self.open = Some(LeafRound {
            round: a.round,
            base_version: a.base_version,
            members: a.members.clone(),
            member_set: a.members.iter().copied().collect(),
            reported: BTreeSet::new(),
            fold,
            loss_sum: 0.0,
        });
        Ok(())
    }

    /// Fold one member's upload. Structured refusals mirror the root's
    /// ingest: a rejected upload leaves the fold unchanged and the
    /// device free to retry (or go straight to the root).
    pub fn accept(
        &mut self,
        client_id: u64,
        round: u64,
        delta: &[f32],
        weight: f64,
        loss: f64,
    ) -> Result<(bool, String)> {
        let r = match &mut self.open {
            Some(r) => r,
            None => return Ok((false, "no round open at this leaf".into())),
        };
        if round != r.round {
            return Ok((false, format!("stale round {round} (now {})", r.round)));
        }
        if !r.member_set.contains(&client_id) {
            return Ok((false, format!("client {client_id} not in this leaf's slice")));
        }
        if r.reported.contains(&client_id) {
            return Ok((false, "duplicate upload".into()));
        }
        if !loss.is_finite() {
            return Ok((false, format!("bad loss {loss}")));
        }
        let accepted = r.fold.accept(
            delta,
            &UpdateStats {
                client_id,
                weight,
                loss,
                staleness: 0,
            },
        );
        if let Err(e) = accepted {
            return Ok((false, e.to_string()));
        }
        r.reported.insert(client_id);
        r.loss_sum += loss;
        Ok((true, String::new()))
    }

    /// Export the fold as one typed [`rpc::ForwardPartial`] request and
    /// close the leaf's round — forwarding is terminal: whatever the
    /// master answers, this leaf starts fresh from the next assignment.
    /// Only members actually folded ride along (stragglers are simply
    /// absent, and the root's pacing decides the round's fate).
    pub fn forward_request(&mut self, task_id: u64) -> Result<rpc::ForwardPartial> {
        let r = self
            .open
            .take()
            .ok_or_else(|| Error::Task("no round open at this leaf".into()))?;
        if r.reported.is_empty() {
            return Err(Error::Task("nothing folded — nothing to forward".into()));
        }
        let part = r.fold.export();
        Ok(rpc::ForwardPartial {
            leaf_id: self.cfg.leaf_id,
            task_id,
            round: r.round,
            base_version: r.base_version,
            members: r.reported.into_iter().collect(),
            sum: part.sum,
            total_weight: part.total_weight,
            count: part.count as u64,
            loss_sum: r.loss_sum,
            min_loss: part.min_loss,
        })
    }

    /// Claim this leaf's slice of `task_id`'s open round through the
    /// typed router. Returns the assignment verbatim — `accepted: false`
    /// is the back-off signal, not an error.
    pub fn claim(&self, client: &FloridaClient, task_id: u64) -> Result<rpc::LeafAssignment> {
        client.leaf_assign(
            self.cfg.leaf_id,
            task_id,
            self.cfg.leaf_index,
            self.cfg.leaf_count,
        )
    }

    /// Forward the folded partial to the master through the typed
    /// router. A rejected partial surfaces as `Err(Error::Server)`.
    pub fn forward(&mut self, client: &FloridaClient, task_id: u64) -> Result<rpc::LeafAck> {
        let req = self.forward_request(task_id)?;
        client.forward_partial(req)
    }
}

/// The engine's deterministic partition rule, exposed for callers that
/// split a cohort locally (tests, fleet drivers): position `i` of the
/// sorted cohort belongs to leaf `i % leaf_count`. Disjoint cover for
/// any `leaf_count ≥ 1`.
pub fn slice_of(cohort_sorted: &[u64], leaf_index: u32, leaf_count: u32) -> Vec<u64> {
    cohort_sorted
        .iter()
        .enumerate()
        .filter(|(i, _)| leaf_count != 0 && i % leaf_count as usize == leaf_index as usize)
        .map(|(_, &c)| c)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn assignment(round: u64, members: Vec<u64>) -> rpc::LeafAssignment {
        rpc::LeafAssignment {
            accepted: true,
            round,
            base_version: 0,
            members,
            reason: String::new(),
        }
    }

    fn leaf(aggregator: &str) -> LeafAggregator {
        LeafAggregator::new(LeafConfig {
            leaf_id: 100,
            leaf_index: 0,
            leaf_count: 2,
            aggregator: aggregator.into(),
            prox_mu: 0.0,
        })
    }

    #[test]
    fn slice_of_is_a_disjoint_cover() {
        let cohort: Vec<u64> = (10..23).collect();
        for leaf_count in 1..=5u32 {
            let mut seen = BTreeSet::new();
            for i in 0..leaf_count {
                for m in slice_of(&cohort, i, leaf_count) {
                    assert!(seen.insert(m), "member {m} in two slices");
                }
            }
            assert_eq!(seen.len(), cohort.len());
        }
    }

    #[test]
    fn leaf_validates_membership_rounds_and_duplicates() {
        let mut l = leaf("fedavg");
        // No round open yet.
        let (ok, why) = l.accept(3, 0, &[1.0], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("no round"), "{why}");
        l.begin_round(&assignment(2, vec![3, 5]), 1).unwrap();
        assert_eq!(l.round(), Some(2));
        assert_eq!(l.pending(), 2);
        // Not in the slice.
        let (ok, why) = l.accept(4, 2, &[1.0], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("not in this leaf"), "{why}");
        // Stale round.
        let (ok, why) = l.accept(3, 1, &[1.0], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("stale round"), "{why}");
        // Bad fold input leaves state unchanged, member free to retry.
        let (ok, _) = l.accept(3, 2, &[1.0, 2.0], 1.0, 0.1).unwrap();
        assert!(!ok, "dim mismatch must be refused");
        assert_eq!(l.pending(), 2);
        let (ok, why) = l.accept(3, 2, &[1.0], 1.0, 0.1).unwrap();
        assert!(ok, "{why}");
        // Duplicate.
        let (ok, why) = l.accept(3, 2, &[1.0], 1.0, 0.1).unwrap();
        assert!(!ok && why.contains("duplicate"), "{why}");
        assert!(!l.complete());
        let (ok, _) = l.accept(5, 2, &[1.0], 1.0, 0.1).unwrap();
        assert!(ok);
        assert!(l.complete());
    }

    #[test]
    fn forward_request_carries_only_folded_members() {
        let mut l = leaf("fedavg");
        // Nothing open, then nothing folded: both are errors.
        assert!(l.forward_request(1).is_err());
        l.begin_round(&assignment(0, vec![3, 5, 9]), 2).unwrap();
        assert!(l.forward_request(1).is_err());
        l.begin_round(&assignment(0, vec![3, 5, 9]), 2).unwrap();
        l.accept(5, 0, &[1.0, 1.0], 2.0, 0.5).unwrap();
        l.accept(3, 0, &[1.0, 1.0], 1.0, 0.3).unwrap();
        let req = l.forward_request(7).unwrap();
        assert_eq!(req.leaf_id, 100);
        assert_eq!(req.task_id, 7);
        assert_eq!(req.members, vec![3, 5], "straggler 9 must be absent");
        assert_eq!(req.count, 2);
        assert!((req.total_weight - 3.0).abs() < 1e-12);
        assert!((req.loss_sum - 0.8).abs() < 1e-12);
        // Forwarding closed the round.
        assert_eq!(l.round(), None);
        assert!(l.forward_request(7).is_err());
    }

    #[test]
    fn robust_strategies_refused_at_the_leaf() {
        for name in ["trimmed_mean", "median"] {
            let mut l = leaf(name);
            let err = l.begin_round(&assignment(0, vec![3, 5]), 2).unwrap_err();
            assert!(err.to_string().contains("root only"), "{err}");
            assert_eq!(l.round(), None, "{name}: refusal must not open a round");
        }
    }

    /// The satellite property test: for random cohorts, random updates,
    /// and random slice partitions, folding through leaves and absorbing
    /// at a master fold matches the flat single-fold reference — for
    /// every aggregation strategy, including the reweighting ones
    /// (fedbuff staleness discounts, dga loss softmax).
    #[test]
    fn prop_tree_fold_matches_flat_reference() {
        let mut rng = Rng::new(0xF10F1DA);
        for trial in 0..40 {
            for name in ["fedavg", "fedprox", "fedbuff", "dga"] {
                let agg = aggregation::by_name(name, 0.01).unwrap();
                let dim = 1 + (rng.next_u64() % 6) as usize;
                let n = 1 + (rng.next_u64() % 9) as usize;
                let leaf_count = 1 + (rng.next_u64() % 4) as u32;
                let updates: Vec<(u64, Vec<f32>, f64, f64)> = (0..n)
                    .map(|i| {
                        let delta: Vec<f32> =
                            (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                        let weight = 0.5 + rng.next_f64() * 4.0;
                        let loss = rng.next_f64() * 3.0;
                        (i as u64 + 1, delta, weight, loss)
                    })
                    .collect();

                // Flat reference: one fold sees every update.
                let mut flat = agg.begin(dim).unwrap();
                for (id, delta, weight, loss) in &updates {
                    flat.accept(
                        delta,
                        &UpdateStats {
                            client_id: *id,
                            weight: *weight,
                            loss: *loss,
                            staleness: 0,
                        },
                    )
                    .unwrap();
                }
                let want = flat.finish().unwrap();

                // Tree: leaves fold their slices, the master absorbs the
                // exported partials in a shuffled arrival order.
                let cohort: Vec<u64> = updates.iter().map(|u| u.0).collect();
                let mut master = agg.begin(dim).unwrap();
                let mut order: Vec<u32> = (0..leaf_count).collect();
                rng.shuffle(&mut order);
                for li in order {
                    let members = slice_of(&cohort, li, leaf_count);
                    if members.is_empty() {
                        continue;
                    }
                    let mut l = LeafAggregator::new(LeafConfig {
                        leaf_id: 200 + li as u64,
                        leaf_index: li,
                        leaf_count,
                        aggregator: name.into(),
                        prox_mu: 0.01,
                    });
                    l.begin_round(&assignment(0, members.clone()), dim).unwrap();
                    for (id, delta, weight, loss) in &updates {
                        if members.contains(id) {
                            let (ok, why) = l.accept(*id, 0, delta, *weight, *loss).unwrap();
                            assert!(ok, "{why}");
                        }
                    }
                    let req = l.forward_request(1).unwrap();
                    master
                        .absorb(&crate::aggregation::PartialFold {
                            sum: req.sum,
                            total_weight: req.total_weight,
                            count: req.count as usize,
                            min_loss: req.min_loss,
                        })
                        .unwrap();
                }
                assert_eq!(master.count(), n);
                let got = master.finish().unwrap();
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                        "{name} trial {trial}: tree {g} vs flat {w} (dim {dim}, n {n}, leaves {leaf_count})"
                    );
                }
            }
        }
    }
}
