//! The seven project-grounded lint rules.
//!
//! Each rule encodes a bug class this repo has actually shipped and
//! fixed by hand (see `docs/architecture.md` § "Static analysis &
//! enforced invariants"):
//!
//! * [`PanickingLock`] — `.lock().unwrap()` on a server path panics the
//!   connection thread when a mutex is poisoned (the PR 5 `RemoteApi`
//!   bug, server-side).
//! * [`U64AsJsonNumber`] — `u64` message fields must ride the JSON
//!   codec as strings; JSON numbers are f64 and corrupt above 2^53
//!   (the PR 5 session-token bug, generalized).
//! * [`WallClockInCore`] — `Instant::now`/`SystemTime::now` outside an
//!   explicit allowlist breaks manual-clock determinism (the seeded
//!   simulator and `Clock::Manual` seam).
//! * [`MsgCoverage`] — every `Msg` variant must be exercised by the
//!   binary round-trip corpus, every JSON-capable variant by the JSON
//!   corpus, and every request variant must have a typed pair in
//!   `proto/rpc.rs`.
//! * [`UncheckedWireLength`] — a wire-derived length must be bounds-
//!   checked before it sizes an allocation (hostile-frame defense).
//! * [`LockAcrossSend`] — a `MutexGuard` held across a transport
//!   `send`/`send_owned` serializes the data plane; the lock-discipline
//!   precondition for sharding it.
//! * [`GlobalLockOnHotPath`] — a Mutex acquired inside a
//!   poll/upload/heartbeat handler re-serializes what the shard plane
//!   partitioned; the hot path must route through `ShardRouter` and
//!   take only its home shard's lock.

use super::{Finding, SourceFile};
use crate::analysis::tokenizer::{TokKind, Token};
use std::collections::{BTreeSet, HashMap};

/// A lint rule over tokenized source files.
///
/// `check` receives the whole tree so cross-file rules (like
/// [`MsgCoverage`]) can correlate; per-file rules iterate the files
/// they [`applies_to`](Rule::applies_to).
pub trait Rule {
    /// Stable rule name — what `allow(<name>)` and the baseline use.
    fn name(&self) -> &'static str;
    /// One-line description for docs and `lint` output.
    fn description(&self) -> &'static str;
    /// File-path scoping (paths are repo-relative, forward slashes).
    fn applies_to(&self, path: &str) -> bool;
    /// Append findings for the tree.
    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>);
}

/// The shipped rule set.
pub fn default_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(PanickingLock),
        Box::new(U64AsJsonNumber),
        Box::new(WallClockInCore),
        Box::new(MsgCoverage),
        Box::new(UncheckedWireLength),
        Box::new(LockAcrossSend),
        Box::new(GlobalLockOnHotPath),
    ]
}

/// Server-side modules: a panic here takes down a connection thread or
/// the orchestrator, not just one device. `metrics/` and `obs/` are in
/// scope too — the telemetry export path runs on request threads, so a
/// poisoned instrument must degrade, never panic the server.
fn server_side(path: &str) -> bool {
    [
        "/services/",
        "/orchestrator/",
        "/transport/",
        "/storage/",
        "/aggtree/",
        "/metrics/",
        "/obs/",
        "/shard/",
    ]
    .iter()
    .any(|d| path.contains(d))
}

/// Index of the brace matching `code[open]` (which must be `{`).
fn close_of(code: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in code.iter().enumerate().skip(open) {
        if t.punct("{") {
            depth += 1;
        } else if t.punct("}") {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Token range (open-brace idx, close-brace idx) of the body of
/// `kw name { … }` — e.g. (`enum`, `Msg`) or (`fn`, `to_json`).
fn item_body(code: &[Token], kw: &str, name: &str) -> Option<(usize, usize)> {
    for i in 0..code.len().saturating_sub(2) {
        if code[i].ident(kw) && code[i + 1].ident(name) {
            let mut j = i + 2;
            while j < code.len() && !code[j].punct("{") {
                if code[j].punct(";") {
                    break; // declaration without a body
                }
                j += 1;
            }
            if j < code.len() && code[j].punct("{") {
                return close_of(code, j).map(|c| (j, c));
            }
        }
    }
    None
}

// ---------------------------------------------------------------------------
// 1. panicking-lock
// ---------------------------------------------------------------------------

/// `.lock().unwrap()` / `.lock().expect(…)` in server-side modules.
pub struct PanickingLock;

impl Rule for PanickingLock {
    fn name(&self) -> &'static str {
        "panicking-lock"
    }

    fn description(&self) -> &'static str {
        "server-side .lock().unwrap()/.expect() panics on mutex poisoning; \
         surface Err(Error::…) or recover with into_inner()"
    }

    fn applies_to(&self, path: &str) -> bool {
        server_side(path)
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            for i in 0..c.len().saturating_sub(6) {
                let hit = c[i].punct(".")
                    && c[i + 1].ident("lock")
                    && c[i + 2].punct("(")
                    && c[i + 3].punct(")")
                    && c[i + 4].punct(".")
                    && (c[i + 5].ident("unwrap") || c[i + 5].ident("expect"))
                    && c[i + 6].punct("(");
                if hit && !f.in_test(c[i + 5].line) {
                    out.push(Finding {
                        rule: self.name(),
                        file: f.path.clone(),
                        line: c[i + 5].line,
                        message: format!(
                            ".lock().{}() panics if a previous holder panicked; map the \
                             PoisonError into Err(Error::…) or recover with \
                             unwrap_or_else(|p| p.into_inner())",
                            c[i + 5].text
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. u64-as-json-number
// ---------------------------------------------------------------------------

/// `u64` message fields encoded as raw JSON numbers in `proto/msg.rs`.
pub struct U64AsJsonNumber;

/// `field: u64` declarations inside top-level `enum`/`struct` bodies.
fn u64_field_names(code: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    let mut i = 0usize;
    while i < code.len() {
        if (code[i].ident("enum") || code[i].ident("struct"))
            && code.get(i + 1).map(|t| t.kind) == Some(TokKind::Ident)
        {
            if let Some((open, close)) = item_body(&code[i..], &code[i].text, &code[i + 1].text)
                .map(|(o, c)| (o + i, c + i))
            {
                for j in open..close.saturating_sub(2) {
                    let field = code[j].kind == TokKind::Ident
                        && code[j + 1].punct(":")
                        && code[j + 2].ident("u64")
                        && code
                            .get(j + 3)
                            .map(|t| t.punct(",") || t.punct("}"))
                            .unwrap_or(false);
                    if field {
                        names.insert(code[j].text.clone());
                    }
                }
                i = close;
                continue;
            }
        }
        i += 1;
    }
    names
}

impl Rule for U64AsJsonNumber {
    fn name(&self) -> &'static str {
        "u64-as-json-number"
    }

    fn description(&self) -> &'static str {
        "u64 Msg fields must ride the JSON codec as strings — JSON numbers \
         are f64-backed and corrupt values above 2^53"
    }

    fn applies_to(&self, path: &str) -> bool {
        path.ends_with("proto/msg.rs")
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            let u64_fields = u64_field_names(c);
            let mut i = 0usize;
            while i + 4 < c.len() {
                let is_set = c[i].punct(".")
                    && c[i + 1].ident("set")
                    && c[i + 2].punct("(")
                    && c[i + 3].kind == TokKind::Str
                    && c[i + 4].punct(",");
                if !is_set || f.in_test(c[i + 3].line) {
                    i += 1;
                    continue;
                }
                let key = c[i + 3].text.trim_matches('"').to_string();
                if !u64_fields.contains(&key) {
                    i += 5;
                    continue;
                }
                // Argument tokens up to the `.set(`'s matching close.
                let mut depth = 1i32;
                let mut j = i + 5;
                let mut stringified = false;
                while j < c.len() && depth > 0 {
                    if c[j].punct("(") {
                        depth += 1;
                    } else if c[j].punct(")") {
                        depth -= 1;
                    } else if c[j].ident("to_string") || c[j].ident("format") {
                        stringified = true;
                    }
                    j += 1;
                }
                if !stringified {
                    out.push(Finding {
                        rule: self.name(),
                        file: f.path.clone(),
                        line: c[i + 3].line,
                        message: format!(
                            "u64 field {key:?} encoded as a JSON number — values above \
                             2^53 corrupt through the f64-backed codec; encode \
                             .to_string() and decode number-or-string"
                        ),
                    });
                }
                i = j;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. wall-clock-in-core
// ---------------------------------------------------------------------------

/// `Instant::now` / `SystemTime::now` outside the allowlist.
pub struct WallClockInCore;

impl Rule for WallClockInCore {
    fn name(&self) -> &'static str {
        "wall-clock-in-core"
    }

    fn description(&self) -> &'static str {
        "Instant::now/SystemTime::now outside util/bench.rs and cli.rs \
         breaks manual-clock determinism; use the Clock seam or justify \
         with an inline allow"
    }

    fn applies_to(&self, path: &str) -> bool {
        !(path.ends_with("util/bench.rs") || path.ends_with("cli.rs"))
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            for i in 0..c.len().saturating_sub(3) {
                let hit = (c[i].ident("Instant") || c[i].ident("SystemTime"))
                    && c[i + 1].punct(":")
                    && c[i + 2].punct(":")
                    && c[i + 3].ident("now");
                if hit && !f.in_test(c[i].line) {
                    out.push(Finding {
                        rule: self.name(),
                        file: f.path.clone(),
                        line: c[i].line,
                        message: format!(
                            "{}::now in core logic — orchestration must run on the \
                             deterministic Clock seam (services::FloridaServer) so \
                             seeded simulations replay bit-identically",
                            c[i].text
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 4. msg-coverage
// ---------------------------------------------------------------------------

/// Cross-file exhaustiveness over the `Msg` enum.
pub struct MsgCoverage;

/// `Msg::Variant` references within `code[range]`.
fn msg_refs(code: &[Token], from: usize, to: usize) -> BTreeSet<String> {
    let mut set = BTreeSet::new();
    let hi = to.min(code.len());
    for i in from..hi.saturating_sub(3) {
        if code[i].ident("Msg")
            && code[i + 1].punct(":")
            && code[i + 2].punct(":")
            && code[i + 3].kind == TokKind::Ident
        {
            set.insert(code[i + 3].text.clone());
        }
    }
    set
}

/// The union of `Msg::…` references in every function tagged with a
/// `// florida-lint: corpus(<name>)` marker.
fn corpus_refs(f: &SourceFile, corpus: &str) -> Option<BTreeSet<String>> {
    let mut found_marker = false;
    let mut set = BTreeSet::new();
    for (name, line) in &f.corpus_markers {
        if name != corpus {
            continue;
        }
        found_marker = true;
        // The marked item: first code token at/after the marker line,
        // then its first brace block.
        let Some(start) = f.code.iter().position(|t| t.line >= *line) else {
            continue;
        };
        let mut j = start;
        while j < f.code.len() && !f.code[j].punct("{") {
            j += 1;
        }
        if j < f.code.len() {
            if let Some(end) = close_of(&f.code, j) {
                set.extend(msg_refs(&f.code, j, end + 1));
            }
        }
    }
    found_marker.then_some(set)
}

impl Rule for MsgCoverage {
    fn name(&self) -> &'static str {
        "msg-coverage"
    }

    fn description(&self) -> &'static str {
        "every Msg variant must round-trip in the binary corpus, every \
         JSON-capable variant in the JSON corpus, and every request \
         variant must have a typed pair in proto/rpc.rs"
    }

    fn applies_to(&self, path: &str) -> bool {
        path.ends_with("proto/msg.rs") || path.ends_with("proto/rpc.rs")
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        let Some(msg) = files.iter().find(|f| f.path.ends_with("proto/msg.rs")) else {
            return;
        };
        let push = |out: &mut Vec<Finding>, line: u32, message: String| {
            out.push(Finding {
                rule: "msg-coverage",
                file: msg.path.clone(),
                line,
                message,
            });
        };
        let Some((open, close)) = item_body(&msg.code, "enum", "Msg") else {
            push(out, 1, "enum Msg not found in proto/msg.rs".into());
            return;
        };

        // Variants at depth 1 of the enum body, with their lines.
        let mut variants: Vec<(String, u32)> = Vec::new();
        let mut depth = 0i32;
        for j in open..=close {
            if msg.code[j].punct("{") {
                depth += 1;
            } else if msg.code[j].punct("}") {
                depth -= 1;
            } else if depth == 1
                && msg.code[j].kind == TokKind::Ident
                && msg.code
                    .get(j + 1)
                    .map(|t| t.punct("{") || t.punct("(") || t.punct(","))
                    .unwrap_or(false)
            {
                variants.push((msg.code[j].text.clone(), msg.code[j].line));
            }
        }

        // Direction sections from the enum's `// ---- a → b ----` comments.
        let enum_lines = (msg.code[open].line, msg.code[close].line);
        let mut switches: Vec<(u32, bool)> = Vec::new();
        for t in msg.tokens.iter().filter(|t| t.is_comment()) {
            if t.line < enum_lines.0 || t.line > enum_lines.1 {
                continue;
            }
            if t.text.contains("→ server") || t.text.contains("→ master") {
                switches.push((t.line, true));
            } else if t.text.contains("→ client") || t.text.contains("→ leaf") {
                switches.push((t.line, false));
            }
        }
        let is_request = |line: u32| -> bool {
            switches
                .iter()
                .rev()
                .find(|(l, _)| *l < line)
                .map(|(_, r)| *r)
                .unwrap_or(false)
        };

        // (a) Every variant in the binary round-trip corpus.
        match corpus_refs(msg, "binary-roundtrip") {
            None => push(
                out,
                1,
                "no `// florida-lint: corpus(binary-roundtrip)` marker in proto/msg.rs — \
                 the round-trip corpus is untracked"
                    .into(),
            ),
            Some(corpus) => {
                for (v, line) in &variants {
                    if !corpus.contains(v) {
                        push(
                            out,
                            *line,
                            format!(
                                "Msg::{v} missing from the corpus(binary-roundtrip) \
                                 round-trip samples"
                            ),
                        );
                    }
                }
            }
        }

        // (b) Every JSON-capable variant (a `Msg::…` arm in to_json) in
        // the JSON corpus.
        if let Some((jopen, jclose)) = item_body(&msg.code, "fn", "to_json") {
            let json_capable = msg_refs(&msg.code, jopen, jclose + 1);
            match corpus_refs(msg, "json-roundtrip") {
                None => push(
                    out,
                    msg.code[jopen].line,
                    "no `// florida-lint: corpus(json-roundtrip)` marker in proto/msg.rs — \
                     the JSON corpus is untracked"
                        .into(),
                ),
                Some(corpus) => {
                    for (v, line) in &variants {
                        if json_capable.contains(v) && !corpus.contains(v) {
                            push(
                                out,
                                *line,
                                format!(
                                    "JSON-capable Msg::{v} missing from the \
                                     corpus(json-roundtrip) round-trip samples"
                                ),
                            );
                        }
                    }
                }
            }
        }

        // (c) Every request variant has a typed `request!` pair in rpc.rs.
        let Some(rpc) = files.iter().find(|f| f.path.ends_with("proto/rpc.rs")) else {
            push(out, 1, "proto/rpc.rs not found — typed RPC pairs unchecked".into());
            return;
        };
        let mut typed: BTreeSet<String> = BTreeSet::new();
        let rc = &rpc.code;
        for i in 0..rc.len().saturating_sub(3) {
            if rc[i].ident("request") && rc[i + 1].punct("!") && rc[i + 2].punct("(") {
                // First ident inside the invocation is the request name.
                if let Some(t) = rc[i + 3..].iter().find(|t| t.kind == TokKind::Ident) {
                    typed.insert(t.text.clone());
                }
            }
        }
        for (v, line) in &variants {
            if is_request(*line) && !typed.contains(v) {
                push(
                    out,
                    *line,
                    format!(
                        "request variant Msg::{v} has no typed `request!` pair in \
                         proto/rpc.rs — protocol errors would surface as raw Msg matches"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 5. unchecked-wire-length
// ---------------------------------------------------------------------------

/// Wire-derived lengths sizing allocations without a bound check.
pub struct UncheckedWireLength;

const LEN_SOURCES: [&str; 5] = [
    "get_varint",
    "get_u32",
    "get_u64",
    "from_le_bytes",
    "from_be_bytes",
];

impl Rule for UncheckedWireLength {
    fn name(&self) -> &'static str {
        "unchecked-wire-length"
    }

    fn description(&self) -> &'static str {
        "a length decoded from the wire must be bounds-checked (MAX_FRAME, \
         remaining(), .min(cap)) before it sizes an allocation"
    }

    fn applies_to(&self, path: &str) -> bool {
        ["/codec/", "/proto/", "/transport/", "/storage/", "/aggtree/"]
            .iter()
            .any(|d| path.contains(d))
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            // ident -> still-unguarded wire length.
            let mut tracked: HashMap<String, bool> = HashMap::new();
            let mut i = 0usize;
            while i < c.len() {
                if f.in_test(c[i].line) {
                    i += 1;
                    continue;
                }
                // New function: bindings don't cross fn boundaries.
                if c[i].ident("fn") {
                    tracked.clear();
                    i += 1;
                    continue;
                }
                // `let [mut] name = <rhs…>;` with a wire-length source in rhs.
                if c[i].ident("let") {
                    let mut j = i + 1;
                    if c.get(j).map(|t| t.ident("mut")).unwrap_or(false) {
                        j += 1;
                    }
                    if let Some(name_tok) = c.get(j).filter(|t| t.kind == TokKind::Ident) {
                        let name = name_tok.text.clone();
                        let mut k = j + 1;
                        let mut depth = 0i32;
                        let mut sourced = false;
                        while k < c.len() {
                            if c[k].punct("(") || c[k].punct("{") || c[k].punct("[") {
                                depth += 1;
                            } else if c[k].punct(")") || c[k].punct("}") || c[k].punct("]") {
                                depth -= 1;
                            } else if c[k].punct(";") && depth <= 0 {
                                break;
                            } else if c[k].kind == TokKind::Ident
                                && LEN_SOURCES.contains(&c[k].text.as_str())
                            {
                                sourced = true;
                            }
                            k += 1;
                        }
                        if sourced {
                            tracked.insert(name, true);
                        }
                        i = j + 1;
                        continue;
                    }
                }
                // Guard: the ident compared, clamped, or measured.
                if c[i].kind == TokKind::Ident && tracked.contains_key(&c[i].text) {
                    let prev = i.checked_sub(1).map(|p| &c[p]);
                    let next = c.get(i + 1);
                    let cmp = |t: Option<&Token>| {
                        t.map(|t| t.punct("<") || t.punct(">")).unwrap_or(false)
                    };
                    let clamped = next.map(|t| t.punct(".")).unwrap_or(false)
                        && c.get(i + 2).map(|t| t.ident("min")).unwrap_or(false);
                    let min_arg = prev.map(|t| t.punct("(")).unwrap_or(false)
                        && i.checked_sub(2)
                            .map(|p| c[p].ident("min"))
                            .unwrap_or(false);
                    if cmp(prev) || cmp(next) || clamped || min_arg {
                        tracked.insert(c[i].text.clone(), false);
                    }
                }
                // Allocation sinks: with_capacity(…) and vec![…; n].
                let alloc_args: Option<(usize, &str)> = if c[i].ident("with_capacity")
                    && c.get(i + 1).map(|t| t.punct("(")).unwrap_or(false)
                {
                    Some((i + 1, "("))
                } else if c[i].ident("vec")
                    && c.get(i + 1).map(|t| t.punct("!")).unwrap_or(false)
                    && c.get(i + 2).map(|t| t.punct("[")).unwrap_or(false)
                {
                    Some((i + 2, "["))
                } else {
                    None
                };
                if let Some((start, open)) = alloc_args {
                    let (inc, dec) = if open == "(" { ("(", ")") } else { ("[", "]") };
                    let mut depth = 0i32;
                    let mut j = start;
                    while j < c.len() {
                        if c[j].punct(inc) {
                            depth += 1;
                        } else if c[j].punct(dec) {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        } else if c[j].kind == TokKind::Ident
                            && tracked.get(&c[j].text).copied().unwrap_or(false)
                        {
                            // `.min(cap)` right on the use site is a guard.
                            let clamped = c.get(j + 1).map(|t| t.punct(".")).unwrap_or(false)
                                && c.get(j + 2).map(|t| t.ident("min")).unwrap_or(false);
                            if !clamped {
                                out.push(Finding {
                                    rule: self.name(),
                                    file: f.path.clone(),
                                    line: c[j].line,
                                    message: format!(
                                        "wire-derived length `{}` sizes an allocation \
                                         without a bound check — a hostile frame can \
                                         claim any length; compare against \
                                         MAX_FRAME/remaining() or clamp with .min()",
                                        c[j].text
                                    ),
                                });
                            }
                        }
                        j += 1;
                    }
                    i = j.max(i + 1);
                    continue;
                }
                i += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 6. lock-across-send
// ---------------------------------------------------------------------------

/// A `MutexGuard` binding live across a transport `send`/`send_owned`.
pub struct LockAcrossSend;

/// Idents whose call in a `let` RHS produces a guard.
const LOCK_CALLS: [&str; 4] = ["lock", "try_lock", "locked", "lock_checked"];

impl Rule for LockAcrossSend {
    fn name(&self) -> &'static str {
        "lock-across-send"
    }

    fn description(&self) -> &'static str {
        "a MutexGuard held across a transport send serializes the data \
         plane and can deadlock with slow peers; serialize under the \
         lock, drop the guard, then send"
    }

    fn applies_to(&self, path: &str) -> bool {
        server_side(path)
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            let mut depth = 0i32;
            // (guard name, depth at binding)
            let mut guards: Vec<(String, i32)> = Vec::new();
            let mut i = 0usize;
            while i < c.len() {
                if f.in_test(c[i].line) {
                    i += 1;
                    continue;
                }
                if c[i].punct("{") {
                    depth += 1;
                } else if c[i].punct("}") {
                    depth -= 1;
                    guards.retain(|(_, d)| *d <= depth);
                } else if c[i].ident("fn") {
                    guards.clear();
                } else if c[i].ident("let") {
                    if let Some((name, after)) = let_binding_name(c, i) {
                        let mut k = after;
                        let mut d = 0i32;
                        let mut locks = false;
                        while k < c.len() {
                            if c[k].punct("(") || c[k].punct("{") || c[k].punct("[") {
                                d += 1;
                            } else if c[k].punct(")") || c[k].punct("}") || c[k].punct("]") {
                                d -= 1;
                            } else if c[k].punct(";") && d <= 0 {
                                break;
                            } else if d == 0
                                && c[k].kind == TokKind::Ident
                                && LOCK_CALLS.contains(&c[k].text.as_str())
                                && c.get(k + 1).map(|t| t.punct("(")).unwrap_or(false)
                            {
                                // Depth 0 only: a lock() inside a nested
                                // block/closure (`let x = { let g = m.lock()…; … };`)
                                // doesn't make the outer binding a guard.
                                locks = true;
                            }
                            k += 1;
                        }
                        if locks {
                            guards.push((name, depth));
                        }
                        i = after;
                        continue;
                    }
                } else if c[i].ident("drop")
                    && c.get(i + 1).map(|t| t.punct("(")).unwrap_or(false)
                {
                    if let Some(t) = c.get(i + 2) {
                        guards.retain(|(n, _)| n != &t.text);
                    }
                } else if c[i].punct(".")
                    && c.get(i + 1)
                        .map(|t| t.ident("send") || t.ident("send_owned"))
                        .unwrap_or(false)
                    && c.get(i + 2).map(|t| t.punct("(")).unwrap_or(false)
                {
                    if let Some((g, _)) = guards.first() {
                        out.push(Finding {
                            rule: self.name(),
                            file: f.path.clone(),
                            line: c[i + 1].line,
                            message: format!(
                                "transport .{}() while MutexGuard `{g}` is live — \
                                 serialize under the lock, drop({g}), then send",
                                c[i + 1].text
                            ),
                        });
                    }
                }
                i += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 7. global-lock-on-hot-path
// ---------------------------------------------------------------------------

/// Lock acquisition inside a poll/upload/heartbeat handler.
pub struct GlobalLockOnHotPath;

/// Function-name substrings that mark a hot-path handler.
const HOT_FN_MARKERS: [&str; 3] = ["poll", "upload", "heartbeat"];

impl Rule for GlobalLockOnHotPath {
    fn name(&self) -> &'static str {
        "global-lock-on-hot-path"
    }

    fn description(&self) -> &'static str {
        "a Mutex acquired inside a poll/upload/heartbeat handler \
         re-serializes the sharded data plane; route the request through \
         ShardRouter so it takes only its home shard's lock"
    }

    fn applies_to(&self, path: &str) -> bool {
        path.contains("/services/") || path.contains("/shard/")
    }

    fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
        for f in files.iter().filter(|f| self.applies_to(&f.path)) {
            let c = &f.code;
            let mut i = 0usize;
            while i + 1 < c.len() {
                let is_hot_fn = c[i].ident("fn")
                    && c[i + 1].kind == TokKind::Ident
                    && HOT_FN_MARKERS
                        .iter()
                        .any(|m| c[i + 1].text.to_ascii_lowercase().contains(m));
                if !is_hot_fn || f.in_test(c[i + 1].line) {
                    i += 1;
                    continue;
                }
                // Handler body: the signature's first `{` (a `;` means a
                // trait declaration — nothing to scan).
                let mut j = i + 2;
                while j < c.len() && !c[j].punct("{") && !c[j].punct(";") {
                    j += 1;
                }
                let Some(close) = c
                    .get(j)
                    .filter(|t| t.punct("{"))
                    .and_then(|_| close_of(c, j))
                else {
                    i = j.max(i + 1);
                    continue;
                };
                for k in j..close {
                    let locks = c[k].kind == TokKind::Ident
                        && LOCK_CALLS.contains(&c[k].text.as_str())
                        && c.get(k + 1).map(|t| t.punct("(")).unwrap_or(false)
                        // `.lock(` / `.locked(` — a method call, not a fn
                        // named `lock` being declared.
                        && k.checked_sub(1).map(|p| c[p].punct(".")).unwrap_or(false);
                    if locks && !f.in_test(c[k].line) {
                        out.push(Finding {
                            rule: self.name(),
                            file: f.path.clone(),
                            line: c[k].line,
                            message: format!(
                                "hot-path handler `{}` acquires a lock via .{}() — every \
                                 poll/upload/heartbeat serializes here; shard the state \
                                 behind ShardRouter (client/task home shard) instead",
                                c[i + 1].text,
                                c[k].text
                            ),
                        });
                    }
                }
                i = close;
            }
        }
    }
}

/// Parse the bound name of `let [mut] name =` / `let Ok(name) =` /
/// `let Some(mut name) =`; returns (name, index-after-pattern).
fn let_binding_name(c: &[Token], let_idx: usize) -> Option<(String, usize)> {
    let mut j = let_idx + 1;
    if c.get(j)?.ident("mut") {
        j += 1;
    }
    let t = c.get(j)?;
    if t.kind != TokKind::Ident {
        return None;
    }
    if (t.ident("Ok") || t.ident("Some")) && c.get(j + 1).map(|t| t.punct("(")).unwrap_or(false) {
        j += 2;
        if c.get(j)?.ident("mut") {
            j += 1;
        }
        let inner = c.get(j)?;
        if inner.kind != TokKind::Ident {
            return None;
        }
        return Some((inner.text.clone(), j + 2));
    }
    Some((t.text.clone(), j + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_rules;

    fn lint_one(rule: Box<dyn Rule>, path: &str, src: &str) -> Vec<Finding> {
        let files = vec![SourceFile::parse(path, src)];
        run_rules(&files, &[rule])
    }

    // -- panicking-lock ----------------------------------------------------

    #[test]
    fn panicking_lock_flags_unwrap_and_expect() {
        let src = "fn f(m: &std::sync::Mutex<u32>) {\n\
                   let a = m.lock().unwrap();\n\
                   let b = m.lock().expect(\"poisoned\");\n}\n";
        let got = lint_one(Box::new(PanickingLock), "rust/src/services/x.rs", src);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].line, 2);
        assert_eq!(got[1].line, 3);
    }

    #[test]
    fn panicking_lock_scopes_to_server_modules_and_skips_tests() {
        let src = "fn f(m: &std::sync::Mutex<u32>) { let a = m.lock().unwrap(); }\n";
        assert!(lint_one(Box::new(PanickingLock), "rust/src/client/x.rs", src).is_empty());
        // The telemetry surfaces run on request threads: in scope.
        assert_eq!(lint_one(Box::new(PanickingLock), "rust/src/metrics/x.rs", src).len(), 1);
        assert_eq!(lint_one(Box::new(PanickingLock), "rust/src/obs/x.rs", src).len(), 1);
        let test_src = "#[cfg(test)]\nmod tests {\n  fn f(m: &std::sync::Mutex<u32>) \
                        { let a = m.lock().unwrap(); }\n}\n";
        assert!(lint_one(Box::new(PanickingLock), "rust/src/services/x.rs", test_src).is_empty());
    }

    #[test]
    fn panicking_lock_accepts_mapped_and_recovered_forms() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> Result<u32, ()> {\n\
                   let a = m.lock().map_err(|_| ())?;\n\
                   let b = m.lock().unwrap_or_else(|p| p.into_inner());\n\
                   Ok(*a + *b)\n}\n";
        assert!(lint_one(Box::new(PanickingLock), "rust/src/services/x.rs", src).is_empty());
    }

    #[test]
    fn panicking_lock_inline_allow() {
        let src = "fn f(m: &std::sync::Mutex<u32>) {\n\
                   // florida-lint: allow(panicking-lock): demo\n\
                   let a = m.lock().unwrap();\n}\n";
        assert!(lint_one(Box::new(PanickingLock), "rust/src/services/x.rs", src).is_empty());
    }

    // -- u64-as-json-number ------------------------------------------------

    const MINI_MSG_HEADER: &str = "pub enum Msg {\n\
        A { client_id: u64, name: String },\n\
    }\n";

    #[test]
    fn u64_json_flags_raw_number_encoding() {
        let src = format!(
            "{MINI_MSG_HEADER}impl Msg {{\n  pub fn to_json(&self) -> Json {{\n\
             Json::obj().set(\"client_id\", *client_id).set(\"name\", name.as_str())\n  }}\n}}\n"
        );
        let got = lint_one(Box::new(U64AsJsonNumber), "rust/src/proto/msg.rs", &src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("client_id"));
    }

    #[test]
    fn u64_json_accepts_stringified_encoding() {
        let src = format!(
            "{MINI_MSG_HEADER}impl Msg {{\n  pub fn to_json(&self) -> Json {{\n\
             Json::obj().set(\"client_id\", client_id.to_string())\n  }}\n}}\n"
        );
        assert!(lint_one(Box::new(U64AsJsonNumber), "rust/src/proto/msg.rs", &src).is_empty());
    }

    #[test]
    fn u64_json_only_applies_to_msg_rs() {
        let src = format!(
            "{MINI_MSG_HEADER}fn f() {{ Json::obj().set(\"client_id\", *client_id); }}\n"
        );
        assert!(lint_one(Box::new(U64AsJsonNumber), "rust/src/proto/mod.rs", &src).is_empty());
    }

    // -- wall-clock-in-core ------------------------------------------------

    #[test]
    fn wall_clock_flags_both_clocks() {
        let src = "fn f() { let a = Instant::now(); let b = std::time::SystemTime::now(); }\n";
        let got = lint_one(Box::new(WallClockInCore), "rust/src/simulator/x.rs", src);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn wall_clock_allowlist_and_tests() {
        let src = "fn f() { let a = Instant::now(); }\n";
        assert!(lint_one(Box::new(WallClockInCore), "rust/src/util/bench.rs", src).is_empty());
        assert!(lint_one(Box::new(WallClockInCore), "rust/src/cli.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n fn f() { let a = Instant::now(); }\n}\n";
        assert!(
            lint_one(Box::new(WallClockInCore), "rust/src/simulator/x.rs", test_src).is_empty()
        );
    }

    // -- msg-coverage ------------------------------------------------------

    fn mini_msg(corpus_has_b: bool, json_corpus: bool) -> String {
        let b_sample = if corpus_has_b { "Msg::B," } else { "" };
        let json_marker = if json_corpus {
            "// florida-lint: corpus(json-roundtrip)\n"
        } else {
            "\n"
        };
        format!(
            "pub enum Msg {{\n\
             // ---- client → server ----\n\
             A {{ x: u64 }},\n\
             B {{ y: u64 }},\n\
             // ---- server → client ----\n\
             C {{ z: u64 }},\n\
             }}\n\
             impl Msg {{\n\
             pub fn to_json(&self) -> Json {{ match self {{ Msg::A {{ .. }} => j() }} }}\n\
             }}\n\
             #[cfg(test)]\n\
             mod tests {{\n\
             // florida-lint: corpus(binary-roundtrip)\n\
             fn all_binary_samples() {{ let v = [Msg::A, {b_sample} Msg::C,]; }}\n\
             {json_marker}\
             fn all_json_samples() {{ let v = [Msg::A,]; }}\n\
             }}\n"
        )
    }

    const MINI_RPC: &str = "request!(A { x: u64 } => ReplyA, \"a\");\n";

    #[test]
    fn msg_coverage_clean_when_complete() {
        let msg = mini_msg(true, true);
        let rpc = format!("request!(A {{ x: u64 }} => ReplyA, \"a\");\n{}",
            "request!(B { y: u64 } => ReplyB, \"b\");\n");
        let files = vec![
            SourceFile::parse("rust/src/proto/msg.rs", &msg),
            SourceFile::parse("rust/src/proto/rpc.rs", &rpc),
        ];
        let got = run_rules(&files, &[Box::new(MsgCoverage) as Box<dyn Rule>]);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn msg_coverage_flags_missing_binary_sample() {
        let files = vec![
            SourceFile::parse("rust/src/proto/msg.rs", &mini_msg(false, true)),
            SourceFile::parse(
                "rust/src/proto/rpc.rs",
                &format!("{MINI_RPC}request!(B {{ y: u64 }} => ReplyB, \"b\");\n"),
            ),
        ];
        let got = run_rules(&files, &[Box::new(MsgCoverage) as Box<dyn Rule>]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("Msg::B"));
        assert!(got[0].message.contains("binary-roundtrip"));
    }

    #[test]
    fn msg_coverage_flags_missing_json_sample_and_missing_rpc_pair() {
        // B is a request with no request! pair; to_json covers A only,
        // and the json corpus is missing entirely.
        let files = vec![
            SourceFile::parse("rust/src/proto/msg.rs", &mini_msg(true, false)),
            SourceFile::parse("rust/src/proto/rpc.rs", MINI_RPC),
        ];
        let got = run_rules(&files, &[Box::new(MsgCoverage) as Box<dyn Rule>]);
        let msgs: Vec<&str> = got.iter().map(|f| f.message.as_str()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("json-roundtrip")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter()
                .any(|m| m.contains("Msg::B") && m.contains("request!")),
            "{msgs:?}"
        );
        // C is a reply — no request! pair needed.
        assert!(!msgs.iter().any(|m| m.contains("Msg::C")), "{msgs:?}");
    }

    // -- unchecked-wire-length ---------------------------------------------

    #[test]
    fn wire_length_flags_unguarded_alloc() {
        let src = "fn d(r: &mut Reader) {\n\
                   let n = r.get_varint()? as usize;\n\
                   let mut v = Vec::with_capacity(n);\n}\n";
        let got = lint_one(Box::new(UncheckedWireLength), "rust/src/codec/x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].line, 3);
    }

    #[test]
    fn wire_length_accepts_guarded_and_clamped() {
        let src = "fn d(r: &mut Reader) {\n\
                   let n = r.get_varint()? as usize;\n\
                   if n > r.remaining() / 8 { return; }\n\
                   let mut v = Vec::with_capacity(n);\n\
                   let len = u32::from_be_bytes(b) as usize;\n\
                   let mut w = Vec::with_capacity(len.min(4096));\n}\n";
        let got = lint_one(Box::new(UncheckedWireLength), "rust/src/codec/x.rs", src);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn wire_length_flags_vec_macro_alloc() {
        let src = "fn d(b: [u8; 4]) {\n\
                   let len = u32::from_be_bytes(b) as usize;\n\
                   let buf = vec![0u8; len];\n}\n";
        let got = lint_one(Box::new(UncheckedWireLength), "rust/src/transport/x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
    }

    #[test]
    fn wire_length_ignores_non_wire_lengths() {
        let src = "fn d(delta: &[f32]) { let mut v = Vec::with_capacity(delta.len() * 4); }\n";
        assert!(lint_one(Box::new(UncheckedWireLength), "rust/src/codec/x.rs", src).is_empty());
    }

    // -- lock-across-send --------------------------------------------------

    #[test]
    fn lock_across_send_flags_live_guard() {
        let src = "fn f(&self, conn: &mut dyn Connection) {\n\
                   let g = self.inner.lock().unwrap();\n\
                   conn.send(&g.frame);\n}\n";
        let got = lint_one(Box::new(LockAcrossSend), "rust/src/services/x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains('g'));
    }

    #[test]
    fn lock_across_send_accepts_drop_and_scope_exit() {
        let src = "fn f(&self, conn: &mut dyn Connection) {\n\
                   let g = self.inner.lock().unwrap();\n\
                   let frame = g.frame.clone();\n\
                   drop(g);\n\
                   conn.send(&frame);\n\
                   let out = {\n\
                     let h = self.inner.lock().unwrap();\n\
                     h.frame.clone()\n\
                   };\n\
                   conn.send_owned(out);\n}\n";
        let got = lint_one(Box::new(LockAcrossSend), "rust/src/services/x.rs", src);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn lock_across_send_tracks_ok_patterns_and_helpers() {
        let src = "fn f(&self, conn: &mut dyn Connection) {\n\
                   let Ok(mut g) = self.inner.locked() else { return; };\n\
                   conn.send_owned(g.take());\n}\n";
        let got = lint_one(Box::new(LockAcrossSend), "rust/src/services/x.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
    }

    // -- global-lock-on-hot-path -------------------------------------------

    #[test]
    fn hot_path_lock_flags_handlers_by_name() {
        let src = "impl S {\n\
                   fn handle_poll(&self) { let g = self.inner.lock().unwrap(); }\n\
                   fn upload_plain(&self) -> u32 { *self.state.locked() }\n\
                   fn on_heartbeat(&self) { let _ = self.reg.try_lock(); }\n\
                   fn commit(&self) { let g = self.inner.lock().unwrap(); }\n\
                   }\n";
        let got = lint_one(Box::new(GlobalLockOnHotPath), "rust/src/services/x.rs", src);
        assert_eq!(got.len(), 3, "{got:?}");
        assert!(got[0].message.contains("handle_poll"));
        assert!(got[1].message.contains("upload_plain"));
        assert!(got[2].message.contains("on_heartbeat"));
    }

    #[test]
    fn hot_path_lock_scopes_to_services_and_shard() {
        let src = "fn poll_task(&self) { let g = self.inner.lock().unwrap(); }\n";
        assert_eq!(lint_one(Box::new(GlobalLockOnHotPath), "rust/src/shard/x.rs", src).len(), 1);
        // The orchestrator is below the dispatch surface — out of scope.
        assert!(
            lint_one(Box::new(GlobalLockOnHotPath), "rust/src/orchestrator/x.rs", src).is_empty()
        );
        let test_src = "#[cfg(test)]\nmod tests {\n\
                        fn poll_task(m: &std::sync::Mutex<u32>) { let g = m.lock().unwrap(); }\n\
                        }\n";
        assert!(
            lint_one(Box::new(GlobalLockOnHotPath), "rust/src/services/x.rs", test_src).is_empty()
        );
    }

    #[test]
    fn hot_path_lock_ignores_lock_free_handlers_and_allows() {
        // Relaxed-atomic instruments and shard-routed calls don't lock.
        let src = "fn note_upload(&self) { self.stats.uploads.inc(); }\n\
                   fn poll_gate(&self) {\n\
                   // florida-lint: allow(global-lock-on-hot-path): single-shard fallback\n\
                   let g = self.inner.lock().unwrap();\n}\n";
        let got = lint_one(Box::new(GlobalLockOnHotPath), "rust/src/services/x.rs", src);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn default_rules_names_are_unique_and_stable() {
        let rules = default_rules();
        let names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
        assert_eq!(
            names,
            [
                "panicking-lock",
                "u64-as-json-number",
                "wall-clock-in-core",
                "msg-coverage",
                "unchecked-wire-length",
                "lock-across-send",
                "global-lock-on-hot-path",
            ]
        );
        for r in &rules {
            assert!(!r.description().is_empty());
        }
    }
}
