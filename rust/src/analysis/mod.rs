//! `florida lint` — repo-aware static analysis (std-only, like `util/`).
//!
//! Past PRs fixed a panicking poisoned mutex on the RPC path, a u64
//! corrupted above 2^53 by the f64-backed JSON codec, and wall-clock
//! nondeterminism in the simulator — each found by hand. This module
//! turns those bug classes into machine-checked invariants: a
//! lightweight tokenizer ([`tokenizer`]), a [`rules::Rule`] framework
//! with file-path scoping, `file:line` findings, inline
//! `// florida-lint: allow(<rule>)` suppression, and a committed
//! [`Baseline`] for grandfathered sites whose count may only shrink.
//!
//! Entry points: the `florida lint [--baseline] [--write-baseline]`
//! CLI subcommand (`cli.rs`) and the `lint_enforced` test target, which
//! runs the same engine over `rust/src` under plain `cargo test`.
//!
//! Suppression syntax, checked per rule name:
//!
//! ```text
//! // florida-lint: allow(wall-clock-in-core): metrics latency is wall time
//! let t0 = Instant::now();
//! ```
//!
//! An `allow` covers its own line and the line directly below, so it
//! works both trailing and as a line above. Corpus markers
//! (`// florida-lint: corpus(binary-roundtrip)`) tag the test-corpus
//! functions the `msg-coverage` rule checks variants against.

pub mod rules;
pub mod tokenizer;

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use crate::error::{Error, Result};
use tokenizer::{tokenize, Token};

pub use rules::{default_rules, Rule};

/// One lint finding, anchored to a source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// A tokenized source file plus the lint-relevant trivia extracted from
/// its comments: `allow` suppressions, corpus markers, and the line
/// ranges of `#[cfg(test)]` regions (tests may panic, block, and read
/// the wall clock freely).
pub struct SourceFile {
    /// Repo-relative path with forward slashes, e.g.
    /// `rust/src/services/router.rs`.
    pub path: String,
    /// All tokens, comments included.
    pub tokens: Vec<Token>,
    /// Significant tokens only (comments stripped) — what rules match.
    pub code: Vec<Token>,
    /// line → rules allowed on that line and the next.
    allows: HashMap<u32, Vec<String>>,
    /// Corpus marker name → source line of the marker.
    pub corpus_markers: Vec<(String, u32)>,
    /// Inclusive line ranges covered by `#[cfg(test)]` items.
    test_ranges: Vec<(u32, u32)>,
}

impl SourceFile {
    /// Tokenize and extract directives. Never fails: a file the
    /// tokenizer cannot make sense of just yields fewer tokens.
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let tokens = tokenize(src);
        let mut allows: HashMap<u32, Vec<String>> = HashMap::new();
        let mut corpus_markers = Vec::new();
        for t in tokens.iter().filter(|t| t.is_comment()) {
            let Some(rest) = t.text.split("florida-lint:").nth(1) else {
                continue;
            };
            for (kind, names) in parse_directives(rest) {
                match kind {
                    DirectiveKind::Allow => {
                        allows.entry(t.line).or_default().extend(names)
                    }
                    DirectiveKind::Corpus => corpus_markers
                        .extend(names.into_iter().map(|n| (n, t.line))),
                }
            }
        }
        let code: Vec<Token> = tokens.iter().filter(|t| !t.is_comment()).cloned().collect();
        let test_ranges = find_test_ranges(&code);
        SourceFile {
            path: path.replace('\\', "/"),
            tokens,
            code,
            allows,
            corpus_markers,
            test_ranges,
        }
    }

    /// Is `rule` suppressed at `line` (allow on the line itself or the
    /// line directly above)?
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        for l in [line, line.saturating_sub(1)] {
            if self
                .allows
                .get(&l)
                .is_some_and(|rs| rs.iter().any(|r| r == rule))
            {
                return true;
            }
        }
        false
    }

    /// Is `line` inside a `#[cfg(test)]` item?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }
}

#[derive(Clone, Copy)]
enum DirectiveKind {
    Allow,
    Corpus,
}

/// Parse `allow(a, b)` / `corpus(x)` occurrences out of a comment tail.
fn parse_directives(rest: &str) -> Vec<(DirectiveKind, Vec<String>)> {
    let mut out = Vec::new();
    for (word, kind) in [
        ("allow(", DirectiveKind::Allow),
        ("corpus(", DirectiveKind::Corpus),
    ] {
        let mut cursor = rest;
        while let Some(pos) = cursor.find(word) {
            let tail = &cursor[pos + word.len()..];
            let Some(end) = tail.find(')') else { break };
            let names = tail[..end]
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            out.push((kind, names));
            cursor = &tail[end..];
        }
    }
    out
}

/// Line ranges of `#[cfg(test)]` items: from the attribute to the close
/// of the first brace block that follows it.
fn find_test_ranges(code: &[Token]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < code.len() {
        let hit = code[i].punct("#")
            && code[i + 1].punct("[")
            && code[i + 2].ident("cfg")
            && code[i + 3].punct("(")
            && code[i + 4].ident("test")
            && code[i + 5].punct(")")
            && code[i + 6].punct("]");
        if !hit {
            i += 1;
            continue;
        }
        let start_line = code[i].line;
        // Find the body: first `{` after the attribute, then its match.
        let mut j = i + 7;
        while j < code.len() && !code[j].punct("{") {
            j += 1;
        }
        let mut depth = 0i32;
        let mut end_line = code.last().map(|t| t.line).unwrap_or(start_line);
        while j < code.len() {
            if code[j].punct("{") {
                depth += 1;
            } else if code[j].punct("}") {
                depth -= 1;
                if depth == 0 {
                    end_line = code[j].line;
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
        out.push((start_line, end_line));
        i = j.max(i + 7);
    }
    out
}

/// Walk `repo_root/rust/src` and parse every `.rs` file, storing paths
/// relative to `repo_root` so findings and the baseline are stable no
/// matter where the engine runs from.
pub fn load_tree(repo_root: &Path) -> Result<Vec<SourceFile>> {
    let src_root = repo_root.join("rust").join("src");
    let mut paths = Vec::new();
    collect_rs_files(&src_root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = std::fs::read_to_string(&p)?;
        let rel = p
            .strip_prefix(repo_root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile::parse(&rel, &text));
    }
    Ok(files)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)
        .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{}: {e}", dir.display()))))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run every rule over the tree, drop inline-suppressed findings, and
/// return the rest sorted by (file, line, rule).
pub fn run_rules(files: &[SourceFile], rules: &[Box<dyn Rule>]) -> Vec<Finding> {
    let by_path: HashMap<&str, &SourceFile> =
        files.iter().map(|f| (f.path.as_str(), f)).collect();
    let mut out = Vec::new();
    for rule in rules {
        let mut raw = Vec::new();
        rule.check(files, &mut raw);
        for f in raw {
            let suppressed = by_path
                .get(f.file.as_str())
                .is_some_and(|s| s.allowed(f.rule, f.line));
            if !suppressed {
                out.push(f);
            }
        }
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Render findings one per line, `file:line: [rule] message`.
pub fn render(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    s
}

/// Grandfathered findings: per (rule, file) counts that may only
/// shrink. Count-based (not line-based) so unrelated edits shifting
/// line numbers never resurrect or mask a finding.
#[derive(Default)]
pub struct Baseline {
    counts: BTreeMap<(String, String), usize>,
}

impl Baseline {
    /// Parse the committed baseline: `#` comments, then
    /// `<rule> <file> <count>` per line.
    pub fn parse(text: &str) -> Result<Baseline> {
        let mut counts = BTreeMap::new();
        for (idx, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (Some(rule), Some(file), Some(n)) = (it.next(), it.next(), it.next()) else {
                return Err(Error::Config(format!(
                    "lint baseline line {}: expected `<rule> <file> <count>`, got {line:?}",
                    idx + 1
                )));
            };
            let n: usize = n.parse().map_err(|_| {
                Error::Config(format!("lint baseline line {}: bad count {n:?}", idx + 1))
            })?;
            counts.insert((rule.to_string(), file.to_string()), n);
        }
        Ok(Baseline { counts })
    }

    /// Serialize findings as a fresh baseline.
    pub fn render_from(findings: &[Finding]) -> String {
        let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        for f in findings {
            *counts.entry((f.rule.to_string(), f.file.clone())).or_default() += 1;
        }
        let mut s = String::from(
            "# florida lint baseline — grandfathered findings, count may only shrink.\n\
             # Format: <rule> <file> <count>\n\
             # Regenerate (after fixing, never to admit new findings):\n\
             #   cargo run --release -- lint --write-baseline\n",
        );
        for ((rule, file), n) in &counts {
            s.push_str(&format!("{rule} {file} {n}\n"));
        }
        s
    }

    /// Split findings into (reported, grandfathered-count, stale-slots).
    ///
    /// A (rule, file) group within its baselined count is grandfathered
    /// wholesale; once a group exceeds its budget every finding in it is
    /// reported (line identity is unknowable, so the whole group
    /// surfaces — fixing back down to budget silences it). `stale` is
    /// how many baseline slots are no longer used; CI prints a nudge to
    /// shrink the file when it is nonzero.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, usize, usize) {
        let mut groups: BTreeMap<(String, String), Vec<Finding>> = BTreeMap::new();
        for f in findings {
            groups
                .entry((f.rule.to_string(), f.file.clone()))
                .or_default()
                .push(f);
        }
        let mut reported = Vec::new();
        let mut grandfathered = 0usize;
        let mut stale = 0usize;
        for ((rule, file), group) in &mut groups {
            let budget = self
                .counts
                .get(&(rule.clone(), file.clone()))
                .copied()
                .unwrap_or(0);
            if group.len() <= budget {
                grandfathered += group.len();
                stale += budget - group.len();
            } else {
                reported.append(group);
            }
        }
        // Baseline entries whose group vanished entirely are stale too.
        for (key, budget) in &self.counts {
            if !groups.contains_key(key) {
                stale += budget;
            }
        }
        reported.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
        (reported, grandfathered, stale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_directive_covers_own_and_next_line() {
        let f = SourceFile::parse(
            "rust/src/x.rs",
            "// florida-lint: allow(some-rule)\nlet a = 1;\nlet b = 2;\n",
        );
        assert!(f.allowed("some-rule", 1));
        assert!(f.allowed("some-rule", 2));
        assert!(!f.allowed("some-rule", 3));
        assert!(!f.allowed("other-rule", 2));
    }

    #[test]
    fn trailing_allow_and_multiple_rules() {
        let f = SourceFile::parse(
            "rust/src/x.rs",
            "let a = 1; // florida-lint: allow(rule-a, rule-b): why\n",
        );
        assert!(f.allowed("rule-a", 1));
        assert!(f.allowed("rule-b", 1));
        assert!(!f.allowed("rule-c", 1));
    }

    #[test]
    fn corpus_markers_collected() {
        let f = SourceFile::parse(
            "rust/src/x.rs",
            "// florida-lint: corpus(binary-roundtrip, json-roundtrip)\nfn samples() {}\n",
        );
        let names: Vec<&str> = f.corpus_markers.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["binary-roundtrip", "json-roundtrip"]);
    }

    #[test]
    fn cfg_test_ranges_detected() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\nfn after() {}\n";
        let f = SourceFile::parse("rust/src/x.rs", src);
        assert!(!f.in_test(1));
        assert!(f.in_test(2));
        assert!(f.in_test(4));
        assert!(f.in_test(5));
        assert!(!f.in_test(6));
    }

    #[test]
    fn baseline_roundtrip_and_budget() {
        let findings = vec![
            Finding {
                rule: "r1",
                file: "rust/src/a.rs".into(),
                line: 3,
                message: "m".into(),
            },
            Finding {
                rule: "r1",
                file: "rust/src/a.rs".into(),
                line: 9,
                message: "m".into(),
            },
        ];
        let text = Baseline::render_from(&findings);
        let base = Baseline::parse(&text).unwrap();
        // Within budget: everything grandfathered.
        let (rep, grand, stale) = base.apply(findings.clone());
        assert!(rep.is_empty());
        assert_eq!(grand, 2);
        assert_eq!(stale, 0);
        // Over budget: the whole group surfaces.
        let mut more = findings.clone();
        more.push(Finding {
            rule: "r1",
            file: "rust/src/a.rs".into(),
            line: 20,
            message: "m".into(),
        });
        let (rep, _, _) = base.apply(more);
        assert_eq!(rep.len(), 3);
        // Under budget: stale slots reported.
        let (rep, grand, stale) = base.apply(findings[..1].to_vec());
        assert!(rep.is_empty());
        assert_eq!(grand, 1);
        assert_eq!(stale, 1);
    }

    #[test]
    fn baseline_rejects_garbage() {
        assert!(Baseline::parse("not enough fields\n").is_err());
        assert!(Baseline::parse("rule file notanumber\n").is_err());
        assert!(Baseline::parse("# comment only\n\n").is_ok());
    }

    #[test]
    fn run_rules_applies_suppression() {
        struct Always;
        impl Rule for Always {
            fn name(&self) -> &'static str {
                "always"
            }
            fn description(&self) -> &'static str {
                "fires on line 2 of every file"
            }
            fn applies_to(&self, _path: &str) -> bool {
                true
            }
            fn check(&self, files: &[SourceFile], out: &mut Vec<Finding>) {
                for f in files {
                    out.push(Finding {
                        rule: "always",
                        file: f.path.clone(),
                        line: 2,
                        message: "hit".into(),
                    });
                }
            }
        }
        let clean = SourceFile::parse("rust/src/a.rs", "fn a() {}\nfn b() {}\n");
        let suppressed = SourceFile::parse(
            "rust/src/b.rs",
            "fn a() {}\nfn b() {} // florida-lint: allow(always)\n",
        );
        let rules: Vec<Box<dyn Rule>> = vec![Box::new(Always)];
        let out = run_rules(&[clean, suppressed], &rules);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].file, "rust/src/a.rs");
    }
}
