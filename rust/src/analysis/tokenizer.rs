//! Lightweight Rust tokenizer for the lint engine.
//!
//! Deliberately NOT a full lexer: the rules in `analysis::rules` match
//! short token sequences (`.lock().unwrap()`, `Instant::now`,
//! `Vec::with_capacity(n)`), so the tokenizer only needs to get four
//! things exactly right — comments (kept as trivia, because
//! `// florida-lint:` directives and the `Msg` section markers live
//! there), string/char literals (so code quoted inside test fixtures
//! can never produce findings), lifetimes vs char literals, and line
//! numbers (findings are reported as `file:line`). Everything else is
//! single-character punctuation; multi-char operators (`::`, `=>`) stay
//! split and the rules match them as consecutive tokens.

/// Token classification — just enough for rule matching.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`lock`, `let`, `u64`, …).
    Ident,
    /// Numeric literal (permissive: `0x1f`, `1_000`, `1e-5`, `1.5f64`).
    Number,
    /// String literal, including raw (`r#"…"#`) and byte (`b"…"`) forms.
    Str,
    /// Char literal (`'x'`, `'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation character.
    Punct,
    /// `// …` comment (text includes the slashes).
    LineComment,
    /// `/* … */` comment, nesting handled.
    BlockComment,
}

/// One token plus the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// Is this an identifier with exactly this text?
    pub fn ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this a punctuation token with exactly this text?
    pub fn punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }

    /// Is this a comment (line or block)?
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize Rust source. Never fails: unterminated literals consume to
/// end-of-input (the lint must degrade, not crash, on a broken tree).
pub fn tokenize(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < chars.len() {
        let c = chars[i];
        let start_line = line;
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if chars.get(i + 1) == Some(&'/') => {
                let mut text = String::new();
                while i < chars.len() && chars[i] != '\n' {
                    text.push(chars[i]);
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::LineComment,
                    text,
                    line: start_line,
                });
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                let mut text = String::new();
                let mut depth = 0usize;
                while i < chars.len() {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        text.push_str("/*");
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        text.push_str("*/");
                        i += 2;
                        if depth == 0 {
                            break;
                        }
                    } else {
                        if chars[i] == '\n' {
                            line += 1;
                        }
                        text.push(chars[i]);
                        i += 1;
                    }
                }
                toks.push(Token {
                    kind: TokKind::BlockComment,
                    text,
                    line: start_line,
                });
            }
            '"' => {
                let (text, ni, nl) = scan_string(&chars, i, line);
                i = ni;
                line = nl;
                toks.push(Token {
                    kind: TokKind::Str,
                    text,
                    line: start_line,
                });
            }
            '\'' => {
                // Lifetime or char literal. `'\…'` is always a char;
                // `'x'` is a char; `'ident` not closed by a quote is a
                // lifetime.
                let next = chars.get(i + 1).copied();
                let is_char = match next {
                    Some('\\') => true,
                    Some(n) if is_ident_start(n) => chars.get(i + 2) == Some(&'\''),
                    Some(_) => true,
                    None => false,
                };
                if is_char {
                    let (text, ni) = scan_char(&chars, i);
                    i = ni;
                    toks.push(Token {
                        kind: TokKind::Char,
                        text,
                        line: start_line,
                    });
                } else {
                    let mut text = String::from("'");
                    i += 1;
                    while i < chars.len() && is_ident_continue(chars[i]) {
                        text.push(chars[i]);
                        i += 1;
                    }
                    toks.push(Token {
                        kind: TokKind::Lifetime,
                        text,
                        line: start_line,
                    });
                }
            }
            c if c.is_ascii_digit() => {
                let mut text = String::new();
                while i < chars.len()
                    && (is_ident_continue(chars[i])
                        || (chars[i] == '.'
                            && chars.get(i + 1).is_some_and(|n| n.is_ascii_digit())
                            && !text.contains('.')))
                {
                    text.push(chars[i]);
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::Number,
                    text,
                    line: start_line,
                });
            }
            c if is_ident_start(c) => {
                let mut text = String::new();
                while i < chars.len() && is_ident_continue(chars[i]) {
                    text.push(chars[i]);
                    i += 1;
                }
                // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
                let next = chars.get(i).copied();
                if matches!(text.as_str(), "r" | "b" | "br")
                    && (next == Some('"') || (next == Some('#') && text != "b"))
                {
                    let (body, ni, nl) = scan_raw_or_byte_string(&chars, i, line, &text);
                    i = ni;
                    line = nl;
                    toks.push(Token {
                        kind: TokKind::Str,
                        text: body,
                        line: start_line,
                    });
                } else {
                    toks.push(Token {
                        kind: TokKind::Ident,
                        text,
                        line: start_line,
                    });
                }
            }
            other => {
                toks.push(Token {
                    kind: TokKind::Punct,
                    text: other.to_string(),
                    line: start_line,
                });
                i += 1;
            }
        }
    }
    toks
}

/// Scan a normal (escaped) string starting at the opening quote.
/// Returns (text-with-quotes, next-index, next-line).
fn scan_string(chars: &[char], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let mut text = String::from("\"");
    i += 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                text.push('\\');
                if let Some(&e) = chars.get(i + 1) {
                    text.push(e);
                    if e == '\n' {
                        line += 1;
                    }
                }
                i += 2;
            }
            '"' => {
                text.push('"');
                i += 1;
                break;
            }
            c => {
                if c == '\n' {
                    line += 1;
                }
                text.push(c);
                i += 1;
            }
        }
    }
    (text, i, line)
}

/// Scan a char literal starting at the opening quote.
fn scan_char(chars: &[char], mut i: usize) -> (String, usize) {
    let mut text = String::from("'");
    i += 1;
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                text.push('\\');
                if let Some(&e) = chars.get(i + 1) {
                    text.push(e);
                }
                i += 2;
            }
            '\'' => {
                text.push('\'');
                i += 1;
                break;
            }
            c => {
                text.push(c);
                i += 1;
            }
        }
    }
    (text, i)
}

/// Scan `r"…"`, `r#"…"#` (any hash count) or `b"…"` after its prefix
/// ident was consumed; `i` points at `"` or `#`.
fn scan_raw_or_byte_string(
    chars: &[char],
    mut i: usize,
    mut line: u32,
    prefix: &str,
) -> (String, usize, u32) {
    if prefix == "b" {
        // Byte string: normal escape rules.
        let (body, ni, nl) = scan_string(chars, i, line);
        return (format!("b{body}"), ni, nl);
    }
    let mut text = String::from(prefix);
    let mut hashes = 0usize;
    while chars.get(i) == Some(&'#') {
        text.push('#');
        hashes += 1;
        i += 1;
    }
    if chars.get(i) == Some(&'"') {
        text.push('"');
        i += 1;
        'outer: while i < chars.len() {
            if chars[i] == '"' {
                // Close only on `"` followed by the right number of `#`.
                let mut ok = true;
                for k in 0..hashes {
                    if chars.get(i + 1 + k) != Some(&'#') {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    text.push('"');
                    for _ in 0..hashes {
                        text.push('#');
                    }
                    i += 1 + hashes;
                    break 'outer;
                }
            }
            if chars[i] == '\n' {
                line += 1;
            }
            text.push(chars[i]);
            i += 1;
        }
    }
    (text, i, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src)
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn basic_sequence() {
        let toks = tokenize("let x = m.lock().unwrap();");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["let", "x", "m", "lock", "unwrap"]);
    }

    #[test]
    fn comments_are_trivia_with_lines() {
        let toks = tokenize("a\n// florida-lint: allow(x)\nb");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].kind, TokKind::LineComment);
        assert_eq!(toks[1].line, 2);
        assert!(toks[1].text.contains("florida-lint"));
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn block_comments_nest_and_count_lines() {
        let toks = tokenize("/* a /* b */\n c */ x");
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert_eq!(toks[1].text, "x");
        assert_eq!(toks[1].line, 2);
    }

    #[test]
    fn code_in_strings_is_not_code() {
        // A rule fixture quoting `.lock().unwrap()` must tokenize as one
        // Str, never as idents a rule could match.
        let toks = kinds(r#"let s = "m.lock().unwrap()";"#);
        assert!(toks
            .iter()
            .all(|(k, t)| *k != TokKind::Ident || t != "unwrap"));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t.contains("unwrap")));
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let toks = kinds(r##"r#"has "quotes" and lock()"# b"bytes" r"plain""##);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs.len(), 3, "{toks:?}");
        assert!(strs[0].contains("quotes"));
        assert!(strs[1].contains("bytes"));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes = toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
        let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn numbers_are_permissive() {
        let toks = kinds("0x1f 1_000 1e-5 2.5f64 0..4");
        let nums: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Number)
            .map(|(_, t)| t.as_str())
            .collect();
        // `0..4` must split into 0, ., ., 4 — not swallow the range.
        assert!(nums.contains(&"0x1f"));
        assert!(nums.contains(&"1_000"));
        assert!(nums.contains(&"0") && nums.contains(&"4"));
        assert!(!nums.iter().any(|n| n.contains("..")));
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let toks = tokenize("let a = \"x\ny\";\nlet b = 1;");
        let b = toks.iter().find(|t| t.ident("b")).unwrap();
        assert_eq!(b.line, 3);
    }
}
