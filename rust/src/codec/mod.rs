//! Wire codec: length-framed binary encoding (the "gRPC path") plus JSON
//! (the "REST path") for client-facing messages.
//!
//! The offline crate set has no protobuf/serde, so the platform defines a
//! compact hand-rolled binary format: little-endian fixed ints, LEB128
//! varints for lengths, raw LE f32 arrays for model payloads (bulk
//! memcpy — this is the hot path that carries flat parameter vectors).

use crate::error::{Error, Result};

/// Binary encoder.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Writer {
        Writer {
            buf: Vec::with_capacity(n),
        }
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint.
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                return;
            }
            self.buf.push(b | 0x80);
        }
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    /// Bulk f32 array (length-prefixed, LE) — model payload hot path.
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_varint(xs.len() as u64);
        self.buf.reserve(xs.len() * 4);
        // Safe bulk copy: f32 → LE bytes. On LE targets this is a memcpy.
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(target_endian = "big")]
        {
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Bulk u32 array (length-prefixed, LE) — masked-update hot path.
    pub fn put_u32s(&mut self, xs: &[u32]) {
        self.put_varint(xs.len() as u64);
        self.buf.reserve(xs.len() * 4);
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(target_endian = "big")]
        {
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Bulk f64 array (length-prefixed, LE) — leaf partial-sum payloads
    /// keep accumulator precision across the leaf→master hop.
    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_varint(xs.len() as u64);
        self.buf.reserve(xs.len() * 8);
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(target_endian = "big")]
        {
            for &x in xs {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Binary decoder over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Codec(format!(
                "short read: need {n}, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(Error::Codec(format!("bad bool byte {v}"))),
        }
    }

    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let b = self.get_u8()?;
            if shift >= 64 {
                return Err(Error::Codec("varint overflow".into()));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_varint()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b).map_err(|e| Error::Codec(format!("bad utf8: {e}")))
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_varint()? as usize;
        // Guard against hostile lengths before allocating.
        if n > self.remaining() / 4 {
            return Err(Error::Codec(format!("f32 array length {n} exceeds frame")));
        }
        let raw = self.take(n * 4)?;
        // §Perf: bulk copy (unaligned-safe) instead of per-element
        // from_le_bytes — this is the model-payload decode hot path.
        #[cfg(target_endian = "little")]
        {
            let mut out = vec![0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                out.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(out)
        }
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() / 4 {
            return Err(Error::Codec(format!("u32 array length {n} exceeds frame")));
        }
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            let mut out = vec![0u32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                out.push(u32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(out)
        }
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() / 8 {
            return Err(Error::Codec(format!("f64 array length {n} exceeds frame")));
        }
        let raw = self.take(n * 8)?;
        #[cfg(target_endian = "little")]
        {
            let mut out = vec![0f64; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 8,
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        {
            let mut out = Vec::with_capacity(n);
            for c in raw.chunks_exact(8) {
                out.push(f64::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(out)
        }
    }
}

/// A message that can cross the wire in the binary encoding.
pub trait Wire: Sized {
    fn encode(&self, w: &mut Writer);
    fn decode(r: &mut Reader) -> Result<Self>;

    fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.into_bytes()
    }

    fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut r = Reader::new(b);
        let v = Self::decode(&mut r)?;
        if !r.is_empty() {
            return Err(Error::Codec(format!("{} trailing bytes", r.remaining())));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u16(65500);
        w.put_u32(0xdeadbeef);
        w.put_u64(u64::MAX - 3);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_bool(true);
        w.put_str("héllo");
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 65500);
        assert_eq!(r.get_u32().unwrap(), 0xdeadbeef);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert!(r.is_empty());
    }

    #[test]
    fn varint_edge_cases() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut w = Writer::new();
            w.put_varint(v);
            let buf = w.into_bytes();
            assert_eq!(Reader::new(&buf).get_varint().unwrap(), v, "{v}");
        }
    }

    #[test]
    fn f32s_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 100.0).collect();
        let mut w = Writer::new();
        w.put_f32s(&xs);
        let buf = w.into_bytes();
        assert_eq!(Reader::new(&buf).get_f32s().unwrap(), xs);
    }

    #[test]
    fn u32s_roundtrip() {
        let xs: Vec<u32> = (0..777u32).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        let mut w = Writer::new();
        w.put_u32s(&xs);
        let buf = w.into_bytes();
        assert_eq!(Reader::new(&buf).get_u32s().unwrap(), xs);
    }

    #[test]
    fn f64s_roundtrip() {
        let xs: Vec<f64> = (0..321).map(|i| i as f64 * 0.25 - 40.0).collect();
        let mut w = Writer::new();
        w.put_f64s(&xs);
        let buf = w.into_bytes();
        assert_eq!(Reader::new(&buf).get_f64s().unwrap(), xs);
    }

    #[test]
    fn short_reads_error() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.get_u32().is_err());
        let mut r = Reader::new(&[]);
        assert!(r.get_u8().is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        // Claim a huge array but supply 4 bytes — must error, not OOM.
        let mut w = Writer::new();
        w.put_varint(u32::MAX as u64);
        w.put_u32(0);
        let buf = w.into_bytes();
        assert!(Reader::new(&buf).get_f32s().is_err());
        assert!(Reader::new(&buf).get_u32s().is_err());
        assert!(Reader::new(&buf).get_f64s().is_err());
    }

    #[test]
    fn bad_bool_rejected() {
        let mut r = Reader::new(&[9]);
        assert!(r.get_bool().is_err());
    }

    #[test]
    fn varint_overflow_rejected() {
        let buf = [0xffu8; 11];
        assert!(Reader::new(&buf).get_varint().is_err());
    }
}
