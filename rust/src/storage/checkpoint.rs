//! Model checkpoints: the durable image of one task at a committed
//! round boundary — config, lifecycle state, round counter, metrics
//! history, and the compressed model blob (the same bytes the
//! [`crate::model::SnapshotStore`] distribution cache hands to clients,
//! so a cache-warm checkpoint costs no extra zlib pass).
//!
//! Writes are atomic: encode to `<path>.tmp`, fsync, rename over the
//! final name, fsync the directory. A reader therefore sees either the
//! previous checkpoint or the new one, never a torn hybrid; a trailing
//! CRC32 catches bit rot and partial tmp files that survived a crash.

use std::io::Write as _;
use std::path::Path;

use crate::codec::{Reader, Wire, Writer};
use crate::config::{FsyncPolicy, TaskConfig};
use crate::error::{Error, Result};
use crate::metrics::{RoundRecord, TaskMetrics};
use crate::model::ModelSnapshot;
use crate::proto::{SelectionCriteria, TaskState};

use super::journal::crc32;
use super::CheckpointView;

const MAGIC: u32 = 0x464C_434B; // "FLCK"
const FORMAT: u32 = 1;

/// A loaded checkpoint (committed-round boundary image of one task).
pub struct Checkpoint {
    pub task_id: u64,
    pub config: TaskConfig,
    pub state: TaskState,
    pub round: u64,
    pub metrics: TaskMetrics,
    /// zlib-compressed [`ModelSnapshot`] (version + params).
    pub blob: Vec<u8>,
}

impl Checkpoint {
    pub fn model(&self) -> Result<ModelSnapshot> {
        ModelSnapshot::from_compressed(&self.blob)
    }
}

fn encode_metrics(w: &mut Writer, m: &TaskMetrics) {
    w.put_u64(m.failed_rounds);
    w.put_u64(m.total_uploads);
    w.put_varint(m.rounds.len() as u64);
    for r in &m.rounds {
        w.put_u64(r.round);
        w.put_u64(r.started_ms);
        w.put_u64(r.ended_ms);
        w.put_varint(r.participants as u64);
        w.put_f64(r.train_loss);
        for opt in [r.eval_loss, r.eval_accuracy, r.epsilon] {
            w.put_bool(opt.is_some());
            w.put_f64(opt.unwrap_or(0.0));
        }
    }
}

fn decode_metrics(r: &mut Reader) -> Result<TaskMetrics> {
    let failed_rounds = r.get_u64()?;
    let total_uploads = r.get_u64()?;
    let n = r.get_varint()? as usize;
    let mut rounds = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let round = r.get_u64()?;
        let started_ms = r.get_u64()?;
        let ended_ms = r.get_u64()?;
        let participants = r.get_varint()? as usize;
        let train_loss = r.get_f64()?;
        let mut opts = [None; 3];
        for o in opts.iter_mut() {
            let present = r.get_bool()?;
            let v = r.get_f64()?;
            *o = present.then_some(v);
        }
        rounds.push(RoundRecord {
            round,
            started_ms,
            ended_ms,
            participants,
            train_loss,
            eval_loss: opts[0],
            eval_accuracy: opts[1],
            epsilon: opts[2],
        });
    }
    Ok(TaskMetrics {
        rounds,
        failed_rounds,
        total_uploads,
    })
}

/// Atomically write `view` to `path` (temp file + rename).
pub fn write(path: &Path, view: &CheckpointView, fsync: FsyncPolicy) -> Result<()> {
    let mut w = Writer::new();
    w.put_u32(MAGIC);
    w.put_u32(FORMAT);
    w.put_u64(view.task_id);
    // Config travels as its JSON surface plus the wire-encoded selection
    // criteria (which the JSON surface does not carry).
    w.put_str(&view.config.to_json().to_string());
    w.put_bytes(&view.config.selection.to_bytes());
    w.put_u8(view.state as u8);
    w.put_u64(view.round);
    encode_metrics(&mut w, view.metrics);
    w.put_bytes(&view.store.compressed()?);
    let payload = w.into_bytes();
    let crc = crc32(&payload);

    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&payload)?;
        f.write_all(&crc.to_le_bytes())?;
        if fsync != FsyncPolicy::Never {
            f.sync_all()?;
        }
    }
    std::fs::rename(&tmp, path)?;
    if fsync != FsyncPolicy::Never {
        // Persist the rename itself. Directory fsync is a Unix-ism;
        // ignore failure on platforms that reject opening directories.
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Load and verify a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < 4 {
        return Err(Error::Codec(format!(
            "checkpoint {}: truncated ({} bytes)",
            path.display(),
            bytes.len()
        )));
    }
    let (payload, tail) = bytes.split_at(bytes.len() - 4);
    let crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(payload) != crc {
        return Err(Error::Codec(format!(
            "checkpoint {}: checksum mismatch",
            path.display()
        )));
    }
    let mut r = Reader::new(payload);
    if r.get_u32()? != MAGIC {
        return Err(Error::Codec(format!(
            "checkpoint {}: bad magic",
            path.display()
        )));
    }
    let format = r.get_u32()?;
    if format != FORMAT {
        return Err(Error::Codec(format!(
            "checkpoint {}: unsupported format {format}",
            path.display()
        )));
    }
    let task_id = r.get_u64()?;
    let mut config = TaskConfig::from_json_str(&r.get_str()?)?;
    config.selection = SelectionCriteria::from_bytes(&r.get_bytes()?)?;
    let state = TaskState::from_u8(r.get_u8()?)
        .ok_or_else(|| Error::Codec(format!("checkpoint {}: bad state", path.display())))?;
    let round = r.get_u64()?;
    let metrics = decode_metrics(&mut r)?;
    let blob = r.get_bytes()?;
    if !r.is_empty() {
        return Err(Error::Codec(format!(
            "checkpoint {}: {} trailing bytes",
            path.display(),
            r.remaining()
        )));
    }
    Ok(Checkpoint {
        task_id,
        config,
        state,
        round,
        metrics,
        blob,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::attest::IntegrityTier;
    use crate::model::SnapshotStore;
    use crate::util::TempDir;

    fn view<'a>(
        config: &'a TaskConfig,
        store: &'a SnapshotStore,
        metrics: &'a TaskMetrics,
    ) -> CheckpointView<'a> {
        CheckpointView {
            task_id: 42,
            config,
            state: TaskState::Running,
            round: 3,
            store,
            metrics,
        }
    }

    #[test]
    fn write_load_roundtrip_bit_for_bit() {
        let tmp = TempDir::new("ckpt").unwrap();
        let path = tmp.path().join("task-42.ckpt");
        let mut config = TaskConfig::default();
        config.selection.min_tier = IntegrityTier::Strong;
        config.selection.os_allow = vec!["android".into()];
        let store = SnapshotStore::new(ModelSnapshot::new(5, vec![0.25, -1.5, 3.0]));
        let mut metrics = TaskMetrics::default();
        metrics.failed_rounds = 2;
        metrics.total_uploads = 17;
        metrics.push(RoundRecord {
            round: 0,
            started_ms: 10,
            ended_ms: 30,
            participants: 4,
            train_loss: 0.5,
            eval_loss: Some(0.4),
            eval_accuracy: None,
            epsilon: Some(1.25),
        });
        write(&path, &view(&config, &store, &metrics), FsyncPolicy::Always).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.task_id, 42);
        assert_eq!(back.state, TaskState::Running);
        assert_eq!(back.round, 3);
        assert_eq!(back.config.selection.min_tier, IntegrityTier::Strong);
        assert_eq!(back.config.selection.os_allow, vec!["android".to_string()]);
        assert_eq!(back.metrics.failed_rounds, 2);
        assert_eq!(back.metrics.total_uploads, 17);
        assert_eq!(back.metrics.rounds.len(), 1);
        assert_eq!(back.metrics.rounds[0].eval_loss, Some(0.4));
        assert_eq!(back.metrics.rounds[0].eval_accuracy, None);
        let model = back.model().unwrap();
        assert_eq!(model.version, 5);
        assert_eq!(model.params, vec![0.25, -1.5, 3.0]);
    }

    #[test]
    fn rewrite_replaces_atomically() {
        let tmp = TempDir::new("ckpt").unwrap();
        let path = tmp.path().join("task-1.ckpt");
        let config = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![1.0]));
        write(&path, &view(&config, &store, &metrics), FsyncPolicy::Commit).unwrap();
        let mut store2 = SnapshotStore::new(ModelSnapshot::new(0, vec![1.0]));
        store2.apply_delta(&[1.0], 1.0).unwrap();
        write(&path, &view(&config, &store2, &metrics), FsyncPolicy::Commit).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model().unwrap().version, 1);
        // No tmp residue.
        assert!(!path.with_extension("ckpt.tmp").exists());
    }

    #[test]
    fn corruption_is_a_clean_error() {
        let tmp = TempDir::new("ckpt").unwrap();
        let path = tmp.path().join("task-9.ckpt");
        let config = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.5; 8]));
        write(&path, &view(&config, &store, &metrics), FsyncPolicy::Never).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        // Truncation too.
        std::fs::write(&path, &bytes[..3]).unwrap();
        assert!(load(&path).is_err());
    }
}
