//! Write-ahead round journal: an append-only log of orchestration
//! transitions, length-prefixed and CRC32-checksummed per record.
//!
//! On-disk frame: `[len: u32 LE][crc32(payload): u32 LE][payload]`,
//! payload being the [`JournalRecord`]'s `Wire` encoding. Replay
//! distinguishes two failure shapes:
//!
//! * a **torn tail** — the file ends mid-frame (crash during an append).
//!   Replay stops cleanly at the last complete record; this is the
//!   expected crash shape and not an error.
//! * **corruption** — a complete frame whose checksum does not match,
//!   a length prefix beyond [`MAX_RECORD_LEN`], or an undecodable
//!   payload. Replay returns a clean `Err`; silent data loss is never
//!   an option the recovery path takes by itself.

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

use crate::codec::{Reader, Wire, Writer};
use crate::config::FsyncPolicy;
use crate::error::{Error, Result};
use crate::proto::TaskState;

/// Upper bound on one record's payload; anything larger is corruption
/// (journal records are small control-plane facts, never model blobs).
pub const MAX_RECORD_LEN: usize = 1 << 24; // 16 MiB

/// One durable orchestration fact. The journal is the delta between the
/// last checkpoint and the crash point; model bytes live in checkpoints.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalRecord {
    /// Task registered (the initial checkpoint carries the model).
    TaskCreated { task_id: u64, config_json: String },
    /// Lifecycle state moved (start/pause/cancel/complete).
    StateChanged { task_id: u64, state: TaskState },
    /// A cohort formed and the round opened.
    RoundStarted {
        task_id: u64,
        round: u64,
        cohort: u64,
    },
    /// An upload was accepted into the round's streaming fold.
    UploadAccepted {
        task_id: u64,
        client_id: u64,
        round: u64,
        weight: f64,
        loss: f64,
    },
    /// The round aggregated; the checkpoint that follows carries the
    /// new model at `version`.
    RoundCommitted {
        task_id: u64,
        round: u64,
        version: u64,
    },
    /// The round was abandoned and will be retried.
    RoundFailed { task_id: u64, round: u64 },
    /// The task reached its final round.
    TaskCompleted { task_id: u64 },
    /// A checkpoint at `version` landed; every earlier record is
    /// absorbed. Appended between the checkpoint write and the journal
    /// truncation, so a crash in that window leaves a tail that replay
    /// can prove stale instead of double-counting it.
    Checkpointed { task_id: u64, version: u64 },
}

impl Wire for JournalRecord {
    fn encode(&self, w: &mut Writer) {
        match self {
            JournalRecord::TaskCreated {
                task_id,
                config_json,
            } => {
                w.put_u8(1);
                w.put_u64(*task_id);
                w.put_str(config_json);
            }
            JournalRecord::StateChanged { task_id, state } => {
                w.put_u8(2);
                w.put_u64(*task_id);
                w.put_u8(*state as u8);
            }
            JournalRecord::RoundStarted {
                task_id,
                round,
                cohort,
            } => {
                w.put_u8(3);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_u64(*cohort);
            }
            JournalRecord::UploadAccepted {
                task_id,
                client_id,
                round,
                weight,
                loss,
            } => {
                w.put_u8(4);
                w.put_u64(*task_id);
                w.put_u64(*client_id);
                w.put_u64(*round);
                w.put_f64(*weight);
                w.put_f64(*loss);
            }
            JournalRecord::RoundCommitted {
                task_id,
                round,
                version,
            } => {
                w.put_u8(5);
                w.put_u64(*task_id);
                w.put_u64(*round);
                w.put_u64(*version);
            }
            JournalRecord::RoundFailed { task_id, round } => {
                w.put_u8(6);
                w.put_u64(*task_id);
                w.put_u64(*round);
            }
            JournalRecord::TaskCompleted { task_id } => {
                w.put_u8(7);
                w.put_u64(*task_id);
            }
            JournalRecord::Checkpointed { task_id, version } => {
                w.put_u8(8);
                w.put_u64(*task_id);
                w.put_u64(*version);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<JournalRecord> {
        match r.get_u8()? {
            1 => Ok(JournalRecord::TaskCreated {
                task_id: r.get_u64()?,
                config_json: r.get_str()?,
            }),
            2 => Ok(JournalRecord::StateChanged {
                task_id: r.get_u64()?,
                state: TaskState::from_u8(r.get_u8()?)
                    .ok_or_else(|| Error::Codec("journal: bad task state".into()))?,
            }),
            3 => Ok(JournalRecord::RoundStarted {
                task_id: r.get_u64()?,
                round: r.get_u64()?,
                cohort: r.get_u64()?,
            }),
            4 => Ok(JournalRecord::UploadAccepted {
                task_id: r.get_u64()?,
                client_id: r.get_u64()?,
                round: r.get_u64()?,
                weight: r.get_f64()?,
                loss: r.get_f64()?,
            }),
            5 => Ok(JournalRecord::RoundCommitted {
                task_id: r.get_u64()?,
                round: r.get_u64()?,
                version: r.get_u64()?,
            }),
            6 => Ok(JournalRecord::RoundFailed {
                task_id: r.get_u64()?,
                round: r.get_u64()?,
            }),
            7 => Ok(JournalRecord::TaskCompleted {
                task_id: r.get_u64()?,
            }),
            8 => Ok(JournalRecord::Checkpointed {
                task_id: r.get_u64()?,
                version: r.get_u64()?,
            }),
            t => Err(Error::Codec(format!("journal: unknown record tag {t}"))),
        }
    }
}

// ---------------------------------------------------------------------
// CRC32 (IEEE, same polynomial as zlib) — table built at compile time.
// ---------------------------------------------------------------------

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_crc_table();

/// CRC32 (IEEE) over `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append-only journal writer over one task's log file.
pub struct WalJournal {
    file: File,
    fsync: FsyncPolicy,
}

impl WalJournal {
    /// Open a fresh (truncated) journal — new task.
    pub fn create(path: &Path, fsync: FsyncPolicy) -> Result<WalJournal> {
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok(WalJournal { file, fsync })
    }

    /// Open an existing journal for appending — recovery re-attach.
    pub fn open_append(path: &Path, fsync: FsyncPolicy) -> Result<WalJournal> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(WalJournal { file, fsync })
    }

    /// Append one record; under [`FsyncPolicy::Always`] the record is
    /// fsynced before this returns.
    pub fn append(&mut self, rec: &JournalRecord) -> Result<()> {
        let payload = rec.to_bytes();
        if payload.len() > MAX_RECORD_LEN {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("journal record too large: {} bytes", payload.len()),
            )));
        }
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        if self.fsync == FsyncPolicy::Always {
            self.file.sync_data()?;
        }
        Ok(())
    }

    /// Drop every record — called after a checkpoint has absorbed them.
    pub fn truncate(&mut self) -> Result<()> {
        self.file.set_len(0)?;
        // Rewind the cursor: without this, the next append on a
        // write-mode handle would land at the old offset and leave a
        // zero-filled hole that replay would reject as corruption.
        self.file.seek(SeekFrom::Start(0))?;
        if self.fsync != FsyncPolicy::Never {
            self.file.sync_data()?;
        }
        Ok(())
    }
}

/// Replay a journal file. A missing file is an empty journal; a torn
/// tail stops cleanly at the last complete record; corruption (bad
/// checksum on a complete frame, absurd length prefix, undecodable
/// payload) is a clean `Err` — never a panic, never a hang.
pub fn replay(path: &Path) -> Result<Vec<JournalRecord>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    let mut records = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let remaining = bytes.len() - pos;
        if remaining < 8 {
            log::warn!(
                "journal {}: torn tail ({remaining} trailing bytes) — stopping at record {}",
                path.display(),
                records.len()
            );
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if len > MAX_RECORD_LEN {
            return Err(Error::Codec(format!(
                "journal {}: record length {len} at offset {pos} exceeds {MAX_RECORD_LEN}",
                path.display()
            )));
        }
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if remaining - 8 < len {
            log::warn!(
                "journal {}: torn record at offset {pos} — stopping at record {}",
                path.display(),
                records.len()
            );
            break;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            return Err(Error::Codec(format!(
                "journal {}: checksum mismatch at offset {pos}",
                path.display()
            )));
        }
        records.push(JournalRecord::from_bytes(payload)?);
        pos += 8 + len;
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn sample_records() -> Vec<JournalRecord> {
        vec![
            JournalRecord::TaskCreated {
                task_id: 1,
                config_json: "{\"task_name\":\"t\"}".into(),
            },
            JournalRecord::StateChanged {
                task_id: 1,
                state: TaskState::Running,
            },
            JournalRecord::RoundStarted { task_id: 1, round: 0, cohort: 4 },
            JournalRecord::UploadAccepted {
                task_id: 1,
                client_id: 9,
                round: 0,
                weight: 2.5,
                loss: 0.125,
            },
            JournalRecord::RoundCommitted { task_id: 1, round: 0, version: 1 },
            JournalRecord::RoundFailed { task_id: 1, round: 1 },
            JournalRecord::TaskCompleted { task_id: 1 },
            JournalRecord::Checkpointed { task_id: 1, version: 1 },
        ]
    }

    #[test]
    fn append_replay_roundtrip() {
        let tmp = TempDir::new("journal").unwrap();
        let path = tmp.path().join("t.journal");
        let recs = sample_records();
        let mut j = WalJournal::create(&path, FsyncPolicy::Always).unwrap();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        assert_eq!(replay(&path).unwrap(), recs);
    }

    #[test]
    fn missing_file_is_empty_journal() {
        let tmp = TempDir::new("journal").unwrap();
        assert!(replay(&tmp.path().join("nope.journal")).unwrap().is_empty());
    }

    #[test]
    fn truncate_clears_records() {
        let tmp = TempDir::new("journal").unwrap();
        let path = tmp.path().join("t.journal");
        let mut j = WalJournal::create(&path, FsyncPolicy::Commit).unwrap();
        j.append(&JournalRecord::TaskCompleted { task_id: 3 }).unwrap();
        j.truncate().unwrap();
        j.append(&JournalRecord::RoundFailed { task_id: 3, round: 7 }).unwrap();
        drop(j);
        assert_eq!(
            replay(&path).unwrap(),
            vec![JournalRecord::RoundFailed { task_id: 3, round: 7 }]
        );
    }

    #[test]
    fn torn_tail_lands_on_last_complete_record() {
        let tmp = TempDir::new("journal").unwrap();
        let path = tmp.path().join("t.journal");
        let recs = sample_records();
        let mut j = WalJournal::create(&path, FsyncPolicy::Never).unwrap();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        let full = std::fs::read(&path).unwrap();
        // Chop 5 bytes off the end: the final frame is torn.
        let cut = tmp.path().join("cut.journal");
        std::fs::write(&cut, &full[..full.len() - 5]).unwrap();
        let got = replay(&cut).unwrap();
        assert_eq!(got, recs[..recs.len() - 1]);
    }

    #[test]
    fn flipped_checksum_is_a_clean_error() {
        let tmp = TempDir::new("journal").unwrap();
        let path = tmp.path().join("t.journal");
        let mut j = WalJournal::create(&path, FsyncPolicy::Never).unwrap();
        j.append(&JournalRecord::TaskCompleted { task_id: 1 }).unwrap();
        drop(j);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] ^= 0xFF; // first CRC byte
        std::fs::write(&path, bytes).unwrap();
        assert!(replay(&path).is_err());
    }

    #[test]
    fn absurd_length_prefix_is_a_clean_error() {
        let tmp = TempDir::new("journal").unwrap();
        let path = tmp.path().join("t.journal");
        // A complete 8-byte header claiming a 4 GiB record.
        let mut frame = Vec::new();
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, frame).unwrap();
        assert!(replay(&path).is_err());
        // A header shorter than 8 bytes is a torn tail, not corruption.
        std::fs::write(&path, u32::MAX.to_le_bytes()).unwrap();
        assert!(replay(&path).unwrap().is_empty());
    }

    #[test]
    fn crc32_known_value() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
