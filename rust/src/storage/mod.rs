//! Durability subsystem: write-ahead round journal, model checkpoints,
//! and crash recovery for the orchestrator.
//!
//! Florida's orchestrator is long-lived managed infrastructure (§3);
//! restart, upgrade and failover must be supported scenarios, not
//! data-loss events. Three parts:
//!
//! * [`journal::WalJournal`] — an append-only, length-prefixed +
//!   checksummed log of [`journal::JournalRecord`]s emitted by
//!   `RoundEngine` transitions through the [`Persistence`] trait
//!   ([`NoopPersistence`] keeps in-memory / simulator / bench paths
//!   zero-cost).
//! * [`checkpoint`] — on every round commit (and on graceful shutdown)
//!   the task's committed state — config, lifecycle state, round,
//!   metrics, and the compressed model blob — is written atomically via
//!   temp-file + rename, then the journal is truncated up to that
//!   version.
//! * [`recover`] — at boot, load the latest checkpoint per task and
//!   replay the journal tail to rebuild each engine at its last
//!   committed round boundary.
//!
//! **Invariant: in-flight rounds are failed-and-retried on recovery.**
//! Uploads stream into an O(dim) aggregation fold at arrival, and folds
//! are not replayable mid-round (the deltas are never retained), so a
//! round that was open at crash time is deliberately abandoned: the
//! recovered engine re-enters `Joining` at the same round number,
//! `failed_rounds` is incremented, and clients simply rejoin and retry.
//! Committed state is never lost: the checkpoint ordering (journal
//! commit record → checkpoint write → journal truncate, with the
//! checkpoint rename atomic) guarantees recovery always lands on a
//! fully-committed model version.

pub mod checkpoint;
pub mod journal;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{FsyncPolicy, StorageConfig, TaskConfig};
use crate::error::Result;
use crate::metrics::TaskMetrics;
use crate::model::SnapshotStore;
use crate::obs::Telemetry;
use crate::proto::TaskState;

pub use checkpoint::Checkpoint;
pub use journal::{JournalRecord, WalJournal};

/// Borrowed image of one task at a persistence point. `round` is the
/// *next* round (the committed-round boundary the engine sits at).
pub struct CheckpointView<'a> {
    pub task_id: u64,
    pub config: &'a TaskConfig,
    pub state: TaskState,
    pub round: u64,
    pub store: &'a SnapshotStore,
    pub metrics: &'a TaskMetrics,
}

/// Durability hooks called by `RoundEngine` transition methods. The
/// default [`NoopPersistence`] makes every hook free, so simulator and
/// bench paths pay nothing for the seam.
pub trait Persistence: Send {
    /// New task registered: write the initial checkpoint, then the
    /// journal birth record.
    fn task_created(&mut self, view: &CheckpointView) -> Result<()>;
    /// Lifecycle state moved (start/pause/cancel/complete).
    fn state_changed(&mut self, state: TaskState) -> Result<()>;
    /// A cohort formed and the round opened.
    fn round_started(&mut self, round: u64, cohort: usize) -> Result<()>;
    /// An upload was accepted into the round's streaming fold.
    fn upload_accepted(&mut self, client_id: u64, round: u64, weight: f64, loss: f64) -> Result<()>;
    /// The round was abandoned (will be retried).
    fn round_failed(&mut self, round: u64) -> Result<()>;
    /// `round` committed: journal the commit, checkpoint, truncate.
    fn round_committed(&mut self, round: u64, view: &CheckpointView) -> Result<()>;
    /// Checkpoint the committed boundary without a commit record
    /// (graceful shutdown, admin-forced checkpoint).
    fn checkpoint(&mut self, view: &CheckpointView) -> Result<()>;
    /// Inject the shared instrument registry (journal/checkpoint
    /// latency, fsync count). Default: ignore — `NoopPersistence` and
    /// test doubles stay instrumentation-free.
    fn set_telemetry(&mut self, _telemetry: Arc<Telemetry>) {}
}

/// Default persistence: everything is a no-op (in-memory deployments).
pub struct NoopPersistence;

impl Persistence for NoopPersistence {
    fn task_created(&mut self, _view: &CheckpointView) -> Result<()> {
        Ok(())
    }
    fn state_changed(&mut self, _state: TaskState) -> Result<()> {
        Ok(())
    }
    fn round_started(&mut self, _round: u64, _cohort: usize) -> Result<()> {
        Ok(())
    }
    fn upload_accepted(
        &mut self,
        _client_id: u64,
        _round: u64,
        _weight: f64,
        _loss: f64,
    ) -> Result<()> {
        Ok(())
    }
    fn round_failed(&mut self, _round: u64) -> Result<()> {
        Ok(())
    }
    fn round_committed(&mut self, _round: u64, _view: &CheckpointView) -> Result<()> {
        Ok(())
    }
    fn checkpoint(&mut self, _view: &CheckpointView) -> Result<()> {
        Ok(())
    }
}

/// Checkpoint path for one task under `state_dir`.
pub fn ckpt_path(state_dir: &Path, task_id: u64) -> PathBuf {
    state_dir.join(format!("task-{task_id}.ckpt"))
}

/// Journal path for one task under `state_dir`.
pub fn journal_path(state_dir: &Path, task_id: u64) -> PathBuf {
    state_dir.join(format!("task-{task_id}.journal"))
}

/// File-backed persistence for one task: a WAL journal plus an
/// atomically-replaced checkpoint, both under the service `state_dir`.
pub struct FilePersistence {
    task_id: u64,
    ckpt: PathBuf,
    journal: WalJournal,
    fsync: FsyncPolicy,
    /// Shared instrument registry (None until injected — recovery-path
    /// persistence created before assembly runs uninstrumented).
    telemetry: Option<Arc<Telemetry>>,
}

/// Elapsed nanos from a wall-clock mark, saturating at `u64::MAX`.
fn elapsed_ns(t0: &std::time::Instant) -> u64 {
    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

impl FilePersistence {
    /// Fresh task: truncates any stale journal for this id.
    pub fn create(storage: &StorageConfig, task_id: u64) -> Result<FilePersistence> {
        Ok(FilePersistence {
            task_id,
            ckpt: ckpt_path(&storage.state_dir, task_id),
            journal: WalJournal::create(&journal_path(&storage.state_dir, task_id), storage.fsync)?,
            fsync: storage.fsync,
            telemetry: None,
        })
    }

    /// Recovery re-attach: append to the surviving journal.
    pub fn attach(storage: &StorageConfig, task_id: u64) -> Result<FilePersistence> {
        Ok(FilePersistence {
            task_id,
            ckpt: ckpt_path(&storage.state_dir, task_id),
            journal: WalJournal::open_append(
                &journal_path(&storage.state_dir, task_id),
                storage.fsync,
            )?,
            fsync: storage.fsync,
            telemetry: None,
        })
    }

    /// Journal append with latency + fsync-barrier accounting. Disk
    /// latency is inherently wall time — the sanctioned exception to
    /// the no-wall-clock rule, scoped to the line below.
    fn timed_append(&mut self, rec: &JournalRecord) -> Result<()> {
        // florida-lint: allow(wall-clock-in-core): disk latency is wall time
        let t0 = std::time::Instant::now();
        let r = self.journal.append(rec);
        if let Some(t) = &self.telemetry {
            t.journal_append_ns.record(elapsed_ns(&t0));
            if self.fsync == FsyncPolicy::Always {
                t.fsyncs.inc();
            }
        }
        r
    }

    /// Checkpoint write with latency + fsync-barrier accounting (same
    /// wall-time exception as `timed_append`).
    fn timed_ckpt_write(&mut self, view: &CheckpointView) -> Result<()> {
        // florida-lint: allow(wall-clock-in-core): disk latency is wall time
        let t0 = std::time::Instant::now();
        let r = checkpoint::write(&self.ckpt, view, self.fsync);
        if let Some(t) = &self.telemetry {
            t.checkpoint_write_ns.record(elapsed_ns(&t0));
            if self.fsync != FsyncPolicy::Never {
                // Two durability barriers: checkpoint file + parent dir.
                t.fsyncs.add(2);
            }
        }
        r
    }
}

impl Persistence for FilePersistence {
    fn task_created(&mut self, view: &CheckpointView) -> Result<()> {
        // Checkpoint first: a task is recoverable iff its checkpoint
        // landed; the journal record is the birth marker after it.
        self.timed_ckpt_write(view)?;
        self.timed_append(&JournalRecord::TaskCreated {
            task_id: self.task_id,
            config_json: view.config.to_json().to_string(),
        })
    }

    fn state_changed(&mut self, state: TaskState) -> Result<()> {
        self.timed_append(&JournalRecord::StateChanged {
            task_id: self.task_id,
            state,
        })?;
        if state == TaskState::Completed {
            // Explicit terminal marker: a journal tail ending in
            // TaskCompleted is unambiguous even if the final commit's
            // checkpoint never lands.
            self.timed_append(&JournalRecord::TaskCompleted {
                task_id: self.task_id,
            })?;
        }
        Ok(())
    }

    fn round_started(&mut self, round: u64, cohort: usize) -> Result<()> {
        self.timed_append(&JournalRecord::RoundStarted {
            task_id: self.task_id,
            round,
            cohort: cohort as u64,
        })
    }

    fn upload_accepted(
        &mut self,
        client_id: u64,
        round: u64,
        weight: f64,
        loss: f64,
    ) -> Result<()> {
        self.timed_append(&JournalRecord::UploadAccepted {
            task_id: self.task_id,
            client_id,
            round,
            weight,
            loss,
        })
    }

    fn round_failed(&mut self, round: u64) -> Result<()> {
        self.timed_append(&JournalRecord::RoundFailed {
            task_id: self.task_id,
            round,
        })
    }

    fn round_committed(&mut self, round: u64, view: &CheckpointView) -> Result<()> {
        // Commit record first: if the checkpoint write below crashes
        // mid-way, recovery sees a commit the checkpoint doesn't cover
        // and retries that round instead of silently losing it.
        self.timed_append(&JournalRecord::RoundCommitted {
            task_id: self.task_id,
            round,
            version: view.store.version,
        })?;
        Persistence::checkpoint(self, view)
    }

    fn checkpoint(&mut self, view: &CheckpointView) -> Result<()> {
        self.timed_ckpt_write(view)?;
        // Marker before truncation: if the truncate below never lands
        // (crash), replay sees the marker and discards the stale tail
        // instead of double-counting records the checkpoint absorbed.
        self.timed_append(&JournalRecord::Checkpointed {
            task_id: self.task_id,
            version: view.store.version,
        })?;
        self.journal.truncate()?;
        if self.fsync != FsyncPolicy::Never {
            if let Some(t) = &self.telemetry {
                // The truncate's own durability barrier.
                t.fsyncs.inc();
            }
        }
        Ok(())
    }

    fn set_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        self.telemetry = Some(telemetry);
    }
}

/// One task rebuilt from its checkpoint + journal tail.
pub struct RecoveredTask {
    pub task_id: u64,
    pub config: TaskConfig,
    /// Model store seeded with the checkpoint blob (cache-warm: the
    /// first post-recovery poll is an `Arc` clone, not a zlib pass).
    pub store: SnapshotStore,
    pub state: TaskState,
    pub round: u64,
    pub metrics: TaskMetrics,
    /// A round that was open at crash time — the caller must fail and
    /// retry it (streaming folds are not replayable mid-round).
    pub interrupted_round: Option<u64>,
}

/// Recovery sweep: load every `task-N.ckpt` under `state_dir`, replay
/// each journal tail, and return the tasks at their last committed
/// round boundary (sorted by task id). A missing/empty dir recovers
/// zero tasks; a corrupt checkpoint or journal is a clean `Err` —
/// operator intervention beats silent data loss.
pub fn recover(state_dir: &Path) -> Result<Vec<RecoveredTask>> {
    let entries = match std::fs::read_dir(state_dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let id: u64 = match name
            .strip_prefix("task-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse().ok())
        {
            Some(id) => id,
            None => continue, // journals, tmp residue, unrelated files
        };
        let ckpt = checkpoint::load(&entry.path())?;
        let store = SnapshotStore::from_blob(ckpt.blob)?;
        let mut state = ckpt.state;
        let mut metrics = ckpt.metrics;
        // Tail effects are accumulated as deltas so a `Checkpointed`
        // marker (checkpoint landed, truncate lost) can discard them.
        let mut uploads_delta = 0u64;
        let mut failed_delta = 0u64;
        let mut open_round = None;
        for rec in journal::replay(&journal_path(state_dir, id))? {
            match rec {
                JournalRecord::TaskCreated { .. } => {}
                JournalRecord::StateChanged { state: s, .. } => state = s,
                JournalRecord::RoundStarted { round, .. } => {
                    if round >= ckpt.round {
                        open_round = Some(round);
                    }
                }
                JournalRecord::UploadAccepted { round, .. } => {
                    if round >= ckpt.round {
                        // Async buffers have no RoundStarted marker; an
                        // upload at the current round opens it too.
                        uploads_delta += 1;
                        open_round = Some(round);
                    }
                }
                JournalRecord::RoundCommitted { round, version, .. } => {
                    if version > store.version {
                        // The commit record landed but the checkpoint
                        // never did: the committed model is lost. Fail
                        // and retry the round from the last durable
                        // version rather than losing it silently.
                        log::warn!(
                            "task {id}: journal records round {round} committed at version \
                             {version} but the checkpoint holds version {} — retrying the round",
                            store.version
                        );
                        open_round = Some(round);
                    } else {
                        open_round = None;
                    }
                }
                JournalRecord::RoundFailed { round, .. } => {
                    if round >= ckpt.round {
                        failed_delta += 1;
                    }
                    open_round = None;
                }
                JournalRecord::TaskCompleted { .. } => state = TaskState::Completed,
                JournalRecord::Checkpointed { version, .. } => {
                    if version <= store.version {
                        // A checkpoint at least as new as the one we
                        // loaded absorbed everything before this marker
                        // (the truncate that should have followed it was
                        // lost). Discard the stale prefix.
                        state = ckpt.state;
                        uploads_delta = 0;
                        failed_delta = 0;
                        open_round = None;
                    } else {
                        log::warn!(
                            "task {id}: journal marks a checkpoint at version {version} but the \
                             loaded checkpoint holds version {} — proceeding from the older one",
                            store.version
                        );
                    }
                }
            }
        }
        metrics.total_uploads += uploads_delta;
        metrics.failed_rounds += failed_delta;
        // Completion is durable only through its checkpoint: the engine
        // journals Completed and then immediately checkpoints-and-
        // truncates, so a surviving tail that says Completed while the
        // loaded checkpoint doesn't means the final commit's checkpoint
        // never landed (crash before or after its RoundCommitted
        // append). Reopen the task so the final round is retried
        // instead of silently dropping its model update.
        if state == TaskState::Completed && ckpt.state != TaskState::Completed {
            log::warn!(
                "task {id}: journaled completion has no durable checkpoint — reopening to retry \
                 the final round"
            );
            state = TaskState::Running;
        }
        let interrupted_round = if state == TaskState::Running {
            open_round
        } else {
            None
        };
        out.push(RecoveredTask {
            task_id: id,
            config: ckpt.config,
            store,
            state,
            round: ckpt.round,
            metrics,
            interrupted_round,
        });
    }
    out.sort_by_key(|t| t.task_id);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsyncPolicy;
    use crate::model::ModelSnapshot;
    use crate::util::TempDir;

    fn storage(tmp: &TempDir) -> StorageConfig {
        StorageConfig::new(tmp.path()).fsync(FsyncPolicy::Commit)
    }

    fn view<'a>(
        task_id: u64,
        config: &'a TaskConfig,
        store: &'a SnapshotStore,
        metrics: &'a TaskMetrics,
        state: TaskState,
        round: u64,
    ) -> CheckpointView<'a> {
        CheckpointView {
            task_id,
            config,
            state,
            round,
            store,
            metrics,
        }
    }

    #[test]
    fn recover_empty_or_missing_dir() {
        let tmp = TempDir::new("storage").unwrap();
        assert!(recover(tmp.path()).unwrap().is_empty());
        assert!(recover(&tmp.path().join("nope")).unwrap().is_empty());
    }

    #[test]
    fn commit_checkpoint_truncates_journal_and_recovers_clean() {
        let tmp = TempDir::new("storage").unwrap();
        let cfg = storage(&tmp);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let mut store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0; 4]));

        let mut p = FilePersistence::create(&cfg, 1).unwrap();
        p.task_created(&view(1, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 4).unwrap();
        p.upload_accepted(7, 0, 1.0, 0.5).unwrap();
        store.apply_delta(&[1.0; 4], 1.0).unwrap();
        p.round_committed(0, &view(1, &task_cfg, &store, &metrics, TaskState::Running, 1))
            .unwrap();
        drop(p);

        // Journal truncated by the commit checkpoint.
        assert_eq!(journal::replay(&journal_path(tmp.path(), 1)).unwrap(), vec![]);
        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks.len(), 1);
        let t = &tasks[0];
        assert_eq!(t.task_id, 1);
        assert_eq!(t.round, 1);
        assert_eq!(t.state, TaskState::Running);
        assert_eq!(t.store.version, 1);
        assert_eq!(t.store.params, vec![1.0; 4]);
        assert!(t.interrupted_round.is_none());
        // Cache-warm: the first poll must not recompress.
        let _ = t.store.compressed().unwrap();
        assert_eq!(t.store.compressions(), 0);
    }

    #[test]
    fn in_flight_round_is_flagged_for_retry() {
        let tmp = TempDir::new("storage").unwrap();
        let cfg = storage(&tmp);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0; 2]));

        let mut p = FilePersistence::create(&cfg, 3).unwrap();
        p.task_created(&view(3, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 2).unwrap();
        p.upload_accepted(1, 0, 1.0, 0.3).unwrap();
        drop(p); // crash mid-round

        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].interrupted_round, Some(0));
        assert_eq!(tasks[0].round, 0);
        assert_eq!(tasks[0].metrics.total_uploads, 1);
    }

    #[test]
    fn commit_record_without_checkpoint_retries_the_round() {
        // Crash between the journal commit record and the checkpoint
        // write: the committed model is gone; the round must be retried
        // from the last durable version, loudly.
        let tmp = TempDir::new("storage").unwrap();
        let cfg = storage(&tmp);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0; 2]));

        let mut p = FilePersistence::create(&cfg, 2).unwrap();
        p.task_created(&view(2, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 2).unwrap();
        drop(p);
        // Simulate the torn commit: record appended, checkpoint missing.
        let mut j =
            WalJournal::open_append(&journal_path(tmp.path(), 2), FsyncPolicy::Never).unwrap();
        j.append(&JournalRecord::RoundCommitted { task_id: 2, round: 0, version: 1 }).unwrap();
        drop(j);

        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].store.version, 0, "last durable version");
        assert_eq!(tasks[0].interrupted_round, Some(0));
    }

    #[test]
    fn lost_final_commit_reopens_a_completed_task() {
        // The terminal crash window: the journal records the final
        // round's commit and the Completed transition, but the
        // checkpoint never lands. The completion rode the lost commit,
        // so recovery must reopen the task and retry the round.
        let tmp = TempDir::new("storage").unwrap();
        let cfg = storage(&tmp);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0]));

        let mut p = FilePersistence::create(&cfg, 4).unwrap();
        p.task_created(&view(4, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 1).unwrap();
        p.state_changed(TaskState::Completed).unwrap();
        drop(p); // crash window A: before the RoundCommitted append

        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].state, TaskState::Running, "completion was not durable");
        assert_eq!(tasks[0].store.version, 0);
        assert_eq!(tasks[0].interrupted_round, Some(0), "the final round retries");

        // Crash window B: the RoundCommitted record landed too, but the
        // checkpoint for version 1 still didn't. Same outcome.
        let mut j =
            WalJournal::open_append(&journal_path(tmp.path(), 4), FsyncPolicy::Never).unwrap();
        j.append(&JournalRecord::RoundCommitted { task_id: 4, round: 0, version: 1 }).unwrap();
        drop(j);
        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].state, TaskState::Running, "completion was not durable");
        assert_eq!(tasks[0].store.version, 0);
        assert_eq!(tasks[0].interrupted_round, Some(0), "the final round retries");
    }

    #[test]
    fn stale_tail_after_lost_truncate_is_discarded() {
        // Crash window: checkpoint + marker landed, truncate didn't.
        // The tail before the marker was absorbed by the checkpoint and
        // must not be double-counted or flagged as an in-flight round.
        let tmp = TempDir::new("storage").unwrap();
        let task_cfg = TaskConfig::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0]));
        let mut metrics = TaskMetrics::default();
        metrics.total_uploads = 1; // the checkpoint already counts it
        checkpoint::write(
            &ckpt_path(tmp.path(), 6),
            &view(6, &task_cfg, &store, &metrics, TaskState::Running, 0),
            FsyncPolicy::Never,
        )
        .unwrap();
        let jpath = journal_path(tmp.path(), 6);
        let mut j = WalJournal::create(&jpath, FsyncPolicy::Never).unwrap();
        j.append(&JournalRecord::RoundStarted { task_id: 6, round: 0, cohort: 1 }).unwrap();
        j.append(&JournalRecord::UploadAccepted {
            task_id: 6,
            client_id: 1,
            round: 0,
            weight: 1.0,
            loss: 0.1,
        })
        .unwrap();
        j.append(&JournalRecord::Checkpointed { task_id: 6, version: 0 }).unwrap();
        drop(j);

        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].metrics.total_uploads, 1, "absorbed upload not recounted");
        assert_eq!(tasks[0].metrics.failed_rounds, 0);
        assert!(tasks[0].interrupted_round.is_none(), "marker proves it was absorbed");

        // Genuine records after the marker still count.
        let mut j = WalJournal::open_append(&jpath, FsyncPolicy::Never).unwrap();
        j.append(&JournalRecord::UploadAccepted {
            task_id: 6,
            client_id: 2,
            round: 0,
            weight: 1.0,
            loss: 0.2,
        })
        .unwrap();
        drop(j);
        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].metrics.total_uploads, 2);
        assert_eq!(tasks[0].interrupted_round, Some(0));
    }

    #[test]
    fn completed_tasks_recover_without_retry() {
        // A real completion is immediately absorbed by its commit
        // checkpoint (state Completed); recovery must not reopen it.
        let tmp = TempDir::new("storage").unwrap();
        let cfg = storage(&tmp);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(1, vec![0.5]));

        let mut p = FilePersistence::create(&cfg, 5).unwrap();
        p.task_created(&view(5, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 1).unwrap();
        p.state_changed(TaskState::Completed).unwrap();
        p.round_committed(0, &view(5, &task_cfg, &store, &metrics, TaskState::Completed, 1))
            .unwrap();
        drop(p);

        let tasks = recover(tmp.path()).unwrap();
        assert_eq!(tasks[0].state, TaskState::Completed);
        assert_eq!(tasks[0].round, 1);
        assert!(tasks[0].interrupted_round.is_none());
    }

    #[test]
    fn file_persistence_reports_latency_and_fsync_barriers() {
        let tmp = TempDir::new("storage-obs").unwrap();
        let cfg = StorageConfig::new(tmp.path()).fsync(FsyncPolicy::Always);
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0; 2]));

        let t = Arc::new(Telemetry::new());
        let mut p = FilePersistence::create(&cfg, 9).unwrap();
        p.set_telemetry(Arc::clone(&t));
        p.task_created(&view(9, &task_cfg, &store, &metrics, TaskState::Created, 0))
            .unwrap();
        p.round_started(0, 1).unwrap();
        p.round_committed(0, &view(9, &task_cfg, &store, &metrics, TaskState::Running, 1))
            .unwrap();

        // 4 appends (created, started, committed, ckpt marker), 2
        // checkpoint writes (birth + commit).
        assert_eq!(t.journal_append_ns.snapshot().count, 4);
        assert_eq!(t.checkpoint_write_ns.snapshot().count, 2);
        // Always: 4 append barriers + 2×2 checkpoint + 1 truncate.
        assert_eq!(t.fsyncs.get(), 9);
    }

    #[test]
    fn noop_persistence_is_free_and_infallible() {
        let mut p = NoopPersistence;
        let task_cfg = TaskConfig::default();
        let metrics = TaskMetrics::default();
        let store = SnapshotStore::new(ModelSnapshot::new(0, vec![0.0]));
        let v = view(1, &task_cfg, &store, &metrics, TaskState::Running, 0);
        p.task_created(&v).unwrap();
        p.state_changed(TaskState::Running).unwrap();
        p.round_started(0, 1).unwrap();
        p.upload_accepted(1, 0, 1.0, 0.0).unwrap();
        p.round_failed(0).unwrap();
        p.round_committed(0, &v).unwrap();
        p.checkpoint(&v).unwrap();
    }
}
