//! Command-line interface (§3.3: "a command-line interface for scripting
//! service and workflow management").
//!
//! Hand-rolled arg parsing (no clap in the offline crate set). Commands:
//!
//! ```text
//! florida serve     --addr HOST:PORT [--task cfg.json] [--artifacts DIR] [--no-attest]
//! florida run-sim   [--preset tiny] [--devices 32] [--rounds 10] [--dp]
//!                   [--async N] [--secagg] [--artifacts DIR] [--csv out.csv]
//! florida status    --addr HOST:PORT --task-id N
//! florida dp-plan   [--q 0.32] [--sigma 0.08] [--rounds 10] [--delta 1e-5]
//! florida scale     [--clients 256] [--rounds 3]
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use crate::client::FloridaClient;
use crate::config::{FsyncPolicy, Manifest, StorageConfig, TaskConfig};
use crate::dp::{DpConfig, DpMode, RdpAccountant};
use crate::error::{Error, Result};
use crate::model::ModelSnapshot;
use crate::obs::export::{FORMAT_JSON, FORMAT_PROMETHEUS};
use crate::orchestrator::{TaskBuilder, TaskEvent};
use crate::proto::{TaskState, WireCodec};
use crate::services::management::NoEval;
use crate::services::FloridaServer;
use crate::simulator::spam::{run_spam, SpamRunConfig};
use crate::transport::tcp::{TcpDialer, TcpTransportListener};
use crate::transport::Listener as _;
use crate::util::ThreadPool;

/// Parsed command line: subcommand + flags.
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `--key value` pairs and bare `--switch`es.
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            return Err(Error::Config("no subcommand (try `florida help`)".into()));
        }
        let command = argv[0].clone();
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.push(key.to_string());
                    i += 1;
                }
            } else {
                return Err(Error::Config(format!("unexpected argument {a:?}")));
            }
        }
        Ok(Args {
            command,
            flags,
            switches,
        })
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

pub const HELP: &str = "\
Project Florida — federated learning platform (reproduction)

USAGE: florida <command> [flags]

COMMANDS:
  run-sim    Run the §5.1 spam-classification FL simulation end to end
             [--preset tiny|micro] [--devices N] [--clients-per-round N]
             [--rounds N] [--dp] [--secagg] [--async BUF] [--non-iid A]
             [--artifacts DIR] [--csv FILE] [--seed N]
  scale      Run the §5.2 dummy-task scaling point
             [--clients N] [--rounds N] [--seed N]
             [--churn-restart [--kill-after N] [--state-dir DIR]]
             [--device-mix [--telemetry-file FILE]]  mixed-tier
             population under the Tiered policy: stragglers drop
             mid-round, leases expire, cohort slots are backfilled;
             reports per-tier participation plus the per-round phase
             breakdown from the telemetry registry; --telemetry-file
             snapshots the full JSON export to disk
             [--tree depth=2 --leaves N]  hierarchical aggregation:
             leaf aggregators fold their cohort slices and forward one
             partial each; verifies bit-identity against the flat path
             [--byzantine F]  adversarial fleet: fraction F of clients
             attack (magnitude-bomb / sign-flip / label-flip); sweeps
             loss-vs-f for fedavg vs trimmed-mean/median and proves the
             admission policy engine sheds a misbehaving client
             [--shards N [--sessions M]]  sharded data plane: M
             simulated sessions (default 2^20) hammer poll/upload at
             1 vs N shards with the same thread count, then the
             N-shard partial-merge commit is proved bit-identical to
             the flat fold; gates on >= 0.7x-linear throughput scaling
  serve      Serve the platform over TCP
             --addr HOST:PORT [--task cfg.json] [--artifacts DIR]
             [--dim N] [--no-attest] [--conns N] [--lease-ms N]
             [--shards N]  partition sessions/policy/ingest instruments
             across N data-plane shards (default 1, bit-identical)
             [--state-dir DIR [--fsync always|commit|never]]
             [--telemetry-file FILE]
             With --state-dir, tasks journal + checkpoint there and are
             recovered at the next boot; 'q' + Enter checkpoints
             everything and exits gracefully (stdin EOF is ignored, so
             detached servers keep serving). A hard kill is also safe:
             the write-ahead journal covers the tail.
             Console: 'telemetry' prints the Prometheus exposition,
             'telemetry json' the JSON export; --telemetry-file writes
             the JSON snapshot at graceful exit. The same data is
             served remotely via the get_telemetry RPC.
  status     Query a served task
             --addr HOST:PORT --task-id N [--json]
  dp-plan    Privacy accounting for a task design
             [--q RATE] [--sigma S] [--rounds N] [--delta D]
  lint       Run the repo-aware static-analysis rules over rust/src
             [--root DIR] [--baseline] [--baseline-file FILE]
             [--write-baseline]
             --baseline grandfathers the committed lint.baseline counts
             (what CI runs); --write-baseline regenerates that file —
             use it only to shrink counts, never to admit new findings
  help       This text
";

/// Entry point used by main.rs; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            println!("{HELP}");
            return Err(e);
        }
    };
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "run-sim" => cmd_run_sim(&args),
        "scale" => cmd_scale(&args),
        "serve" => cmd_serve(&args),
        "status" => cmd_status(&args),
        "dp-plan" => cmd_dp_plan(&args),
        "lint" => cmd_lint(&args),
        other => {
            println!("{HELP}");
            Err(Error::Config(format!("unknown command {other:?}")))
        }
    }
}

fn cmd_run_sim(args: &Args) -> Result<()> {
    let mut cfg = SpamRunConfig::default();
    cfg.artifacts_dir = args.flag_or("artifacts", "artifacts");
    cfg.preset = args.flag_or("preset", "tiny");
    cfg.n_devices = args.usize_or("devices", 32)?;
    cfg.clients_per_round = args.usize_or("clients-per-round", cfg.n_devices.min(32))?;
    cfg.rounds = args.usize_or("rounds", 10)? as u64;
    cfg.seed = args.usize_or("seed", 1234)? as u64;
    cfg.secure_agg = args.switch("secagg");
    if args.switch("dp") {
        cfg.dp = DpConfig::paper_local();
    }
    if let Some(buf) = args.flag("async") {
        cfg.async_buffer = Some(
            buf.parse()
                .map_err(|_| Error::Config("--async expects buffer size".into()))?,
        );
    }
    if let Some(a) = args.flag("non-iid") {
        cfg.non_iid_alpha = Some(
            a.parse()
                .map_err(|_| Error::Config("--non-iid expects alpha".into()))?,
        );
    }
    println!(
        "run-sim: preset={} devices={} rounds={} dp={:?} secagg={} async={:?}",
        cfg.preset, cfg.n_devices, cfg.rounds, cfg.dp.mode, cfg.secure_agg, cfg.async_buffer
    );
    let result = run_spam(&cfg)?;
    println!(
        "\nround  participants  duration(ms)  train-loss  eval-acc  epsilon"
    );
    for r in &result.rounds {
        println!(
            "{:>5}  {:>12}  {:>12}  {:>10.4}  {:>8}  {:>7}",
            r.round,
            r.participants,
            r.duration_ms(),
            r.train_loss,
            r.eval_accuracy
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "-".into()),
            r.epsilon
                .map(|e| format!("{e:.3}"))
                .unwrap_or_else(|| "-".into()),
        );
    }
    println!(
        "\nfinal accuracy {:.4} | mean round {:.0} ms | wall {} ms | failed rounds {}",
        result.final_accuracy, result.mean_round_ms, result.total_wall_ms, result.failed_rounds
    );
    if let Some(csv) = args.flag("csv") {
        let mut text = String::from(
            "round,duration_ms,participants,train_loss,eval_accuracy,epsilon\n",
        );
        for r in &result.rounds {
            text.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.round,
                r.duration_ms(),
                r.participants,
                r.train_loss,
                r.eval_accuracy.unwrap_or(f64::NAN),
                r.epsilon.unwrap_or(f64::NAN)
            ));
        }
        std::fs::write(csv, text)?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<()> {
    let n = args.usize_or("clients", 256)?;
    let rounds = args.usize_or("rounds", 3)? as u64;
    let seed = args.usize_or("seed", 7)? as u64;
    if args.flag("shards").is_some() {
        // Sharded data plane: hammer the hot-path primitives over a
        // ~1M-session simulated fleet at 1 vs N shards, then prove the
        // sharded partial-merge commit matches the flat fold exactly.
        let shards = args.usize_or("shards", 4)?;
        let sessions = args.usize_or("sessions", 1 << 20)?;
        let r = crate::simulator::scaling::run_shard_scale(shards, sessions, seed)?;
        println!(
            "shard-scale: {} sessions over {} shard(s), {} worker thread(s) ({} core(s))",
            r.sessions, r.shards, r.threads, r.cores
        );
        println!(
            "  poll:   {:>12.0} ops/s flat -> {:>12.0} ops/s sharded ({:.2}x)",
            r.poll_ops_per_sec_flat, r.poll_ops_per_sec_sharded, r.poll_speedup
        );
        println!(
            "  upload: {:>12.0} ops/s flat -> {:>12.0} ops/s sharded ({:.2}x)",
            r.upload_ops_per_sec_flat, r.upload_ops_per_sec_sharded, r.upload_speedup
        );
        println!(
            "  commit exactness: {} rounds, bit-identical {} (max |diff| {}) (wall {} ms)",
            r.rounds_completed, r.bit_identical, r.max_abs_diff, r.wall_ms
        );
        r.gate()?;
        println!(
            "  gate passed: flat fold matched bitwise; scaling >= 0.7x ideal where the host \
             can express it"
        );
        return Ok(());
    }
    if let Some(spec) = args.flag("tree") {
        // Hierarchical aggregation: the same seeded fleet through a
        // leaf/master tree vs the flat path, verified bit-identical.
        let leaves = args.usize_or("leaves", 4)? as u32;
        let tree = crate::config::TreeSpec::parse(spec, leaves)?;
        if !tree.uses_leaves() {
            return Err(Error::Config(
                "scale --tree needs depth=2 and --leaves >= 1".into(),
            ));
        }
        let r = crate::simulator::scaling::run_tree_scale(n.min(4096), rounds, tree.leaves, seed)?;
        println!(
            "tree-scale: {} clients over {} leaves (depth {}), {} rounds",
            r.n_clients, r.leaves, tree.depth, r.rounds_completed
        );
        println!(
            "  root ingest frames/round: flat {} -> tree {} ({}x fan-in absorbed at the leaves)",
            r.root_frames_flat,
            r.root_frames_tree,
            r.root_frames_flat / r.root_frames_tree.max(1)
        );
        println!(
            "  bit-identical to flat path: {} (max |diff| {}) (wall {} ms)",
            r.bit_identical, r.max_abs_diff, r.wall_ms
        );
        if !r.bit_identical {
            return Err(Error::Task(
                "tree path diverged from flat reference".into(),
            ));
        }
        return Ok(());
    }
    if let Some(frac) = args.flag("byzantine") {
        // Adversarial-fleet scenario: sweep attacker fractions across
        // undefended fedavg vs the robust strategies, then assert the
        // robustness + admission-policy gates at the requested fraction.
        let f: f64 = frac
            .parse()
            .map_err(|_| Error::Config(format!("--byzantine expects a fraction, got {frac:?}")))?;
        let r = crate::simulator::scaling::run_byzantine(n.min(4096), rounds, f, seed)?;
        println!(
            "byzantine: {} clients, {} rounds, attacks magnitude-bomb/sign-flip/label-flip",
            r.n_clients, r.rounds
        );
        println!("\n  f      byz  fedavg        trimmed_mean  median        (final loss vs optimum)");
        let fractions: Vec<f64> = r
            .points
            .iter()
            .filter(|p| p.strategy == "fedavg")
            .map(|p| p.f)
            .collect();
        for &g in &fractions {
            let cell = |s: &str| {
                r.loss_of(s, g)
                    .map(|l| format!("{l:<12.3e}"))
                    .unwrap_or_else(|| "-".into())
            };
            let byz = r
                .points
                .iter()
                .find(|p| (p.f - g).abs() < 1e-9)
                .map(|p| p.n_byzantine)
                .unwrap_or(0);
            println!(
                "  {g:<5.2}  {byz:<3}  {}  {}  {}",
                cell("fedavg"),
                cell("trimmed_mean"),
                cell("median")
            );
        }
        println!(
            "\n  admission policy: {} request(s) refused pre-engine; attacker reputation {:.2}",
            r.policy_rejected, r.attacker_reputation
        );
        r.gate(f)?;
        println!(
            "  gate passed at f={f}: robust within 10% of clean baseline, fedavg degraded \
             (wall {} ms)",
            r.wall_ms
        );
        return Ok(());
    }
    if args.switch("device-mix") {
        // Heterogeneity scenario: mixed-tier population, capability-aware
        // (Tiered) selection, mid-round lease evictions + backfill.
        let (r, telemetry) =
            crate::simulator::scaling::run_device_mix_report(n.min(4096), rounds, seed)?;
        println!(
            "device-mix: {} clients (high {} / mid {} / low {}), {} rounds",
            r.n_clients,
            r.population_by_tier[2],
            r.population_by_tier[1],
            r.population_by_tier[0],
            r.rounds_completed
        );
        println!(
            "  per-tier uploads: high {}, mid {}, low {} (low enters via backfill)",
            r.uploads_by_tier[2], r.uploads_by_tier[1], r.uploads_by_tier[0]
        );
        println!(
            "  lease evictions {}, cohort backfills {}, failed rounds {}",
            r.evicted, r.backfilled, r.failed_rounds
        );
        println!(
            "  rounds to target: {} (wall {} ms)",
            r.rounds_completed, r.wall_ms
        );
        print!("{}", telemetry.phase_table());
        if let Some(path) = args.flag("telemetry-file") {
            std::fs::write(path, telemetry.to_json())?;
            println!("  telemetry snapshot written to {path}");
        }
        return Ok(());
    }
    if args.switch("churn-restart") {
        // Durability scenario: kill the server mid-experiment, recover
        // from the state dir, report rounds-to-reconverge.
        let kill_after = args.usize_or("kill-after", (rounds / 2).max(1) as usize)? as u64;
        let tmp;
        let state_dir = match args.flag("state-dir") {
            Some(dir) => std::path::PathBuf::from(dir),
            None => {
                tmp = crate::util::TempDir::new("churn")?;
                tmp.path().to_path_buf()
            }
        };
        use crate::simulator::scaling::run_churn_restart;
        let r = run_churn_restart(n, rounds, kill_after, seed, &state_dir)?;
        println!(
            "churn-restart: {} clients, killed mid-round after {} committed rounds",
            r.n_clients, r.committed_before
        );
        println!(
            "  recovered: round {} retried, version preserved {}, weights preserved {}",
            r.interrupted_round, r.version_preserved, r.params_preserved
        );
        println!(
            "  rounds to reconverge: {} (wall {} ms)",
            r.rounds_to_reconverge, r.wall_ms
        );
        return Ok(());
    }
    let p = crate::simulator::scaling::run_scaling_point(n, rounds, seed)?;
    println!(
        "scale: {} clients, {} rounds -> mean iteration {:.1} ms (wall {} ms)",
        p.n_clients, p.rounds, p.round_ms, p.wall_ms
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args
        .flag("addr")
        .ok_or_else(|| Error::Config("serve requires --addr".into()))?;
    let seed = args.usize_or("seed", 99)? as u64;
    let attest = !args.switch("no-attest");
    // Data-plane shard count: sessions, policy buckets and hot-path
    // instruments partition by stable client-id hash; 1 = today's flat
    // server (bit-identical, pinned by the shard_determinism suite).
    let shards = args.usize_or("shards", 1)?;
    let server = match args.flag("state-dir") {
        Some(dir) => {
            let storage = StorageConfig::new(dir)
                .fsync(FsyncPolicy::parse(&args.flag_or("fsync", "commit"))?);
            let s = Arc::new(FloridaServer::with_storage_sharded(
                attest,
                Arc::new(NoEval),
                seed,
                true,
                storage,
                shards,
            )?);
            for t in s.management.list_tasks() {
                println!(
                    "recovered task {} {:?} at round {}/{} ({})",
                    t.task_id,
                    t.task_name,
                    t.round,
                    t.total_rounds,
                    t.state.name()
                );
            }
            s
        }
        None => Arc::new(FloridaServer::sharded(
            attest,
            Arc::new(NoEval),
            seed,
            true,
            shards,
        )),
    };
    // Session liveness lease (protocol v2); default from SessionConfig.
    let lease_ms = args.usize_or(
        "lease-ms",
        crate::config::SessionConfig::default().lease_ms as usize,
    )? as u64;
    server.sessions.set_lease_ms(lease_ms);
    // Optionally deploy a task at startup (JSON config → TaskBuilder) —
    // unless recovery already brought back a live task of that name.
    if let Some(cfg_path) = args.flag("task") {
        let text = std::fs::read_to_string(cfg_path)?;
        let tcfg = TaskConfig::from_json_str(&text)?;
        let live = server.management.list_tasks().into_iter().any(|t| {
            t.task_name == tcfg.task_name
                && matches!(
                    t.state,
                    TaskState::Created | TaskState::Running | TaskState::Paused
                )
        });
        if live {
            println!(
                "task {:?} already recovered from the state dir — not redeploying",
                tcfg.task_name
            );
        } else {
            let init = match args.flag("artifacts") {
                Some(dir) => {
                    let manifest = Manifest::load(dir)?;
                    let preset = manifest.preset(&tcfg.preset)?;
                    ModelSnapshot::from_f32_file(&manifest.path_of(&preset.init_path))?
                }
                None => ModelSnapshot::new(0, vec![0.0; args.usize_or("dim", 5)?]),
            };
            let handle = TaskBuilder::from_config(tcfg).deploy(&server.management, init)?;
            println!("deployed task {} from {cfg_path}", handle.id());
        }
    }
    // Console loop: 'telemetry' / 'telemetry json' dump the registry;
    // 'q' + Enter checkpoints every task at its committed-round boundary
    // and exits (snapshotting telemetry first if --telemetry-file was
    // given). Detached servers (stdin closed) just keep serving — hard
    // kills are covered by the write-ahead journal.
    {
        let server = Arc::clone(&server);
        let telemetry_file = args.flag("telemetry-file").map(str::to_string);
        std::thread::spawn(move || {
            let stdin = std::io::stdin();
            let mut line = String::new();
            loop {
                line.clear();
                match stdin.read_line(&mut line) {
                    // Detached: never treat EOF as a shutdown request.
                    Ok(0) | Err(_) => return,
                    Ok(_) if matches!(line.trim(), "q" | "quit" | "exit") => break,
                    Ok(_) if line.trim() == "telemetry" => {
                        print!("{}", server.telemetry_render(FORMAT_PROMETHEUS));
                    }
                    Ok(_) if line.trim() == "telemetry json" => {
                        println!("{}", server.telemetry_render(FORMAT_JSON));
                    }
                    Ok(_) => {}
                }
            }
            if let Some(path) = &telemetry_file {
                match std::fs::write(path, server.telemetry_render(FORMAT_JSON)) {
                    Ok(()) => println!("telemetry snapshot written to {path}"),
                    Err(e) => println!("telemetry snapshot failed: {e}"),
                }
            }
            let n = server.checkpoint_all();
            println!("shutdown: checkpointed {n} task(s)");
            server.stop();
            std::process::exit(0);
        });
    }
    let listener = TcpTransportListener::bind(addr)?;
    println!("florida serving on {}", listener.local_addr());
    let pool = ThreadPool::new(args.usize_or("conns", 64)?);
    // Lifecycle event log: the dashboard view of round orchestration,
    // driven by the subscription stream rather than status polling.
    {
        let events = server.subscribe();
        std::thread::spawn(move || loop {
            match events.next_timeout(std::time::Duration::from_secs(60)) {
                Some(ev) => println!("{}", render_event(&ev)),
                // Idle or disconnected: back off instead of spinning.
                None => std::thread::sleep(std::time::Duration::from_millis(100)),
            }
        });
    }
    // Background deadline sweep.
    {
        let server = Arc::clone(&server);
        std::thread::spawn(move || loop {
            server.tick();
            std::thread::sleep(std::time::Duration::from_millis(100));
        });
    }
    server.serve(Box::new(listener), &pool);
    Ok(())
}

/// One task-event log line for the serve console.
fn render_event(ev: &TaskEvent) -> String {
    match ev {
        TaskEvent::TaskStateChanged { task_id, state } => {
            format!("task {task_id}: state → {}", state.name())
        }
        TaskEvent::ClientJoined { task_id, client_id } => {
            format!("task {task_id}: client {client_id} joined")
        }
        TaskEvent::RoundStarted {
            task_id,
            round,
            cohort,
        } => format!("task {task_id}: round {round} started ({cohort} clients)"),
        TaskEvent::RoundCommitted {
            task_id,
            round,
            participants,
            train_loss,
        } => format!(
            "task {task_id}: round {round} committed ({participants} participants, loss {train_loss:.4})"
        ),
        TaskEvent::QuorumMissed {
            task_id,
            round,
            reported,
            quorum,
        } => format!("task {task_id}: round {round} missed quorum ({reported}/{quorum})"),
        TaskEvent::RoundFailed { task_id, round } => {
            format!("task {task_id}: round {round} failed — retrying")
        }
        TaskEvent::ClientEvicted {
            task_id,
            client_id,
            round,
        } => format!("task {task_id}: client {client_id} lease-evicted from round {round}"),
        TaskEvent::CohortBackfilled {
            task_id,
            client_id,
            round,
        } => format!("task {task_id}: client {client_id} backfilled into round {round}"),
        TaskEvent::TaskCompleted { task_id } => format!("task {task_id}: completed"),
    }
}

fn cmd_status(args: &Args) -> Result<()> {
    let addr = args
        .flag("addr")
        .ok_or_else(|| Error::Config("status requires --addr".into()))?;
    let task_id = args.usize_or("task-id", 1)? as u64;
    let codec = if args.switch("json") {
        WireCodec::Json
    } else {
        WireCodec::Binary
    };
    // Typed stub: a protocol ErrorReply surfaces as Err(Error::Server).
    let client = FloridaClient::connect(&TcpDialer, addr, codec)?;
    let st = client.task_status(task_id)?;
    println!(
        "task {} {:?} state={} round {}/{}",
        st.task.task_id,
        st.task.task_name,
        st.task.state.name(),
        st.task.round,
        st.task.total_rounds
    );
    println!(
        "last round: {} participants, {} ms, loss {:.4}, acc {:.4}, eps {:.3}",
        st.participants, st.last_round_duration_ms, st.last_loss, st.last_accuracy, st.epsilon
    );
    Ok(())
}

fn cmd_dp_plan(args: &Args) -> Result<()> {
    let q = args.f64_or("q", 0.32)?;
    let sigma = args.f64_or("sigma", 0.08)?;
    let rounds = args.usize_or("rounds", 10)? as u64;
    let delta = args.f64_or("delta", 1e-5)?;
    let mut acct = RdpAccountant::new();
    println!("round   epsilon(delta={delta})");
    for r in 1..=rounds {
        acct.step(q, sigma)?;
        let (eps, order) = acct.epsilon(delta)?;
        println!("{r:>5}   {eps:>10.4}  (order {order})");
    }
    let cfg = DpConfig {
        mode: DpMode::Local,
        clip_norm: args.f64_or("clip", 0.5)?,
        noise_multiplier: sigma,
    };
    println!(
        "\nconfig: clip={} sigma={} q={} rounds={} -> eps={:.3}",
        cfg.clip_norm,
        sigma,
        q,
        rounds,
        acct.epsilon(delta)?.0
    );
    Ok(())
}

/// `florida lint` — run the static-analysis rules over `rust/src`.
///
/// Exit is nonzero on any reported finding, so `scripts/check.sh` and
/// CI can gate on it; the `lint_enforced` test target runs the same
/// engine under plain `cargo test`.
fn cmd_lint(args: &Args) -> Result<()> {
    use crate::analysis::{default_rules, load_tree, render, run_rules, Baseline};
    let root_flag = args.flag_or("root", ".");
    let root = std::path::Path::new(&root_flag);
    let files = load_tree(root)?;
    let rules = default_rules();
    let findings = run_rules(&files, &rules);
    let baseline_file = args.flag_or("baseline-file", "lint.baseline");
    let baseline_path = root.join(&baseline_file);

    if args.switch("write-baseline") {
        std::fs::write(&baseline_path, Baseline::render_from(&findings))?;
        println!(
            "lint: wrote {} ({} grandfathered finding(s))",
            baseline_path.display(),
            findings.len()
        );
        return Ok(());
    }

    let (reported, grandfathered, stale) = if args.switch("baseline") {
        let text = std::fs::read_to_string(&baseline_path).map_err(|e| {
            Error::Config(format!(
                "lint --baseline: cannot read {}: {e}",
                baseline_path.display()
            ))
        })?;
        Baseline::parse(&text)?.apply(findings)
    } else {
        (findings, 0, 0)
    };

    if stale > 0 {
        println!(
            "lint: note: {stale} baseline slot(s) no longer used — shrink \
             lint.baseline with `florida lint --write-baseline`"
        );
    }
    if reported.is_empty() {
        println!(
            "lint: clean — {} file(s), {} rule(s), {} grandfathered",
            files.len(),
            rules.len(),
            grandfathered
        );
        Ok(())
    } else {
        print!("{}", render(&reported));
        Err(Error::Config(format!(
            "lint: {} finding(s) — fix, `// florida-lint: allow(<rule>): <reason>`, \
             or (to grandfather, counts may only shrink) --write-baseline",
            reported.len()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = Args::parse(&argv("run-sim --devices 16 --dp --preset micro")).unwrap();
        assert_eq!(a.command, "run-sim");
        assert_eq!(a.usize_or("devices", 0).unwrap(), 16);
        assert_eq!(a.flag_or("preset", "tiny"), "micro");
        assert!(a.switch("dp"));
        assert!(!a.switch("secagg"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Args::parse(&argv("")).is_err());
        assert!(Args::parse(&argv("cmd positional")).is_err());
        let a = Args::parse(&argv("cmd --n abc")).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn dp_plan_runs() {
        let a = Args::parse(&argv("dp-plan --q 0.32 --sigma 0.08 --rounds 3")).unwrap();
        cmd_dp_plan(&a).unwrap();
    }

    #[test]
    fn scale_device_mix_runs() {
        let a = Args::parse(&argv("scale --device-mix --clients 12 --rounds 1")).unwrap();
        cmd_scale(&a).unwrap();
    }

    #[test]
    fn scale_device_mix_snapshots_telemetry_to_file() {
        let tmp = crate::util::TempDir::new("cli-telemetry").unwrap();
        let path = tmp.path().join("telemetry.json");
        let cmd = format!(
            "scale --device-mix --clients 12 --rounds 1 --telemetry-file {}",
            path.display()
        );
        cmd_scale(&Args::parse(&argv(&cmd)).unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let hists = parsed.get("histograms").expect("histograms key");
        for key in [
            "round_phase_joining_ms",
            "round_phase_training_ms",
            "round_phase_unmasking_ms",
            "round_phase_commit_ms",
        ] {
            assert!(hists.get(key).is_some(), "missing histogram {key}");
        }
        assert!(parsed.get("rpc").is_some(), "missing per-RPC section");
    }

    #[test]
    fn scale_tree_runs_and_validates() {
        let a =
            Args::parse(&argv("scale --tree depth=2 --leaves 4 --clients 12 --rounds 1")).unwrap();
        cmd_scale(&a).unwrap();
        // depth=1 never uses leaves; the tree run must refuse it.
        let a = Args::parse(&argv("scale --tree depth=1 --clients 12 --rounds 1")).unwrap();
        assert!(cmd_scale(&a).is_err());
        let a = Args::parse(&argv("scale --tree depth=3 --leaves 2")).unwrap();
        assert!(cmd_scale(&a).is_err());
    }

    #[test]
    fn scale_shards_runs_and_validates() {
        // One shard: gate reduces to commit exactness (speedup is only
        // enforced when the partition can express it), so this is a
        // stable CI smoke; the check.sh smoke runs the 4-shard fleet.
        let a = Args::parse(&argv("scale --shards 1 --sessions 2048")).unwrap();
        cmd_scale(&a).unwrap();
        let a = Args::parse(&argv("scale --shards 0 --sessions 2048")).unwrap();
        assert!(cmd_scale(&a).is_err());
        let a = Args::parse(&argv("scale --shards 2 --sessions 1")).unwrap();
        assert!(cmd_scale(&a).is_err());
    }

    #[test]
    fn scale_byzantine_runs_and_validates() {
        let a = Args::parse(&argv("scale --byzantine 0.2 --clients 10 --rounds 3")).unwrap();
        cmd_scale(&a).unwrap();
        // An attacking majority cannot be defended against.
        let a = Args::parse(&argv("scale --byzantine 0.6 --clients 10 --rounds 1")).unwrap();
        assert!(cmd_scale(&a).is_err());
        let a = Args::parse(&argv("scale --byzantine nope --clients 10")).unwrap();
        assert!(cmd_scale(&a).is_err());
    }

    #[test]
    fn event_rendering() {
        let line = render_event(&TaskEvent::RoundCommitted {
            task_id: 3,
            round: 1,
            participants: 8,
            train_loss: 0.5,
        });
        assert!(line.contains("task 3"));
        assert!(line.contains("committed"));
        let line = render_event(&TaskEvent::QuorumMissed {
            task_id: 3,
            round: 0,
            reported: 1,
            quorum: 4,
        });
        assert!(line.contains("1/4"));
    }

    #[test]
    fn help_dispatch() {
        assert_eq!(run(&argv("help")), 0);
        assert_eq!(run(&argv("definitely-not-a-command")), 1);
    }
}
