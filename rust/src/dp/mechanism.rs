//! The Gaussian mechanism: L2 clipping + calibrated noise.
//!
//! Local mode (paper's Fig-11 DP run): every client clips its pseudo-
//! gradient to `clip_norm` and adds `N(0, (σ·clip)²)` per coordinate
//! before upload — the server never sees an unnoised update.
//! Central mode: clients only clip; the master aggregator adds
//! `N(0, (σ·clip)²)` once to the aggregate (requires the trusted-
//! aggregator / confidential-container deployment of §4.3).

use crate::util::Rng;

/// Where noise is injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DpMode {
    /// No differential privacy.
    Off,
    /// Client-side clip + noise (user-level local DP of §5.1).
    Local,
    /// Server-side noise on the aggregate (trusted aggregator, §4.3).
    Central,
}

/// Task-level DP configuration (set at task creation, §3.3.1).
#[derive(Clone, Copy, Debug)]
pub struct DpConfig {
    pub mode: DpMode,
    /// L2 clipping norm (paper Fig 11: 0.5).
    pub clip_norm: f64,
    /// Noise multiplier σ (paper Fig 11: 0.08).
    pub noise_multiplier: f64,
}

impl DpConfig {
    pub fn off() -> DpConfig {
        DpConfig {
            mode: DpMode::Off,
            clip_norm: 0.0,
            noise_multiplier: 0.0,
        }
    }

    /// The exact configuration of the paper's Fig-11 DP experiment.
    pub fn paper_local() -> DpConfig {
        DpConfig {
            mode: DpMode::Local,
            clip_norm: 0.5,
            noise_multiplier: 0.08,
        }
    }
}

/// Stateless Gaussian mechanism operations over flat f32 vectors.
pub struct GaussianMechanism;

impl GaussianMechanism {
    /// Scale `xs` so its L2 norm is at most `clip_norm`. Returns the
    /// pre-clip norm.
    pub fn clip(xs: &mut [f32], clip_norm: f64) -> f64 {
        let norm = crate::util::stats::l2_norm(xs);
        if norm > clip_norm && norm > 0.0 {
            let s = (clip_norm / norm) as f32;
            for x in xs.iter_mut() {
                *x *= s;
            }
        }
        norm
    }

    /// Add N(0, (σ·clip)²) per coordinate.
    pub fn add_noise(xs: &mut [f32], clip_norm: f64, sigma: f64, rng: &mut Rng) {
        let std = sigma * clip_norm;
        if std <= 0.0 {
            return;
        }
        for x in xs.iter_mut() {
            *x += rng.normal_scaled(0.0, std) as f32;
        }
    }

    /// Local-DP client path: clip then noise. Returns pre-clip norm.
    pub fn privatize(xs: &mut [f32], cfg: &DpConfig, rng: &mut Rng) -> f64 {
        match cfg.mode {
            DpMode::Off => crate::util::stats::l2_norm(xs),
            DpMode::Local => {
                let n = Self::clip(xs, cfg.clip_norm);
                Self::add_noise(xs, cfg.clip_norm, cfg.noise_multiplier, rng);
                n
            }
            // Central mode: clients only clip.
            DpMode::Central => Self::clip(xs, cfg.clip_norm),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::l2_norm;

    #[test]
    fn clip_bounds_norm() {
        let mut v = vec![3.0f32, 4.0];
        let pre = GaussianMechanism::clip(&mut v, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-5);
        // direction preserved
        assert!((v[0] / v[1] - 0.75).abs() < 1e-5);
    }

    #[test]
    fn clip_noop_when_small() {
        let mut v = vec![0.1f32, 0.1];
        let orig = v.clone();
        GaussianMechanism::clip(&mut v, 10.0);
        assert_eq!(v, orig);
    }

    #[test]
    fn noise_statistics() {
        let mut rng = crate::util::Rng::new(5);
        let n = 100_000;
        let mut v = vec![0f32; n];
        GaussianMechanism::add_noise(&mut v, 0.5, 0.08, &mut rng);
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        let want_std = 0.5 * 0.08;
        assert!(mean.abs() < 3.0 * want_std / (n as f64).sqrt() * 3.0);
        assert!((var.sqrt() - want_std).abs() / want_std < 0.05);
    }

    #[test]
    fn privatize_modes() {
        let mut rng = crate::util::Rng::new(6);
        let cfg_off = DpConfig::off();
        let mut a = vec![3.0f32, 4.0];
        GaussianMechanism::privatize(&mut a, &cfg_off, &mut rng);
        assert_eq!(a, vec![3.0, 4.0]);

        let cfg_local = DpConfig::paper_local();
        let mut b = vec![3.0f32, 4.0];
        GaussianMechanism::privatize(&mut b, &cfg_local, &mut rng);
        // clipped to 0.5 plus small noise
        assert!(l2_norm(&b) < 0.7);

        let cfg_central = DpConfig {
            mode: DpMode::Central,
            ..cfg_local
        };
        let mut c = vec![3.0f32, 4.0];
        GaussianMechanism::privatize(&mut c, &cfg_central, &mut rng);
        assert!((l2_norm(&c) - 0.5).abs() < 1e-5); // clip only, no noise
    }

    #[test]
    fn zero_sigma_adds_nothing() {
        let mut rng = crate::util::Rng::new(7);
        let mut v = vec![1.0f32; 8];
        GaussianMechanism::add_noise(&mut v, 0.5, 0.0, &mut rng);
        assert_eq!(v, vec![1.0f32; 8]);
    }
}
