//! Rényi-DP accountant for the subsampled Gaussian mechanism.
//!
//! Implements the moments bound of Mironov et al. ("Rényi Differential
//! Privacy of the Sampled Gaussian Mechanism", 2019) / Wang et al. 2018 —
//! the same accounting the paper exposes in the dashboard ("the user can
//! access a Rényi-DP privacy accountant ... to determine the current
//! privacy loss ε", §4.2; the Fig-11 experiment used Opacus' RDP
//! accountant and reports ε=2 at δ=1e-5).
//!
//! For integer order α, sampling rate q and noise multiplier σ:
//!
//!   RDP(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k ·
//!            exp(k(k−1)/(2σ²))
//!
//! accumulated over steps, then converted to (ε, δ) with the improved
//! RDP→DP conversion of Balle et al. 2020 (as in Opacus):
//!
//!   ε = RDP_total(α) + log((α−1)/α) − (log δ + log α)/(α−1),  min over α.

use crate::error::{Error, Result};

/// Default Rényi orders: 2..=64 then coarser up to 512.
fn default_orders() -> Vec<u32> {
    let mut o: Vec<u32> = (2..=64).collect();
    o.extend([72, 80, 96, 128, 160, 192, 256, 320, 384, 512]);
    o
}

/// log(exp(a) + exp(b)) without overflow.
fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// log C(n, k) via lgamma.
fn log_binom(n: u32, k: u32) -> f64 {
    lgamma((n + 1) as f64) - lgamma((k + 1) as f64) - lgamma((n - k + 1) as f64)
}

/// Lanczos log-gamma (g=7, n=9) — no libm lgamma in std.
fn lgamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().abs().ln() - lgamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// RDP of ONE subsampled-Gaussian step at integer order α.
pub fn rdp_step(q: f64, sigma: f64, alpha: u32) -> f64 {
    assert!(alpha >= 2);
    if q == 0.0 {
        return 0.0;
    }
    if sigma == 0.0 {
        return f64::INFINITY;
    }
    if q >= 1.0 {
        // Plain Gaussian mechanism: RDP(α) = α / (2σ²).
        return alpha as f64 / (2.0 * sigma * sigma);
    }
    // log Σ_k C(α,k) (1−q)^{α−k} q^k exp(k(k−1)/2σ²)
    let mut log_sum = f64::NEG_INFINITY;
    for k in 0..=alpha {
        let term = log_binom(alpha, k)
            + (alpha - k) as f64 * (1.0 - q).ln()
            + k as f64 * q.ln()
            + (k as f64 * (k as f64 - 1.0)) / (2.0 * sigma * sigma);
        log_sum = log_add(log_sum, term);
    }
    (log_sum / (alpha as f64 - 1.0)).max(0.0)
}

/// Accumulating RDP accountant (one instance per task).
#[derive(Clone, Debug)]
pub struct RdpAccountant {
    orders: Vec<u32>,
    /// Accumulated RDP at each order.
    rdp: Vec<f64>,
    steps: u64,
}

impl Default for RdpAccountant {
    fn default() -> Self {
        Self::new()
    }
}

impl RdpAccountant {
    pub fn new() -> RdpAccountant {
        let orders = default_orders();
        let rdp = vec![0.0; orders.len()];
        RdpAccountant {
            orders,
            rdp,
            steps: 0,
        }
    }

    /// Record one aggregation round: sampling rate `q` (cohort / population)
    /// with noise multiplier `sigma`.
    pub fn step(&mut self, q: f64, sigma: f64) -> Result<()> {
        if !(0.0..=1.0).contains(&q) {
            return Err(Error::Dp(format!("sampling rate {q} outside [0,1]")));
        }
        if sigma < 0.0 {
            return Err(Error::Dp(format!("negative sigma {sigma}")));
        }
        for (i, &a) in self.orders.iter().enumerate() {
            self.rdp[i] += rdp_step(q, sigma, a);
        }
        self.steps += 1;
        Ok(())
    }

    /// Record `n` identical steps at once.
    pub fn steps(&mut self, n: u64, q: f64, sigma: f64) -> Result<()> {
        if n == 0 {
            return Ok(());
        }
        for (i, &a) in self.orders.iter().enumerate() {
            self.rdp[i] += n as f64 * rdp_step(q, sigma, a);
        }
        self.steps += n;
        Ok(())
    }

    pub fn num_steps(&self) -> u64 {
        self.steps
    }

    /// Current ε at the given δ (and the optimal order).
    pub fn epsilon(&self, delta: f64) -> Result<(f64, u32)> {
        if !(0.0..1.0).contains(&delta) || delta == 0.0 {
            return Err(Error::Dp(format!("delta {delta} outside (0,1)")));
        }
        let mut best = (f64::INFINITY, 0u32);
        for (i, &a) in self.orders.iter().enumerate() {
            let af = a as f64;
            // Balle et al. conversion (Opacus' formula).
            let eps = self.rdp[i] + ((af - 1.0) / af).ln() - (delta.ln() + af.ln()) / (af - 1.0);
            if eps < best.0 {
                best = (eps, a);
            }
        }
        Ok((best.0.max(0.0), best.1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lgamma_matches_factorials() {
        for n in 1..15u32 {
            let fact: f64 = (1..n).map(|i| i as f64).product::<f64>();
            assert!((lgamma(n as f64) - fact.ln()).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn log_binom_matches_pascal() {
        assert!((log_binom(10, 3) - 120f64.ln()).abs() < 1e-9);
        assert!((log_binom(5, 0) - 0.0).abs() < 1e-9);
        assert!((log_binom(5, 5) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn no_subsampling_equals_plain_gaussian() {
        let sigma = 2.0;
        for alpha in [2u32, 8, 32] {
            let want = alpha as f64 / (2.0 * sigma * sigma);
            assert!((rdp_step(1.0, sigma, alpha) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_sampling_is_free() {
        assert_eq!(rdp_step(0.0, 1.0, 8), 0.0);
    }

    #[test]
    fn subsampling_amplifies_privacy() {
        // q < 1 must cost less than q = 1 at the same σ, α.
        let full = rdp_step(1.0, 1.0, 8);
        let sub = rdp_step(0.1, 1.0, 8);
        assert!(sub < full, "{sub} !< {full}");
        assert!(sub > 0.0);
    }

    #[test]
    fn rdp_monotone_in_steps_and_sigma() {
        let mut a = RdpAccountant::new();
        a.steps(10, 0.1, 1.0).unwrap();
        let (e10, _) = a.epsilon(1e-5).unwrap();
        a.steps(10, 0.1, 1.0).unwrap();
        let (e20, _) = a.epsilon(1e-5).unwrap();
        assert!(e20 > e10);

        let mut hi = RdpAccountant::new();
        hi.steps(10, 0.1, 4.0).unwrap();
        let (ehi, _) = hi.epsilon(1e-5).unwrap();
        assert!(ehi < e10, "more noise must mean less epsilon");
    }

    #[test]
    fn analytic_reference_point() {
        // Small-q analytic check: RDP(α) ≈ q²α/σ² per step, so with
        // q=0.01, σ=1, T=1000: ε(δ=1e-5) ≈ min_α 0.1α + log(1/δ)/(α−1)
        // ≈ 2.1 at α ≈ 12. The exact bound must land within ~10%.
        let mut a = RdpAccountant::new();
        a.steps(1000, 0.01, 1.0).unwrap();
        let (eps, order) = a.epsilon(1e-5).unwrap();
        assert!((eps - 2.1).abs() < 0.25, "eps={eps}");
        assert!((8..=20).contains(&order), "order={order}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut a = RdpAccountant::new();
        assert!(a.step(1.5, 1.0).is_err());
        assert!(a.step(-0.1, 1.0).is_err());
        assert!(a.step(0.5, -1.0).is_err());
        assert!(a.epsilon(0.0).is_err());
        assert!(a.epsilon(1.0).is_err());
    }

    #[test]
    fn sigma_zero_gives_infinite_eps() {
        let mut a = RdpAccountant::new();
        a.step(0.5, 0.0).unwrap();
        let (eps, _) = a.epsilon(1e-5).unwrap();
        assert!(eps.is_infinite());
    }
}
