//! Differential privacy (§4.2): Gaussian mechanism + Rényi-DP accountant.
//!
//! "Differential privacy injects Gaussian noise into the training process
//! ... We provide support for local or global differentially-private noise
//! addition. ... the user can access a Rényi-DP privacy accountant in the
//! dashboard to determine the current privacy loss ε."

pub mod accountant;
pub mod mechanism;

pub use accountant::RdpAccountant;
pub use mechanism::{DpConfig, DpMode, GaussianMechanism};
