//! Scaling harness (§5.2): the dummy task at varying client counts.
//!
//! "The task consists in having each client generating an all-ones array
//! of size 5 and sending it to the server, which then aggregates all the
//! arrays." Reproduces Fig 11 (right): per-iteration duration vs number
//! of concurrent clients.

use std::path::Path;
use std::sync::Arc;

use crate::aggtree::{LeafAggregator, LeafConfig};
use crate::client::{ConstantTrainer, FloridaClient};
use crate::config::{CohortSpec, FsyncPolicy, PolicyConfig, StorageConfig, TreeSpec};
use crate::error::{Error, Result};
use crate::model::ModelSnapshot;
use crate::obs::export::Report;
use crate::orchestrator::TaskBuilder;
use crate::proto::{
    ComputeTier, DeviceCaps, DeviceProfile, LoadHints, RoundRole, TaskState, PROTO_V2,
};
use crate::services::management::NoEval;
use crate::services::FloridaServer;
use crate::shard::{ShardIngestPlane, ShardedPolicy, ShardedSessions};
use crate::simulator::{run_fleet, FleetConfig, Heterogeneity};

/// One scaling measurement.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub n_clients: usize,
    /// Mean duration of one iteration (round), ms.
    pub round_ms: f64,
    /// Wall time for the whole run, ms.
    pub wall_ms: u64,
    pub rounds: usize,
    /// Registration phase duration (the §5 "70k devices" surge claim is
    /// about connection/registration capacity).
    pub register_ms: u64,
}

/// Run the dummy task with `n` concurrent clients for `rounds` rounds.
pub fn run_scaling_point(n: usize, rounds: u64, seed: u64) -> Result<ScalingPoint> {
    // Attestation off for the pure-throughput measurement (the paper's
    // dummy task measures orchestration cost, not crypto admission; the
    // secagg_vg_cost bench covers crypto).
    let server = Arc::new(FloridaServer::with_evaluator(
        false,
        Arc::new(NoEval),
        seed,
        true,
    ));
    // Dummy task: all-ones array of size 5.
    let task = TaskBuilder::new(&format!("dummy-scaling-{n}"))
        .clients_per_round(n)
        .rounds(rounds)
        .round_timeout_ms(120_000)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
        .id();

    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();
    let fleet = FleetConfig {
        n_devices: n,
        heterogeneity: Heterogeneity::none(),
        base_compute_ms: 0,
        seed,
        poll_sleep_ms: 2,
    };
    let reports = run_fleet(&server, task, &fleet, |_| ConstantTrainer { step: 1.0 });
    let wall_ms = t0.elapsed().as_millis() as u64;

    let (_, metrics, _) = server.task_handle(task).status()?;
    let register_ms = server.selection.count() as u64; // count only; see bench
    let _ = reports;
    Ok(ScalingPoint {
        n_clients: n,
        round_ms: metrics.mean_round_duration_ms(),
        wall_ms,
        rounds: metrics.rounds.len(),
        register_ms,
    })
}

/// Outcome of the §Durability churn scenario: kill the server
/// mid-experiment, recover it from `state_dir`, and finish the task.
#[derive(Clone, Debug)]
pub struct ChurnRestartReport {
    pub n_clients: usize,
    /// Rounds committed before the kill.
    pub committed_before: u64,
    /// The round that was in flight when the server died (it is retried
    /// after recovery, never silently lost).
    pub interrupted_round: u64,
    /// Committed rounds the recovered server needed to finish the task —
    /// `total - committed_before`, since the interrupted round keeps its
    /// round number.
    pub rounds_to_reconverge: u64,
    /// Model version after recovery equals the pre-kill committed
    /// version (no committed work lost, no phantom commits).
    pub version_preserved: bool,
    /// Recovered weights match the pre-kill committed weights
    /// bit-for-bit.
    pub params_preserved: bool,
    pub wall_ms: u64,
}

/// Run the dummy task with durability on, kill the server after
/// `kill_after` committed rounds (mid-round, with a partial cohort
/// already uploaded), recover from `state_dir`, and drive the task to
/// completion. Rounds are driven synchronously through the management
/// API so the kill point is deterministic.
pub fn run_churn_restart(
    n: usize,
    total_rounds: u64,
    kill_after: u64,
    seed: u64,
    state_dir: &Path,
) -> Result<ChurnRestartReport> {
    if n < 2 {
        return Err(Error::Config("churn restart needs >= 2 clients".into()));
    }
    if !(1..total_rounds).contains(&kill_after) {
        return Err(Error::Config(format!(
            "kill_after must be in 1..{total_rounds}"
        )));
    }
    let storage = StorageConfig::new(state_dir).fsync(FsyncPolicy::Commit);
    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();

    // One plaintext sync round through the management API: everyone
    // joins (forming the cohort), then `uploaders` clients report.
    fn drive(server: &FloridaServer, task: u64, n: usize, uploaders: usize) -> Result<()> {
        let now = server.now_ms();
        for c in 1..=n as u64 {
            server.management.join(c, task, [0u8; 32], now)?;
        }
        for c in 1..=n as u64 {
            let _ = server.management.fetch_round(c, task, &server.selection, now)?;
        }
        let (round, version) = server
            .management
            .with_task(task, |t| Ok((t.round, t.global.version)))?;
        for c in 1..=uploaders as u64 {
            let (ok, why) = server.management.accept_plain(
                c,
                task,
                round,
                version,
                vec![1.0; 5],
                1.0,
                0.1,
                now + 1,
            )?;
            if !ok {
                return Err(Error::Task(why));
            }
        }
        Ok(())
    }

    // Phase 1: run to the kill point, leaving a round in flight.
    let (task, committed_before, params_before, version_before) = {
        let server = Arc::new(FloridaServer::with_storage(
            false,
            Arc::new(NoEval),
            seed,
            true,
            storage.clone(),
        )?);
        let task = TaskBuilder::new("churn-restart")
            .clients_per_round(n)
            .rounds(total_rounds)
            .round_timeout_ms(120_000)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
            .id();
        for _ in 0..kill_after {
            drive(&server, task, n, n)?;
        }
        // Mid-experiment kill: half the cohort has already uploaded.
        drive(&server, task, n, n / 2)?;
        let snap = server
            .management
            .with_task(task, |t| Ok((t.global.params.clone(), t.global.version)))?;
        (task, kill_after, snap.0, snap.1)
    }; // server dropped: the crash

    // Phase 2: recover and reconverge.
    let server = Arc::new(FloridaServer::with_storage(
        false,
        Arc::new(NoEval),
        seed,
        true,
        storage,
    )?);
    let (interrupted_round, version_preserved, params_preserved) =
        server.management.with_task(task, |t| {
            Ok((
                t.round,
                t.global.version == version_before,
                t.global.params == params_before,
            ))
        })?;
    let mut rounds_after = 0u64;
    loop {
        let state = server.management.with_task(task, |t| Ok(t.state))?;
        if state != TaskState::Running {
            break;
        }
        if rounds_after > total_rounds + 2 {
            return Err(Error::Task("churn restart failed to reconverge".into()));
        }
        drive(&server, task, n, n)?;
        rounds_after += 1;
    }
    let (desc, metrics, _) = server.management.task_status(task)?;
    if desc.state != TaskState::Completed || metrics.rounds.len() as u64 != total_rounds {
        return Err(Error::Task(format!(
            "churn restart ended in state {} after {} committed rounds",
            desc.state.name(),
            metrics.rounds.len()
        )));
    }
    Ok(ChurnRestartReport {
        n_clients: n,
        committed_before,
        interrupted_round,
        rounds_to_reconverge: rounds_after,
        version_preserved,
        params_preserved,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

/// Outcome of the §Heterogeneity device-mix scenario: a mixed-tier
/// population under the `Tiered` capability-aware cohort policy, with
/// stragglers going dark mid-round (lease expiry → eviction → backfill).
#[derive(Clone, Debug)]
pub struct DeviceMixReport {
    pub n_clients: usize,
    /// Population per compute tier, indexed by `ComputeTier as usize`
    /// (`[low, mid, high]`).
    pub population_by_tier: [usize; 3],
    /// Accepted uploads per compute tier across the whole run.
    pub uploads_by_tier: [u64; 3],
    /// Mid-round lease evictions observed on the event stream.
    pub evicted: u64,
    /// Cohort slots refilled from the join pool after an eviction.
    pub backfilled: u64,
    /// Committed rounds (== the target when the run converges).
    pub rounds_completed: u64,
    pub failed_rounds: u64,
    pub wall_ms: u64,
}

/// Run the device-mix scenario: `n` clients split into high/mid/low
/// compute tiers open v2 sessions reporting their profile; a `Tiered`
/// task selects the top half by reported tier each round; a quarter of
/// the cohort (its slowest members) goes dark mid-round and is evicted
/// when its lease expires, the slots backfilled from the waiting pool —
/// so low-tier devices participate exactly through the repair path.
/// Driven on the server's manual clock for deterministic lease math.
pub fn run_device_mix(n: usize, rounds: u64, seed: u64) -> Result<DeviceMixReport> {
    run_device_mix_report(n, rounds, seed).map(|(report, _)| report)
}

/// [`run_device_mix`] plus the server's full telemetry export — the
/// round-phase breakdown and per-RPC latency quantiles the `scale`
/// scenario prints and `--telemetry-file` snapshots.
pub fn run_device_mix_report(
    n: usize,
    rounds: u64,
    seed: u64,
) -> Result<(DeviceMixReport, Report)> {
    if n < 6 {
        return Err(Error::Config("device mix needs >= 6 clients".into()));
    }
    if rounds == 0 {
        return Err(Error::Config("device mix needs >= 1 round".into()));
    }
    const LEASE_MS: u64 = 2_000;
    let server = Arc::new(FloridaServer::for_testing(false, seed));
    server.sessions.set_lease_ms(LEASE_MS);
    let k = n / 2;
    let n_high = (n / 6).max(1);
    let n_mid = k - n_high;
    let tier_of = |i: usize| {
        if i < n_high {
            ComputeTier::High
        } else if i < n_high + n_mid {
            ComputeTier::Mid
        } else {
            ComputeTier::Low
        }
    };
    let task = TaskBuilder::new("device-mix")
        .clients_per_round(k)
        .rounds(rounds)
        .cohort_policy(CohortSpec::Tiered)
        .round_timeout_ms(60_000)
        .min_report_fraction(0.5)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
        .id();
    let stub = FloridaClient::direct(&server);
    let events = server.subscribe();
    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();

    // Every device opens a v2 session reporting its compute tier.
    let mut population_by_tier = [0usize; 3];
    let open = |i: usize, nonce: u64| -> Result<(u64, u64)> {
        let device_id = format!("mix-{i}");
        let verdict = server.auth.authority().issue(
            &device_id,
            crate::crypto::attest::IntegrityTier::Device,
            nonce,
            u64::MAX / 2,
        );
        let profile = DeviceProfile {
            compute_tier: tier_of(i),
            ..Default::default()
        };
        let grant = stub.open_session(
            &device_id,
            verdict,
            DeviceCaps::default(),
            profile,
            PROTO_V2,
        )?;
        if !grant.accepted {
            return Err(Error::Attestation(grant.reason));
        }
        Ok((grant.client_id, grant.token))
    };
    // (device index, client_id, session token)
    let mut clients: Vec<(usize, u64, u64)> = Vec::with_capacity(n);
    for i in 0..n {
        let (cid, token) = open(i, i as u64)?;
        population_by_tier[tier_of(i) as usize] += 1;
        clients.push((i, cid, token));
    }

    let mut uploads_by_tier = [0u64; 3];
    let mut nonce = n as u64;
    for _ in 0..rounds {
        // Everyone renews its lease and volunteers for the round.
        for &(_, cid, token) in &clients {
            let ack = stub.session_heartbeat(cid, token, LoadHints::default())?;
            if !ack.renewed {
                return Err(Error::Selection(format!("client {cid}: {}", ack.reason)));
            }
            // Joiners queued from the previous round are still in the
            // pool — their rejoin reads "already joined", which is fine.
            let join = stub.join_round(cid, task, [0u8; 32])?;
            if !join.accepted && !join.reason.contains("already joined") {
                return Err(Error::Task(join.reason));
            }
        }
        // First fetch: the Tiered cohort forms — top `k` by reported tier.
        let mut in_cohort: Vec<(usize, u64)> = Vec::new();
        for &(i, cid, _) in &clients {
            if let RoundRole::Train(_) = stub.fetch_round(cid, task)? {
                in_cohort.push((i, cid));
            }
        }
        // The slowest quarter of the cohort goes dark (stragglers that
        // stop heartbeating mid-round).
        let n_drop = (in_cohort.len() / 4).max(1);
        in_cohort.sort_by_key(|&(i, _)| tier_of(i));
        let droppers: Vec<(usize, u64)> = in_cohort[..n_drop].to_vec();
        let is_dropper = |cid: u64| droppers.iter().any(|&(_, d)| d == cid);
        // The live cohort members train and upload.
        server.advance_ms(100);
        let mut upload = |i: usize, cid: u64| -> Result<()> {
            if let RoundRole::Train(ri) = stub.fetch_round(cid, task)? {
                let model = ModelSnapshot::from_compressed(&ri.model_blob)?;
                stub.upload_plain(crate::proto::rpc::UploadPlain {
                    client_id: cid,
                    task_id: task,
                    round: ri.round,
                    base_version: model.version,
                    delta: vec![1.0; model.dim()],
                    weight: 1.0,
                    loss: 0.1,
                })?;
                uploads_by_tier[tier_of(i) as usize] += 1;
            }
            Ok(())
        };
        for &(i, cid) in &in_cohort[n_drop..] {
            upload(i, cid)?;
        }
        // Mid-lease the live fleet renews; the droppers stay dark.
        server.advance_ms(LEASE_MS / 2 - 500);
        for &(_, cid, token) in &clients {
            if !is_dropper(cid) {
                let _ = stub.session_heartbeat(cid, token, LoadHints::default());
            }
        }
        // Past the droppers' expiry: the sweep evicts them mid-round and
        // backfills their cohort slots from the waiting (low-tier) pool.
        server.advance_ms(LEASE_MS / 2 + 600);
        // Backfilled draftees discover their Train role and report.
        for &(i, cid, _) in &clients {
            if !is_dropper(cid) {
                upload(i, cid)?;
            }
        }
        // Dropped devices come back online and reopen their sessions
        // (fresh token + lease) for the next round.
        for &(i, dropped_cid) in &droppers {
            let (cid, token) = open(i, nonce)?;
            nonce += 1;
            debug_assert_eq!(cid, dropped_cid, "re-registration keeps the id");
            if let Some(c) = clients.iter_mut().find(|c| c.0 == i) {
                c.2 = token;
            }
        }
    }

    let (desc, metrics, _) = server.management.task_status(task)?;
    if desc.state != TaskState::Completed {
        return Err(Error::Task(format!(
            "device mix ended in state {} after {} rounds",
            desc.state.name(),
            metrics.rounds.len()
        )));
    }
    let mut evicted = 0u64;
    let mut backfilled = 0u64;
    for ev in events.drain() {
        match ev.kind() {
            "client_evicted" => evicted += 1,
            "cohort_backfilled" => backfilled += 1,
            _ => {}
        }
    }
    Ok((
        DeviceMixReport {
            n_clients: n,
            population_by_tier,
            uploads_by_tier,
            evicted,
            backfilled,
            rounds_completed: metrics.rounds.len() as u64,
            failed_rounds: metrics.failed_rounds,
            wall_ms: t0.elapsed().as_millis() as u64,
        },
        server.telemetry_report(),
    ))
}

/// Outcome of the hierarchical-aggregation scenario: the same seeded
/// fleet driven once through the flat path (every device uploads to the
/// root) and once through a `depth=2` leaf/master tree, demonstrating
/// multiplied ingest fan-in with bit-identical results.
#[derive(Clone, Debug)]
pub struct TreeScaleReport {
    pub n_clients: usize,
    pub leaves: u32,
    pub rounds_completed: u64,
    /// Ingest frames that reached the root per round on each path:
    /// `n_clients` flat vs `leaves` through the tree — the fan-in
    /// multiplication the leaf layer buys.
    pub root_frames_flat: u64,
    pub root_frames_tree: u64,
    /// Final model weights match bit-for-bit across the two paths.
    pub bit_identical: bool,
    pub max_abs_diff: f32,
    pub wall_ms: u64,
}

/// Run the §5.2 dummy task (all-ones deltas at unit weight) on the same
/// seeded fleet through both topologies and compare the final models.
/// The leaf plane goes through the typed router + interceptor chain
/// (`LeafAssign` / `ForwardPartial`), exactly as a deployed leaf would.
pub fn run_tree_scale(n: usize, rounds: u64, leaves: u32, seed: u64) -> Result<TreeScaleReport> {
    TreeSpec { depth: 2, leaves }.validate()?;
    if n < leaves as usize {
        return Err(Error::Config(format!(
            "tree scale needs >= 1 client per leaf ({n} clients, {leaves} leaves)"
        )));
    }
    if rounds == 0 {
        return Err(Error::Config("tree scale needs >= 1 round".into()));
    }
    const DIM: usize = 5;
    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();

    let make_server = |tag: &str| -> Result<(Arc<FloridaServer>, u64)> {
        let server = Arc::new(FloridaServer::with_evaluator(
            false,
            Arc::new(NoEval),
            seed,
            true,
        ));
        let task = TaskBuilder::new(tag)
            .clients_per_round(n)
            .rounds(rounds)
            .round_timeout_ms(120_000)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; DIM]))?
            .id();
        Ok((server, task))
    };
    // Everyone joins and fetches: the round's cohort forms.
    let form_cohort = |server: &FloridaServer, task: u64| -> Result<(u64, u64)> {
        let now = server.now_ms();
        for c in 1..=n as u64 {
            server.management.join(c, task, [0u8; 32], now)?;
        }
        for c in 1..=n as u64 {
            let _ = server.management.fetch_round(c, task, &server.selection, now)?;
        }
        server
            .management
            .with_task(task, |t| Ok((t.round, t.global.version)))
    };

    // Flat reference: every device uploads straight to the root.
    let (flat_srv, flat_task) = make_server("tree-scale-flat")?;
    for _ in 0..rounds {
        let (round, version) = form_cohort(&flat_srv, flat_task)?;
        for c in 1..=n as u64 {
            let (ok, why) = flat_srv.management.accept_plain(
                c,
                flat_task,
                round,
                version,
                vec![1.0; DIM],
                1.0,
                0.1,
                flat_srv.now_ms() + 1,
            )?;
            if !ok {
                return Err(Error::Task(why));
            }
        }
    }

    // Tree path: the same fleet, but uploads fold at `leaves` leaf
    // aggregators which each forward one partial through the router.
    let (tree_srv, tree_task) = make_server("tree-scale-tree")?;
    let stub = FloridaClient::direct(&tree_srv);
    for _ in 0..rounds {
        form_cohort(&tree_srv, tree_task)?;
        for li in 0..leaves {
            let mut leaf = LeafAggregator::new(LeafConfig {
                leaf_id: 1000 + li as u64,
                leaf_index: li,
                leaf_count: leaves,
                aggregator: "fedavg".into(),
                prox_mu: 0.0,
            });
            let a = leaf.claim(&stub, tree_task)?;
            if !a.accepted {
                return Err(Error::Task(format!("leaf {li}: {}", a.reason)));
            }
            let members = a.members.clone();
            leaf.begin_round(&a, DIM)?;
            for &m in &members {
                let (ok, why) = leaf.accept(m, a.round, &[1.0; DIM], 1.0, 0.1)?;
                if !ok {
                    return Err(Error::Task(format!("leaf {li} member {m}: {why}")));
                }
            }
            let ack = leaf.forward(&stub, tree_task)?;
            if ack.folded != members.len() as u64 {
                return Err(Error::Task(format!(
                    "leaf {li}: root credited {} of {} members",
                    ack.folded,
                    members.len()
                )));
            }
        }
    }

    for (srv, task, tag) in [(&flat_srv, flat_task, "flat"), (&tree_srv, tree_task, "tree")] {
        let (desc, metrics, _) = srv.management.task_status(task)?;
        if desc.state != TaskState::Completed || metrics.rounds.len() as u64 != rounds {
            return Err(Error::Task(format!(
                "{tag} path ended in state {} after {} rounds",
                desc.state.name(),
                metrics.rounds.len()
            )));
        }
    }
    let p_flat = flat_srv
        .management
        .with_task(flat_task, |t| Ok(t.global.params.clone()))?;
    let p_tree = tree_srv
        .management
        .with_task(tree_task, |t| Ok(t.global.params.clone()))?;
    let max_abs_diff = p_flat
        .iter()
        .zip(&p_tree)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    Ok(TreeScaleReport {
        n_clients: n,
        leaves,
        rounds_completed: rounds,
        root_frames_flat: n as u64,
        root_frames_tree: leaves as u64,
        bit_identical: p_flat == p_tree,
        max_abs_diff,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

/// Outcome of the sharded data-plane scenario: a simulated fleet
/// (default ~1M sessions) hammering the three hot-path primitives —
/// policy admission, lease renewal, upload ingest — once against a
/// single-shard plane and once against `shards` shards with the same
/// thread count, plus a round-exactness phase proving the sharded
/// partial-merge path commits the same weights as the flat fold.
#[derive(Clone, Debug)]
pub struct ShardScaleReport {
    pub shards: usize,
    /// Simulated sessions per throughput configuration.
    pub sessions: usize,
    /// Worker threads driving each configuration (same for both).
    pub threads: usize,
    /// Cores the host actually exposes (`available_parallelism`).
    pub cores: usize,
    pub poll_ops: u64,
    pub upload_ops: u64,
    /// Hot-path throughput, ops/sec, single shard vs `shards` shards.
    pub poll_ops_per_sec_flat: f64,
    pub poll_ops_per_sec_sharded: f64,
    pub upload_ops_per_sec_flat: f64,
    pub upload_ops_per_sec_sharded: f64,
    pub poll_speedup: f64,
    pub upload_speedup: f64,
    /// Exactness phase: rounds committed on each path.
    pub rounds_completed: u64,
    /// Flat fold == shards=1 plane (bitwise) == shards=N plane (the
    /// scenario feeds dyadic deltas, so every fold order is exact).
    pub bit_identical: bool,
    pub max_abs_diff: f32,
    pub wall_ms: u64,
}

impl ShardScaleReport {
    /// The acceptance gate the `scale --shards N` smoke enforces:
    /// commit-exactness always; near-linear (>= 0.7x ideal) hot-path
    /// scaling whenever both the partition and the host can express it.
    pub fn gate(&self) -> Result<()> {
        if !self.bit_identical {
            return Err(Error::Task(format!(
                "sharded commit diverged from the flat fold (max |diff| {})",
                self.max_abs_diff
            )));
        }
        if self.shards > 1 && self.cores > 1 {
            let want = 0.7 * self.shards.min(self.cores) as f64;
            if self.poll_speedup < want || self.upload_speedup < want {
                return Err(Error::Task(format!(
                    "sub-linear shard scaling: poll {:.2}x / upload {:.2}x, want >= {want:.2}x",
                    self.poll_speedup, self.upload_speedup
                )));
            }
        }
        Ok(())
    }
}

/// Dyadic delta for (client, round, coordinate): a multiple of 2^-10 in
/// [-1, 1), so every fold order — flat, per-lane, lane-then-root — sums
/// exactly in f64 and cross-shard comparisons can demand bitwise
/// equality instead of an epsilon.
fn dyadic_delta(client: u64, round: u64, j: usize) -> f32 {
    ((client * 7 + round * 13 + j as u64 * 3) % 2048) as f32 / 1024.0 - 1.0
}

/// Drive the three hot-path primitives over `sessions` simulated
/// clients with `threads` workers against an N-shard plane; returns
/// (poll ops/sec, upload ops/sec). Polls and uploads are timed as
/// separate phases so the two throughput numbers don't blur.
fn shard_hotpath_run(
    shards: usize,
    sessions: usize,
    threads: usize,
    polls_per_client: usize,
    dim: usize,
) -> Result<(f64, f64)> {
    let registry = ShardedSessions::with_shards(60_000, shards);
    let policy = ShardedPolicy::with_shards(PolicyConfig::enabled(), shards);
    let plane = ShardIngestPlane::new(1, "fedavg", 0.0, shards);
    let members: Vec<u64> = (1..=sessions as u64).collect();
    plane.begin_local(0, 0, &members, dim)?;

    let chunk = sessions.div_ceil(threads).max(1);
    let ranges: Vec<&[u64]> = members.chunks(chunk).collect();
    let refused = std::sync::atomic::AtomicU64::new(0);

    // Fleet arrival (untimed setup): v1 implicit sessions, no tokens.
    std::thread::scope(|s| {
        let registry = &registry;
        for &ids in &ranges {
            s.spawn(move || {
                for &id in ids {
                    registry.touch_v1(id, 0);
                }
            });
        }
    });

    // Poll phase: admission gate + lease renewal per op.
    // florida-lint: allow(wall-clock-in-core): throughput measurement, not round logic
    let t = std::time::Instant::now();
    std::thread::scope(|s| {
        let (registry, policy, refused) = (&registry, &policy, &refused);
        for &ids in &ranges {
            s.spawn(move || {
                for &id in ids {
                    for _ in 0..polls_per_client {
                        if policy.admit_principal(id, 0).is_err() {
                            refused.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        registry.touch_v1(id, 0);
                    }
                }
            });
        }
    });
    let poll_secs = t.elapsed().as_secs_f64().max(1e-9);

    // Upload phase: one shard-local fold per client.
    let delta = vec![1.0f32; dim];
    // florida-lint: allow(wall-clock-in-core): throughput measurement, not round logic
    let t = std::time::Instant::now();
    std::thread::scope(|s| {
        let (plane, delta, refused) = (&plane, &delta, &refused);
        for &ids in &ranges {
            s.spawn(move || {
                for &id in ids {
                    match plane.accept(id, 0, delta, 1.0, 0.1) {
                        Ok((true, _)) => {}
                        _ => {
                            refused.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let upload_secs = t.elapsed().as_secs_f64().max(1e-9);

    let refused = refused.load(std::sync::atomic::Ordering::Relaxed);
    if refused > 0 {
        return Err(Error::Task(format!(
            "hot-path run refused {refused} op(s); the scenario config admits everything"
        )));
    }
    Ok((
        (sessions * polls_per_client) as f64 / poll_secs,
        sessions as f64 / upload_secs,
    ))
}

/// Run the sharded data-plane scenario: throughput at 1 vs `shards`
/// shards over `sessions` simulated clients, then the exactness phase —
/// the same seeded cohort committed through the flat fold, a 1-shard
/// plane (bitwise-pinned) and an N-shard plane (dyadic-exact).
pub fn run_shard_scale(shards: usize, sessions: usize, seed: u64) -> Result<ShardScaleReport> {
    if shards == 0 || shards > crate::shard::MAX_SHARDS {
        return Err(Error::Config(format!(
            "shard scale needs 1..={} shards, got {shards}",
            crate::shard::MAX_SHARDS
        )));
    }
    if sessions < shards {
        return Err(Error::Config(format!(
            "shard scale needs >= 1 session per shard ({sessions} sessions, {shards} shards)"
        )));
    }
    const DIM: usize = 5;
    const POLLS_PER_CLIENT: usize = 2;
    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let threads = shards.min(cores).max(1);

    // -- Phase 1: hot-path throughput, same thread count both runs ----
    let (poll_flat, upload_flat) =
        shard_hotpath_run(1, sessions, threads, POLLS_PER_CLIENT, DIM)?;
    let (poll_sharded, upload_sharded) =
        shard_hotpath_run(shards, sessions, threads, POLLS_PER_CLIENT, DIM)?;

    // -- Phase 2: commit exactness on real servers --------------------
    let n = (shards * 6).max(24);
    let rounds = 2u64;
    let make_server = |tag: &str, server_shards: usize| -> Result<(Arc<FloridaServer>, u64)> {
        let server = Arc::new(FloridaServer::sharded(
            false,
            Arc::new(NoEval),
            seed,
            true,
            server_shards,
        ));
        let task = TaskBuilder::new(tag)
            .clients_per_round(n)
            .rounds(rounds)
            .round_timeout_ms(120_000)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; DIM]))?
            .id();
        Ok((server, task))
    };
    let form_cohort = |server: &FloridaServer, task: u64| -> Result<(u64, u64)> {
        let now = server.now_ms();
        for c in 1..=n as u64 {
            server.management.join(c, task, [0u8; 32], now)?;
        }
        for c in 1..=n as u64 {
            let _ = server.management.fetch_round(c, task, &server.selection, now)?;
        }
        server
            .management
            .with_task(task, |t| Ok((t.round, t.global.version)))
    };

    // Flat reference: every client folds straight at the root.
    let (flat_srv, flat_task) = make_server("shard-scale-flat", 1)?;
    for _ in 0..rounds {
        let (round, version) = form_cohort(&flat_srv, flat_task)?;
        for c in 1..=n as u64 {
            let delta: Vec<f32> = (0..DIM).map(|j| dyadic_delta(c, round, j)).collect();
            let (ok, why) = flat_srv.management.accept_plain(
                c,
                flat_task,
                round,
                version,
                delta,
                1.0,
                0.1,
                flat_srv.now_ms() + 1,
            )?;
            if !ok {
                return Err(Error::Task(why));
            }
        }
    }

    // Sharded planes: fold per shard lane, merge partials at commit.
    let mut params_by_shards = Vec::new();
    let mut rounds_completed = 0;
    for server_shards in [1usize, shards] {
        let (srv, task) = make_server(&format!("shard-scale-{server_shards}"), server_shards)?;
        let plane = ShardIngestPlane::new(task, "fedavg", 0.0, server_shards);
        for _ in 0..rounds {
            let (round, _) = form_cohort(&srv, task)?;
            plane.begin_round(&srv.management, DIM)?;
            for c in 1..=n as u64 {
                let delta: Vec<f32> = (0..DIM).map(|j| dyadic_delta(c, round, j)).collect();
                let (ok, why) = plane.accept(c, round, &delta, 1.0, 0.1)?;
                if !ok {
                    return Err(Error::Task(format!("client {c}: {why}")));
                }
            }
            let folded = plane.commit(&srv.management, srv.now_ms() + 1)?;
            if folded != n as u64 {
                return Err(Error::Task(format!(
                    "{server_shards}-shard commit credited {folded} of {n} members"
                )));
            }
        }
        let (desc, metrics, _) = srv.management.task_status(task)?;
        if desc.state != TaskState::Completed {
            return Err(Error::Task(format!(
                "{server_shards}-shard path ended in state {}",
                desc.state.name()
            )));
        }
        rounds_completed = metrics.rounds.len() as u64;
        params_by_shards.push(srv.management.with_task(task, |t| Ok(t.global.params.clone()))?);
    }
    let p_flat = flat_srv
        .management
        .with_task(flat_task, |t| Ok(t.global.params.clone()))?;
    let max_abs_diff = params_by_shards
        .iter()
        .flat_map(|p| p_flat.iter().zip(p).map(|(a, b)| (a - b).abs()))
        .fold(0.0f32, f32::max);
    let bit_identical = params_by_shards.iter().all(|p| *p == p_flat);

    Ok(ShardScaleReport {
        shards,
        sessions,
        threads,
        cores,
        poll_ops: (sessions * POLLS_PER_CLIENT) as u64,
        upload_ops: sessions as u64,
        poll_ops_per_sec_flat: poll_flat,
        poll_ops_per_sec_sharded: poll_sharded,
        upload_ops_per_sec_flat: upload_flat,
        upload_ops_per_sec_sharded: upload_sharded,
        poll_speedup: poll_sharded / poll_flat.max(1e-9),
        upload_speedup: upload_sharded / upload_flat.max(1e-9),
        rounds_completed,
        bit_identical,
        max_abs_diff,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

/// One cell of the adversarial sweep: one strategy at one attacker
/// fraction, scored by distance-to-optimum after the final round.
#[derive(Clone, Debug)]
pub struct ByzantinePoint {
    pub strategy: String,
    pub f: f64,
    pub n_byzantine: usize,
    /// Mean squared distance of the final global model from the known
    /// optimum (the scenario's ground truth), so "accuracy vs f" is a
    /// deterministic number rather than a stochastic eval.
    pub final_loss: f64,
}

/// Outcome of the adversarial-fleet scenario: the same seeded fleet
/// swept over attacker fractions with and without robust aggregation,
/// plus the hardened-admission sub-phase proving the policy engine
/// refuses a misbehaving client before any service sees it.
#[derive(Clone, Debug)]
pub struct ByzantineReport {
    pub n_clients: usize,
    pub rounds: u64,
    pub points: Vec<ByzantinePoint>,
    /// Requests the admission policy refused in the hardened sub-phase.
    pub policy_rejected: u64,
    /// The NaN-spamming attacker's reputation after its uploads were
    /// zero-scored (starts at 1.0, sinks below the admission floor).
    pub attacker_reputation: f64,
    pub wall_ms: u64,
}

impl ByzantineReport {
    pub fn loss_of(&self, strategy: &str, f: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.strategy == strategy && (p.f - f).abs() < 1e-9)
            .map(|p| p.final_loss)
    }

    /// The acceptance gate: at every swept fraction ≤ `f_max` the robust
    /// strategies hold final loss within 10% of their own clean (f = 0)
    /// baseline, while plain fedavg measurably degrades at `f_max`.
    pub fn gate(&self, f_max: f64) -> Result<()> {
        let base = |strategy: &str| {
            self.loss_of(strategy, 0.0)
                .ok_or_else(|| Error::Task(format!("missing f=0 baseline for {strategy}")))
        };
        for strategy in ["trimmed_mean", "median"] {
            let clean = base(strategy)?;
            for p in self.points.iter().filter(|p| {
                p.strategy == strategy && p.f > 0.0 && p.f <= f_max + 1e-9
            }) {
                if p.final_loss > clean * 1.10 + 1e-6 {
                    return Err(Error::Task(format!(
                        "{strategy} degraded at f={}: loss {:.3e} vs clean {:.3e}",
                        p.f, p.final_loss, clean
                    )));
                }
            }
        }
        if f_max > 0.0 {
            let clean = base("fedavg")?;
            let hit = self.loss_of("fedavg", f_max).ok_or_else(|| {
                Error::Task(format!("missing fedavg point at f={f_max}"))
            })?;
            if hit <= 10.0 * (clean + 1e-9) {
                return Err(Error::Task(format!(
                    "fedavg unexpectedly robust at f={f_max}: loss {hit:.3e} vs clean {clean:.3e}"
                )));
            }
        }
        if self.policy_rejected == 0 {
            return Err(Error::Task(
                "admission policy refused nothing in the hardened sub-phase".into(),
            ));
        }
        Ok(())
    }
}

/// Drive one strategy × fraction cell: `n` clients optimize toward an
/// all-ones target; `round(f·n)` of them are Byzantine, cycling through
/// magnitude-bomb (honest × 1e4), sign-flip (−honest), and label-flip
/// (descend toward −target) attacks. Driven synchronously through the
/// management API on a manual clock, so every cell is deterministic.
fn run_byzantine_cell(
    strategy: &str,
    f: f64,
    n: usize,
    rounds: u64,
    seed: u64,
) -> Result<ByzantinePoint> {
    const DIM: usize = 8;
    let n_byz = (f * n as f64).round() as usize;
    let server = FloridaServer::for_testing(false, seed);
    let mut cfg = crate::config::TaskConfig::default();
    cfg.task_name = format!("byzantine-{strategy}-{n_byz}");
    cfg.aggregator = strategy.into();
    cfg.trim_fraction = 0.25;
    cfg.clients_per_round = n;
    cfg.total_rounds = rounds;
    cfg.round_timeout_ms = 120_000;
    let task = TaskBuilder::from_config(cfg)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; DIM]))?
        .id();
    let opt = vec![1.0f32; DIM];
    for _ in 0..rounds {
        let now = server.now_ms();
        for c in 1..=n as u64 {
            server.management.join(c, task, [0u8; 32], now)?;
        }
        for c in 1..=n as u64 {
            let _ = server.management.fetch_round(c, task, &server.selection, now)?;
        }
        let (round, version, params) = server
            .management
            .with_task(task, |t| Ok((t.round, t.global.version, t.global.params.clone())))?;
        for c in 1..=n as u64 {
            // Honest clients take half a step toward the optimum.
            let honest: Vec<f32> = opt
                .iter()
                .zip(&params)
                .map(|(o, p)| (o - p) * 0.5)
                .collect();
            let idx = c as usize - 1;
            let delta: Vec<f32> = if idx < n_byz {
                match idx % 3 {
                    // Magnitude bomb: right direction, absurd scale.
                    0 => honest.iter().map(|d| d * 1e4).collect(),
                    // Sign flip: undo the honest fleet's work.
                    1 => honest.iter().map(|d| -d).collect(),
                    // Label flip: descend toward the opposite target.
                    _ => opt.iter().zip(&params).map(|(o, p)| (-o - p) * 0.5).collect(),
                }
            } else {
                honest
            };
            let (ok, why) = server
                .management
                .accept_plain(c, task, round, version, delta, 1.0, 0.1, now + 1)?;
            if !ok {
                return Err(Error::Task(format!(
                    "{strategy} f={f}: client {c} upload refused: {why}"
                )));
            }
        }
    }
    let params = server
        .management
        .with_task(task, |t| Ok(t.global.params.clone()))?;
    let loss = params
        .iter()
        .zip(&opt)
        .map(|(p, o)| ((p - o) as f64).powi(2))
        .sum::<f64>()
        / DIM as f64;
    Ok(ByzantinePoint {
        strategy: strategy.into(),
        f,
        n_byzantine: n_byz,
        // A diverged fedavg run can push f32 params to infinity; report
        // it as a huge finite loss so gate comparisons stay ordered.
        final_loss: if loss.is_finite() { loss } else { f64::MAX },
    })
}

/// Hardened-admission sub-phase: the same NaN-spamming adversary, but
/// the platform enforces [`crate::config::PolicyConfig`]. Each rejected
/// upload (`Ack { ok: false }` from the zero-scoring robust fold) feeds
/// the reputation ledger; once the attacker sinks below the floor, the
/// router refuses it before any service runs — while the honest cohort
/// member keeps uploading normally. Returns (policy rejections,
/// attacker reputation).
fn run_policy_demo(seed: u64) -> Result<(u64, f64)> {
    use crate::config::PolicyConfig;
    use crate::crypto::attest::IntegrityTier;
    use crate::proto::Msg;
    const DIM: usize = 4;
    let server = FloridaServer::for_testing(false, seed);
    server.policy.set_config(PolicyConfig {
        enabled: true,
        bucket_capacity: 64.0,
        refill_per_sec: 1.0,
        tenant_quota: 0,
        quota_window_ms: 1_000,
        min_reputation: 0.5,
        reputation_penalty: 0.3,
        reputation_recovery_per_sec: 0.01,
    })?;
    let task = TaskBuilder::new("byzantine-policy")
        .clients_per_round(2)
        .rounds(1)
        .aggregator("trimmed_mean")
        .round_timeout_ms(120_000)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; DIM]))?
        .id();
    let register = |dev: &str, nonce: u64| -> Result<u64> {
        let verdict =
            server
                .auth
                .authority()
                .issue(dev, IntegrityTier::Device, nonce, u64::MAX / 2);
        match server.handle(Msg::Register {
            device_id: dev.into(),
            verdict,
            caps: Default::default(),
        }) {
            Msg::RegisterAck {
                accepted: true,
                client_id,
                ..
            } => Ok(client_id),
            other => Err(Error::Task(format!("register {dev}: {other:?}"))),
        }
    };
    let honest = register("policy-honest", 1)?;
    let attacker = register("policy-attacker", 2)?;
    for c in [honest, attacker] {
        match server.handle(Msg::JoinRound {
            client_id: c,
            task_id: task,
            dh_pubkey: [0; 32],
        }) {
            Msg::JoinAck { accepted: true, .. } => {}
            other => return Err(Error::Task(format!("join {c}: {other:?}"))),
        }
        let _ = server.handle(Msg::FetchRound {
            client_id: c,
            task_id: task,
        });
    }
    let upload = |c: u64, delta: Vec<f32>| -> Msg {
        server.handle(Msg::UploadPlain {
            client_id: c,
            task_id: task,
            round: 0,
            base_version: 0,
            delta,
            weight: 1.0,
            loss: 0.1,
        })
    };
    // The attacker spams non-finite deltas. The robust fold zero-scores
    // each (Ack { ok: false } → one reputation offense); after enough
    // offenses the router refuses the request outright (ErrorReply
    // naming the reputation floor) — the engine never sees it.
    let mut engine_rejections = 0u64;
    let mut policy_refusals = 0u64;
    for _ in 0..6 {
        match upload(attacker, vec![f32::NAN; DIM]) {
            Msg::Ack { ok: false, .. } => engine_rejections += 1,
            Msg::ErrorReply { message } if message.contains("reputation") => {
                policy_refusals += 1
            }
            other => return Err(Error::Task(format!("attacker upload: {other:?}"))),
        }
    }
    if engine_rejections == 0 || policy_refusals == 0 {
        return Err(Error::Task(format!(
            "policy demo saw {engine_rejections} engine rejections, \
             {policy_refusals} policy refusals — expected both"
        )));
    }
    // The honest cohort member is unaffected.
    match upload(honest, vec![0.1; DIM]) {
        Msg::Ack { ok: true, .. } => {}
        other => return Err(Error::Task(format!("honest upload refused: {other:?}"))),
    }
    let reputation = server.policy.reputation_of(attacker).unwrap_or(1.0);
    Ok((server.policy.rejections(), reputation))
}

/// Run the adversarial-fleet sweep: attacker fractions {0, 0.1, 0.2,
/// 0.3} ∪ {f_max} across fedavg (undefended), trimmed-mean, and median,
/// then the hardened-admission sub-phase. `f_max` is the fraction the
/// CLI gate is asserted at; the honest majority requirement bounds it
/// below 0.5.
pub fn run_byzantine(n: usize, rounds: u64, f_max: f64, seed: u64) -> Result<ByzantineReport> {
    if n < 6 {
        return Err(Error::Config("byzantine sweep needs >= 6 clients".into()));
    }
    if rounds == 0 {
        return Err(Error::Config("byzantine sweep needs >= 1 round".into()));
    }
    if !(0.0..0.5).contains(&f_max) {
        return Err(Error::Config(format!(
            "byzantine fraction {f_max} outside [0, 0.5) — robustness needs an honest majority"
        )));
    }
    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();
    let mut fractions = vec![0.0, 0.1, 0.2, 0.3];
    if !fractions.iter().any(|&g| (g - f_max).abs() < 1e-9) {
        fractions.push(f_max);
        fractions.sort_by(f64::total_cmp);
    }
    let mut points = Vec::new();
    for strategy in ["fedavg", "trimmed_mean", "median"] {
        for &f in &fractions {
            points.push(run_byzantine_cell(strategy, f, n, rounds, seed)?);
        }
    }
    let (policy_rejected, attacker_reputation) = run_policy_demo(seed ^ 0xAD)?;
    Ok(ByzantineReport {
        n_clients: n,
        rounds,
        points,
        policy_rejected,
        attacker_reputation,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn churn_restart_retries_in_flight_round_and_reconverges() {
        let tmp = TempDir::new("churn").unwrap();
        let r = run_churn_restart(4, 3, 1, 11, tmp.path()).unwrap();
        assert_eq!(r.committed_before, 1);
        assert_eq!(r.interrupted_round, 1, "in-flight round keeps its number");
        assert!(r.version_preserved, "committed version must survive the kill");
        assert!(r.params_preserved, "committed weights must survive the kill");
        assert_eq!(r.rounds_to_reconverge, 2, "retry round 1, then round 2");
    }

    #[test]
    fn churn_restart_validates_inputs() {
        let tmp = TempDir::new("churn").unwrap();
        assert!(run_churn_restart(1, 3, 1, 0, tmp.path()).is_err());
        assert!(run_churn_restart(4, 3, 3, 0, tmp.path()).is_err());
        assert!(run_churn_restart(4, 3, 0, 0, tmp.path()).is_err());
    }

    #[test]
    fn device_mix_partitions_by_tier_and_backfills_evictions() {
        let r = run_device_mix(12, 2, 5).unwrap();
        assert_eq!(r.rounds_completed, 2);
        assert_eq!(r.failed_rounds, 0, "repair must beat the deadline path");
        assert_eq!(r.population_by_tier.iter().sum::<usize>(), 12);
        // Tiered selection: the high tier participates every round…
        assert!(r.uploads_by_tier[ComputeTier::High as usize] > 0);
        // …and the low tier participates ONLY via eviction backfill.
        assert!(r.evicted > 0, "stragglers must be lease-evicted");
        assert!(r.backfilled > 0, "evicted slots must be drafted from the pool");
        assert!(
            r.uploads_by_tier[ComputeTier::Low as usize] > 0,
            "backfill must pull the waiting low tier into the round"
        );
        // Every committed round was fully reported after repair.
        let total: u64 = r.uploads_by_tier.iter().sum();
        assert_eq!(total, 2 * (12 / 2) as u64, "k uploads per committed round");
    }

    #[test]
    fn device_mix_report_carries_the_telemetry_export() {
        let (r, telemetry) = run_device_mix_report(12, 2, 5).unwrap();
        assert_eq!(r.rounds_completed, 2);
        let committed = telemetry
            .counters
            .iter()
            .find(|(n, _)| *n == "rounds_committed")
            .unwrap()
            .1;
        assert_eq!(committed, 2);
        // Eviction counters agree with the event-stream tally.
        let evictions = telemetry
            .counters
            .iter()
            .find(|(n, _)| *n == "evictions")
            .unwrap()
            .1;
        assert_eq!(evictions, r.evicted);
        // Phase histograms populated; traces obey the sum invariant.
        let training = &telemetry
            .hists
            .iter()
            .find(|(n, _)| *n == "round_phase_training_ms")
            .unwrap()
            .1;
        assert_eq!(training.count, 2);
        assert_eq!(telemetry.rounds.len(), 2);
        for t in &telemetry.rounds {
            assert!(
                t.joining_ms + t.training_ms + t.unmasking_ms + t.commit_ms <= t.total_ms(),
                "phase sums must not exceed the round total"
            );
        }
        // Per-RPC quantiles ride along for the export surface.
        assert!(telemetry.rpc.iter().any(|m| m.method == "upload_plain"));
    }

    #[test]
    fn device_mix_validates_inputs() {
        assert!(run_device_mix(4, 2, 0).is_err());
        assert!(run_device_mix(12, 0, 0).is_err());
    }

    #[test]
    fn tree_scale_bit_identical_to_flat() {
        let r = run_tree_scale(12, 2, 4, 7).unwrap();
        assert_eq!(r.rounds_completed, 2);
        assert_eq!(r.root_frames_flat, 12, "flat: one frame per device");
        assert_eq!(r.root_frames_tree, 4, "tree: one frame per leaf");
        assert!(
            r.bit_identical,
            "dyadic all-ones folds must match exactly (max diff {})",
            r.max_abs_diff
        );
        assert_eq!(r.max_abs_diff, 0.0);
    }

    #[test]
    fn tree_scale_handles_uneven_slices() {
        // 10 clients over 4 leaves: slices of 3/3/2/2.
        let r = run_tree_scale(10, 1, 4, 3).unwrap();
        assert!(r.bit_identical);
    }

    #[test]
    fn shard_scale_commits_identical_weights() {
        // Small fleet for CI; the CLI default drives >= 2^20 sessions.
        let r = run_shard_scale(4, 4096, 7).unwrap();
        assert!(
            r.bit_identical,
            "sharded partial-merge must match the flat fold (max diff {})",
            r.max_abs_diff
        );
        assert_eq!(r.max_abs_diff, 0.0);
        assert_eq!(r.rounds_completed, 2);
        assert_eq!(r.poll_ops, 2 * 4096);
        assert_eq!(r.upload_ops, 4096);
        assert!(r.threads >= 1 && r.threads <= 4);
        // Speedup is host-dependent; the gate() is only enforced by the
        // `scale --shards N` smoke, where the fleet is large enough to
        // dominate thread startup. Exactness must hold regardless.
        assert!(r.poll_ops_per_sec_flat > 0.0 && r.upload_ops_per_sec_sharded > 0.0);
    }

    #[test]
    fn shard_scale_validates_inputs() {
        assert!(run_shard_scale(0, 100, 1).is_err());
        assert!(run_shard_scale(2, 1, 1).is_err());
        assert!(run_shard_scale(512, 100_000, 1).is_err());
    }

    #[test]
    fn byzantine_sweep_gates_robust_vs_fedavg() {
        let r = run_byzantine(10, 3, 0.2, 21).unwrap();
        r.gate(0.2).unwrap();
        // Undefended fedavg diverges by orders of magnitude under the
        // magnitude bomb; the robust strategies track their clean run.
        let clean = r.loss_of("fedavg", 0.0).unwrap();
        assert!(r.loss_of("fedavg", 0.2).unwrap() > 10.0 * clean);
        let tm_clean = r.loss_of("trimmed_mean", 0.0).unwrap();
        assert!(r.loss_of("trimmed_mean", 0.2).unwrap() <= tm_clean * 1.10 + 1e-6);
        let md_clean = r.loss_of("median", 0.0).unwrap();
        assert!(r.loss_of("median", 0.2).unwrap() <= md_clean * 1.10 + 1e-6);
        // The hardened sub-phase shed traffic pre-engine and sank the
        // attacker below the admission floor.
        assert!(r.policy_rejected > 0);
        assert!(r.attacker_reputation < 0.5);
    }

    #[test]
    fn byzantine_validates_inputs() {
        assert!(run_byzantine(4, 3, 0.2, 0).is_err(), "too few clients");
        assert!(run_byzantine(10, 0, 0.2, 0).is_err(), "zero rounds");
        assert!(run_byzantine(10, 3, 0.5, 0).is_err(), "no honest majority");
    }

    #[test]
    fn tree_scale_validates_inputs() {
        assert!(run_tree_scale(12, 2, 0, 0).is_err(), "depth 2 needs leaves");
        assert!(run_tree_scale(2, 2, 4, 0).is_err(), "fewer clients than leaves");
        assert!(run_tree_scale(12, 0, 4, 0).is_err(), "zero rounds");
    }
}
