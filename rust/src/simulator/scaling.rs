//! Scaling harness (§5.2): the dummy task at varying client counts.
//!
//! "The task consists in having each client generating an all-ones array
//! of size 5 and sending it to the server, which then aggregates all the
//! arrays." Reproduces Fig 11 (right): per-iteration duration vs number
//! of concurrent clients.

use std::path::Path;
use std::sync::Arc;

use crate::client::ConstantTrainer;
use crate::config::{FsyncPolicy, StorageConfig};
use crate::error::{Error, Result};
use crate::model::ModelSnapshot;
use crate::orchestrator::TaskBuilder;
use crate::proto::TaskState;
use crate::services::management::NoEval;
use crate::services::FloridaServer;
use crate::simulator::{run_fleet, FleetConfig, Heterogeneity};

/// One scaling measurement.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub n_clients: usize,
    /// Mean duration of one iteration (round), ms.
    pub round_ms: f64,
    /// Wall time for the whole run, ms.
    pub wall_ms: u64,
    pub rounds: usize,
    /// Registration phase duration (the §5 "70k devices" surge claim is
    /// about connection/registration capacity).
    pub register_ms: u64,
}

/// Run the dummy task with `n` concurrent clients for `rounds` rounds.
pub fn run_scaling_point(n: usize, rounds: u64, seed: u64) -> Result<ScalingPoint> {
    // Attestation off for the pure-throughput measurement (the paper's
    // dummy task measures orchestration cost, not crypto admission; the
    // secagg_vg_cost bench covers crypto).
    let server = Arc::new(FloridaServer::with_evaluator(
        false,
        Arc::new(NoEval),
        seed,
        true,
    ));
    // Dummy task: all-ones array of size 5.
    let task = TaskBuilder::new(&format!("dummy-scaling-{n}"))
        .clients_per_round(n)
        .rounds(rounds)
        .round_timeout_ms(120_000)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
        .id();

    let t0 = std::time::Instant::now();
    let fleet = FleetConfig {
        n_devices: n,
        heterogeneity: Heterogeneity::none(),
        base_compute_ms: 0,
        seed,
        poll_sleep_ms: 2,
    };
    let reports = run_fleet(&server, task, &fleet, |_| ConstantTrainer { step: 1.0 });
    let wall_ms = t0.elapsed().as_millis() as u64;

    let (_, metrics, _) = server.task_handle(task).status()?;
    let register_ms = server.selection.count() as u64; // count only; see bench
    let _ = reports;
    Ok(ScalingPoint {
        n_clients: n,
        round_ms: metrics.mean_round_duration_ms(),
        wall_ms,
        rounds: metrics.rounds.len(),
        register_ms,
    })
}

/// Outcome of the §Durability churn scenario: kill the server
/// mid-experiment, recover it from `state_dir`, and finish the task.
#[derive(Clone, Debug)]
pub struct ChurnRestartReport {
    pub n_clients: usize,
    /// Rounds committed before the kill.
    pub committed_before: u64,
    /// The round that was in flight when the server died (it is retried
    /// after recovery, never silently lost).
    pub interrupted_round: u64,
    /// Committed rounds the recovered server needed to finish the task —
    /// `total - committed_before`, since the interrupted round keeps its
    /// round number.
    pub rounds_to_reconverge: u64,
    /// Model version after recovery equals the pre-kill committed
    /// version (no committed work lost, no phantom commits).
    pub version_preserved: bool,
    /// Recovered weights match the pre-kill committed weights
    /// bit-for-bit.
    pub params_preserved: bool,
    pub wall_ms: u64,
}

/// Run the dummy task with durability on, kill the server after
/// `kill_after` committed rounds (mid-round, with a partial cohort
/// already uploaded), recover from `state_dir`, and drive the task to
/// completion. Rounds are driven synchronously through the management
/// API so the kill point is deterministic.
pub fn run_churn_restart(
    n: usize,
    total_rounds: u64,
    kill_after: u64,
    seed: u64,
    state_dir: &Path,
) -> Result<ChurnRestartReport> {
    if n < 2 {
        return Err(Error::Config("churn restart needs >= 2 clients".into()));
    }
    if !(1..total_rounds).contains(&kill_after) {
        return Err(Error::Config(format!(
            "kill_after must be in 1..{total_rounds}"
        )));
    }
    let storage = StorageConfig::new(state_dir).fsync(FsyncPolicy::Commit);
    let t0 = std::time::Instant::now();

    // One plaintext sync round through the management API: everyone
    // joins (forming the cohort), then `uploaders` clients report.
    fn drive(server: &FloridaServer, task: u64, n: usize, uploaders: usize) -> Result<()> {
        let now = server.now_ms();
        for c in 1..=n as u64 {
            server.management.join(c, task, [0u8; 32], now)?;
        }
        for c in 1..=n as u64 {
            let _ = server.management.fetch_round(c, task, &server.selection, now)?;
        }
        let (round, version) = server
            .management
            .with_task(task, |t| Ok((t.round, t.global.version)))?;
        for c in 1..=uploaders as u64 {
            let (ok, why) = server.management.accept_plain(
                c,
                task,
                round,
                version,
                vec![1.0; 5],
                1.0,
                0.1,
                now + 1,
            )?;
            if !ok {
                return Err(Error::Task(why));
            }
        }
        Ok(())
    }

    // Phase 1: run to the kill point, leaving a round in flight.
    let (task, committed_before, params_before, version_before) = {
        let server = Arc::new(FloridaServer::with_storage(
            false,
            Arc::new(NoEval),
            seed,
            true,
            storage.clone(),
        )?);
        let task = TaskBuilder::new("churn-restart")
            .clients_per_round(n)
            .rounds(total_rounds)
            .round_timeout_ms(120_000)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
            .id();
        for _ in 0..kill_after {
            drive(&server, task, n, n)?;
        }
        // Mid-experiment kill: half the cohort has already uploaded.
        drive(&server, task, n, n / 2)?;
        let snap = server
            .management
            .with_task(task, |t| Ok((t.global.params.clone(), t.global.version)))?;
        (task, kill_after, snap.0, snap.1)
    }; // server dropped: the crash

    // Phase 2: recover and reconverge.
    let server = Arc::new(FloridaServer::with_storage(
        false,
        Arc::new(NoEval),
        seed,
        true,
        storage,
    )?);
    let (interrupted_round, version_preserved, params_preserved) =
        server.management.with_task(task, |t| {
            Ok((
                t.round,
                t.global.version == version_before,
                t.global.params == params_before,
            ))
        })?;
    let mut rounds_after = 0u64;
    loop {
        let state = server.management.with_task(task, |t| Ok(t.state))?;
        if state != TaskState::Running {
            break;
        }
        if rounds_after > total_rounds + 2 {
            return Err(Error::Task("churn restart failed to reconverge".into()));
        }
        drive(&server, task, n, n)?;
        rounds_after += 1;
    }
    let (desc, metrics, _) = server.management.task_status(task)?;
    if desc.state != TaskState::Completed || metrics.rounds.len() as u64 != total_rounds {
        return Err(Error::Task(format!(
            "churn restart ended in state {} after {} committed rounds",
            desc.state.name(),
            metrics.rounds.len()
        )));
    }
    Ok(ChurnRestartReport {
        n_clients: n,
        committed_before,
        interrupted_round,
        rounds_to_reconverge: rounds_after,
        version_preserved,
        params_preserved,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn churn_restart_retries_in_flight_round_and_reconverges() {
        let tmp = TempDir::new("churn").unwrap();
        let r = run_churn_restart(4, 3, 1, 11, tmp.path()).unwrap();
        assert_eq!(r.committed_before, 1);
        assert_eq!(r.interrupted_round, 1, "in-flight round keeps its number");
        assert!(r.version_preserved, "committed version must survive the kill");
        assert!(r.params_preserved, "committed weights must survive the kill");
        assert_eq!(r.rounds_to_reconverge, 2, "retry round 1, then round 2");
    }

    #[test]
    fn churn_restart_validates_inputs() {
        let tmp = TempDir::new("churn").unwrap();
        assert!(run_churn_restart(1, 3, 1, 0, tmp.path()).is_err());
        assert!(run_churn_restart(4, 3, 3, 0, tmp.path()).is_err());
        assert!(run_churn_restart(4, 3, 0, 0, tmp.path()).is_err());
    }
}
