//! Scaling harness (§5.2): the dummy task at varying client counts.
//!
//! "The task consists in having each client generating an all-ones array
//! of size 5 and sending it to the server, which then aggregates all the
//! arrays." Reproduces Fig 11 (right): per-iteration duration vs number
//! of concurrent clients.

use std::sync::Arc;

use crate::client::ConstantTrainer;
use crate::error::Result;
use crate::model::ModelSnapshot;
use crate::orchestrator::TaskBuilder;
use crate::services::management::NoEval;
use crate::services::FloridaServer;
use crate::simulator::{run_fleet, FleetConfig, Heterogeneity};

/// One scaling measurement.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub n_clients: usize,
    /// Mean duration of one iteration (round), ms.
    pub round_ms: f64,
    /// Wall time for the whole run, ms.
    pub wall_ms: u64,
    pub rounds: usize,
    /// Registration phase duration (the §5 "70k devices" surge claim is
    /// about connection/registration capacity).
    pub register_ms: u64,
}

/// Run the dummy task with `n` concurrent clients for `rounds` rounds.
pub fn run_scaling_point(n: usize, rounds: u64, seed: u64) -> Result<ScalingPoint> {
    // Attestation off for the pure-throughput measurement (the paper's
    // dummy task measures orchestration cost, not crypto admission; the
    // secagg_vg_cost bench covers crypto).
    let server = Arc::new(FloridaServer::with_evaluator(
        false,
        Arc::new(NoEval),
        seed,
        true,
    ));
    // Dummy task: all-ones array of size 5.
    let task = TaskBuilder::new(&format!("dummy-scaling-{n}"))
        .clients_per_round(n)
        .rounds(rounds)
        .round_timeout_ms(120_000)
        .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))?
        .id();

    let t0 = std::time::Instant::now();
    let fleet = FleetConfig {
        n_devices: n,
        heterogeneity: Heterogeneity::none(),
        base_compute_ms: 0,
        seed,
        poll_sleep_ms: 2,
    };
    let reports = run_fleet(&server, task, &fleet, |_| ConstantTrainer { step: 1.0 });
    let wall_ms = t0.elapsed().as_millis() as u64;

    let (_, metrics, _) = server.task_handle(task).status()?;
    let register_ms = server.selection.count() as u64; // count only; see bench
    let _ = reports;
    Ok(ScalingPoint {
        n_clients: n,
        round_ms: metrics.mean_round_duration_ms(),
        wall_ms,
        rounds: metrics.rounds.len(),
        register_ms,
    })
}
