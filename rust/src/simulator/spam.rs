//! End-to-end spam-classification harness (§5.1) — the Fig-11 workload.
//!
//! Wires everything together: synthetic corpus → 100 shards → PJRT
//! runtime → HloTrainer devices → FloridaServer with HloEvaluator →
//! sync/async FL with optional local DP and secure aggregation.
//! Shared by `examples/spam_classification.rs`, the CLI `run-sim`
//! subcommand, and the Fig-11 benches.

use std::sync::Arc;

use crate::config::Manifest;
use crate::data::{SpamCorpus, SpamCorpusConfig};
use crate::dp::DpConfig;
use crate::error::Result;
use crate::metrics::RoundRecord;
use crate::model::ModelSnapshot;
use crate::orchestrator::TaskBuilder;
use crate::runtime::{HloEvaluator, HloTrainer, Runtime, ShardSampler};
use crate::services::FloridaServer;
use crate::simulator::{FleetConfig, Heterogeneity};

/// Configuration of one spam-FL run.
#[derive(Clone, Debug)]
pub struct SpamRunConfig {
    pub artifacts_dir: String,
    pub preset: String,
    /// Simulated devices (paper: 32; 16-node over-participation: 64).
    pub n_devices: usize,
    pub clients_per_round: usize,
    pub rounds: u64,
    /// None → sync; Some(k) → async with buffer size k.
    pub async_buffer: Option<usize>,
    pub secure_agg: bool,
    pub vg_size: usize,
    pub dp: DpConfig,
    pub client_lr: f32,
    pub prox_mu: f32,
    pub aggregator: String,
    /// Shards in the corpus (paper: 100).
    pub n_shards: usize,
    /// Dirichlet alpha for non-IID shards (None = IID).
    pub non_iid_alpha: Option<f64>,
    pub heterogeneity: Heterogeneity,
    /// Simulated nominal on-device compute per round (ms), scaled by each
    /// device's heterogeneity speed multiplier. Models slow phones whose
    /// wall-clock dominates the actual PJRT time on this host; 0 = off.
    pub sim_compute_ms: u64,
    pub seed: u64,
    pub runtime_workers: usize,
}

impl Default for SpamRunConfig {
    fn default() -> Self {
        SpamRunConfig {
            artifacts_dir: "artifacts".into(),
            preset: "tiny".into(),
            n_devices: 32,
            clients_per_round: 32,
            rounds: 10,
            async_buffer: None,
            secure_agg: false,
            vg_size: 16,
            dp: DpConfig::off(),
            client_lr: 5e-4,
            prox_mu: 0.0,
            aggregator: "fedavg".into(),
            n_shards: 100,
            non_iid_alpha: None,
            heterogeneity: Heterogeneity::none(),
            sim_compute_ms: 0,
            seed: 1234,
            runtime_workers: 1,
        }
    }
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct SpamRunResult {
    pub rounds: Vec<RoundRecord>,
    pub final_accuracy: f64,
    pub mean_round_ms: f64,
    pub total_wall_ms: u64,
    pub epsilon: Option<f64>,
    pub failed_rounds: u64,
}

/// Run the full §5.1 workload.
pub fn run_spam(cfg: &SpamRunConfig) -> Result<SpamRunResult> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let preset = manifest.preset(&cfg.preset)?.clone();

    // Corpus with the model's vocab/seq shape, 100 shards.
    let mut ccfg = SpamCorpusConfig::for_model(preset.vocab, preset.seq_len);
    ccfg.seed ^= cfg.seed;
    let corpus = match cfg.non_iid_alpha {
        None => SpamCorpus::generate(&ccfg, cfg.n_shards),
        Some(a) => SpamCorpus::generate_non_iid(&ccfg, cfg.n_shards, a),
    };
    let train = Arc::new(corpus.train);
    let test = Arc::new(corpus.test);
    let shards = corpus.shards;

    // PJRT runtime shared by all simulated devices + the evaluator.
    let rt = Runtime::new(manifest.clone(), cfg.runtime_workers)?;
    let evaluator = Arc::new(HloEvaluator::new(rt.handle(), preset.clone(), Arc::clone(&test)));

    let server = Arc::new(FloridaServer::with_evaluator(
        true,
        evaluator,
        cfg.seed,
        true,
    ));

    let aggregator = if cfg.async_buffer.is_some() && cfg.aggregator == "fedavg" {
        "fedbuff".to_string()
    } else {
        cfg.aggregator.clone()
    };
    let mut builder = TaskBuilder::new("spam-classification")
        .app("python-app")
        .workflow("python-workflow")
        .preset(&cfg.preset)
        .clients_per_round(cfg.clients_per_round)
        .rounds(cfg.rounds)
        .aggregator(&aggregator)
        .client_lr(cfg.client_lr)
        .prox_mu(cfg.prox_mu)
        .dp(cfg.dp)
        .dp_population(cfg.n_shards) // paper: pool of 100 clients
        .round_timeout_ms(600_000)
        .min_report_fraction(0.75);
    if let Some(k) = cfg.async_buffer {
        builder = builder.buffered_async(k);
    }
    if cfg.secure_agg {
        builder = builder.secure_agg(cfg.vg_size);
    }

    let init = ModelSnapshot::from_f32_file(&manifest.path_of(&preset.init_path))?;
    let handle = builder.deploy(&server.management, init)?;
    let task_id = handle.id();
    // Round-lifecycle log via the event stream (the §3.3 dashboard view).
    let events = handle.subscribe();
    // Detached: exits when the task completes or the server drops.
    let _event_logger = std::thread::spawn(move || {
        while let Some(ev) = events.next_timeout(std::time::Duration::from_secs(1800)) {
            log::info!("spam-sim event: {} (task {})", ev.kind(), ev.task_id());
            if matches!(
                ev,
                crate::orchestrator::TaskEvent::TaskCompleted { .. }
                    | crate::orchestrator::TaskEvent::TaskStateChanged {
                        state: crate::proto::TaskState::Cancelled
                            | crate::proto::TaskState::Failed,
                        ..
                    }
            ) {
                break;
            }
        }
    });

    // Build per-device trainers: each device samples a random shard per
    // round — approximated by giving device i shard (i + round) % S via a
    // fixed random shard here (paper: "each client accesses one of the
    // 100 splits at random").
    let fleet = FleetConfig {
        n_devices: cfg.n_devices,
        heterogeneity: cfg.heterogeneity,
        base_compute_ms: 0,
        seed: cfg.seed,
        poll_sleep_ms: 1,
    };
    let local_dp = if cfg.dp.mode == crate::dp::DpMode::Local {
        Some(cfg.dp)
    } else {
        None
    };

    // Pre-sample device heterogeneity profiles (speed multipliers).
    let profiles: Vec<crate::simulator::DeviceProfile> = {
        let mut prng = crate::util::Rng::new(cfg.seed ^ 0xBEEF);
        (0..cfg.n_devices)
            .map(|_| cfg.heterogeneity.sample(&mut prng))
            .collect()
    };
    let sim_compute_ms = cfg.sim_compute_ms;

    // florida-lint: allow(wall-clock-in-core): wall_ms run reporting, not round logic
    let t0 = std::time::Instant::now();
    let rt_for_devices = Arc::clone(&rt);
    let reports = run_fleet_with_dp(&server, task_id, &fleet, local_dp, |i| {
        let mut rng = crate::util::Rng::new(cfg.seed ^ (i as u64) << 17);
        let shard_id = rng.range(0, shards.len());
        let sampler = ShardSampler::new(
            Arc::clone(&train),
            shards[shard_id].clone(),
            0.2, // paper: 20% of the split per iteration
            cfg.seed ^ (i as u64 + 1),
        );
        crate::simulator::SimulatedCompute {
            inner: HloTrainer::new(rt_for_devices.handle(), preset.clone(), sampler),
            base_ms: sim_compute_ms,
            profile: profiles[i],
        }
    });
    let total_wall_ms = t0.elapsed().as_millis() as u64;

    let (_, metrics, epsilon) = server.task_handle(task_id).status()?;
    let final_accuracy = metrics
        .rounds
        .iter()
        .rev()
        .find_map(|r| r.eval_accuracy)
        .unwrap_or(f64::NAN);
    let _ = reports;
    Ok(SpamRunResult {
        mean_round_ms: metrics.mean_round_duration_ms(),
        final_accuracy,
        total_wall_ms,
        epsilon,
        failed_rounds: metrics.failed_rounds,
        rounds: metrics.rounds,
    })
}

/// `run_fleet` with client-side local DP injection.
fn run_fleet_with_dp<F, T>(
    server: &Arc<FloridaServer>,
    task_id: u64,
    cfg: &FleetConfig,
    local_dp: Option<DpConfig>,
    make_trainer: F,
) -> Vec<crate::client::ExecutionReport>
where
    F: Fn(usize) -> T + Send + Sync,
    T: crate::client::Trainer + 'static,
{
    use crate::client::{DirectApi, FederatedLearningClient};
    use crate::crypto::attest::IntegrityTier;
    use crate::proto::DeviceCaps;
    use std::sync::atomic::{AtomicBool, Ordering};

    let stop = Arc::new(AtomicBool::new(false));
    let ticker = {
        let server = Arc::clone(server);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                server.tick();
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        })
    };
    let reports: Vec<crate::client::ExecutionReport> = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(cfg.n_devices);
        for i in 0..cfg.n_devices {
            let server = Arc::clone(server);
            let trainer = make_trainer(i);
            let seed = cfg.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let builder = std::thread::Builder::new()
                .name(format!("device-{i}"))
                .stack_size(1 << 20);
            joins.push(
                builder
                    .spawn_scoped(scope, move || {
                        let device_id = format!("sim-device-{i}");
                        let verdict = server.auth.authority().issue(
                            &device_id,
                            IntegrityTier::Device,
                            seed,
                            u64::MAX / 2,
                        );
                        let mut client = FederatedLearningClient::new(
                            Box::new(DirectApi {
                                server: Arc::clone(&server),
                            }),
                            &device_id,
                            verdict,
                            DeviceCaps::default(),
                            seed,
                        );
                        client.local_dp = local_dp;
                        let mut report = Default::default();
                        let mut tr = trainer;
                        // Session protocol v2, with v1 register fallback.
                        match client.open_session() {
                            Ok(_) => {
                                if let Err(e) = client.run_task(task_id, &mut tr, &mut report) {
                                    log::warn!("device {i}: {e}");
                                }
                            }
                            Err(e) => log::warn!("device {i} session open failed: {e}"),
                        }
                        report
                    })
                    .expect("spawn device"),
            );
        }
        joins
            .into_iter()
            .map(|j| j.join().unwrap_or_default())
            .collect()
    });
    stop.store(true, Ordering::SeqCst);
    let _ = ticker.join();
    reports
}
