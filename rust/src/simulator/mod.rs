//! Multi-client device simulator (§5's AzureML-simulator substitute).
//!
//! Runs N simulated devices against an in-process [`FloridaServer`]: each
//! device is a thread executing the real SDK protocol loop with a real
//! trainer (PJRT `HloTrainer` or the §5.2 dummy `ConstantTrainer`), with
//! per-device heterogeneity (compute speed, network delay, dropout).

pub mod scaling;
pub mod spam;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::client::{
    DirectApi, ExecutionReport, FederatedLearningClient, ServerApi, TrainOutcome, Trainer,
};
use crate::crypto::attest::IntegrityTier;
use crate::error::Result;
use crate::model::ModelSnapshot;
use crate::proto::{DeviceCaps, Msg};
use crate::services::FloridaServer;
use crate::util::Rng;

/// Per-device heterogeneity profile.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    /// Multiplier on simulated compute time (1.0 = nominal).
    pub speed_mult: f64,
    /// One-way network delay applied around server calls.
    pub network_delay_ms: u64,
    /// Probability the device drops after training (upload lost).
    pub dropout_prob: f64,
}

impl Default for DeviceProfile {
    fn default() -> Self {
        DeviceProfile {
            speed_mult: 1.0,
            network_delay_ms: 0,
            dropout_prob: 0.0,
        }
    }
}

impl DeviceProfile {
    /// Report the simulated hardware to the server as the protocol-v2
    /// heterogeneity axes (`SessionOpen`'s device profile): slow devices
    /// read as low compute tier, delayed links as constrained bandwidth.
    pub fn wire_profile(&self) -> crate::proto::DeviceProfile {
        use crate::proto::{BandwidthClass, ComputeTier};
        crate::proto::DeviceProfile {
            compute_tier: if self.speed_mult <= 0.8 {
                ComputeTier::High
            } else if self.speed_mult <= 1.5 {
                ComputeTier::Mid
            } else {
                ComputeTier::Low
            },
            bandwidth: if self.network_delay_ms == 0 {
                BandwidthClass::Fast
            } else if self.network_delay_ms <= 3 {
                BandwidthClass::Broadband
            } else {
                BandwidthClass::Constrained
            },
            avail_window_ms: 0,
        }
    }
}

/// Fleet-level heterogeneity distribution (log-normal speeds — the usual
/// straggler model; cf. §2 "client heterogeneity").
#[derive(Clone, Copy, Debug)]
pub struct Heterogeneity {
    pub speed_sigma: f64,
    pub base_delay_ms: u64,
    pub delay_jitter_ms: u64,
    pub dropout_prob: f64,
}

impl Heterogeneity {
    pub fn none() -> Heterogeneity {
        Heterogeneity {
            speed_sigma: 0.0,
            base_delay_ms: 0,
            delay_jitter_ms: 0,
            dropout_prob: 0.0,
        }
    }

    /// Moderate heterogeneity used by the Fig-11 center experiment.
    pub fn moderate() -> Heterogeneity {
        Heterogeneity {
            speed_sigma: 0.5,
            base_delay_ms: 2,
            delay_jitter_ms: 3,
            dropout_prob: 0.0,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> DeviceProfile {
        DeviceProfile {
            speed_mult: rng.lognormal(0.0, self.speed_sigma),
            network_delay_ms: self.base_delay_ms
                + if self.delay_jitter_ms > 0 {
                    rng.below(self.delay_jitter_ms) as u64
                } else {
                    0
                },
            dropout_prob: self.dropout_prob,
        }
    }
}

/// Trainer wrapper injecting simulated compute latency.
pub struct SimulatedCompute<T: Trainer> {
    pub inner: T,
    /// Nominal per-round compute time before the speed multiplier.
    pub base_ms: u64,
    pub profile: DeviceProfile,
}

impl<T: Trainer> Trainer for SimulatedCompute<T> {
    fn train(
        &mut self,
        model: &ModelSnapshot,
        round: u64,
        lr: f32,
        prox_mu: f32,
    ) -> Result<TrainOutcome> {
        if self.base_ms > 0 {
            let ms = (self.base_ms as f64 * self.profile.speed_mult) as u64;
            thread::sleep(Duration::from_millis(ms));
        }
        self.inner.train(model, round, lr, prox_mu)
    }
}

/// ServerApi wrapper injecting network delay.
pub struct DelayedApi {
    pub inner: Box<dyn ServerApi>,
    pub delay_ms: u64,
}

impl ServerApi for DelayedApi {
    fn call_traced(&self, msg: Msg, trace_id: Option<u64>) -> Result<Msg> {
        if self.delay_ms > 0 {
            thread::sleep(Duration::from_millis(self.delay_ms));
        }
        let r = self.inner.call_traced(msg, trace_id);
        if self.delay_ms > 0 {
            thread::sleep(Duration::from_millis(self.delay_ms));
        }
        r
    }
}

/// Fleet run configuration.
pub struct FleetConfig {
    pub n_devices: usize,
    pub heterogeneity: Heterogeneity,
    /// Simulated nominal compute per round (0 = none; real PJRT time
    /// still applies for HloTrainer).
    pub base_compute_ms: u64,
    pub seed: u64,
    /// Poll sleep for device loops.
    pub poll_sleep_ms: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_devices: 32,
            heterogeneity: Heterogeneity::none(),
            base_compute_ms: 0,
            seed: 7,
            poll_sleep_ms: 1,
        }
    }
}

/// Run a fleet of devices against `task_id` until the task completes.
/// `make_trainer(i)` builds device i's trainer. Returns per-device reports.
pub fn run_fleet<F, T>(
    server: &Arc<FloridaServer>,
    task_id: u64,
    cfg: &FleetConfig,
    make_trainer: F,
) -> Vec<ExecutionReport>
where
    F: Fn(usize) -> T + Send + Sync,
    T: Trainer + 'static,
{
    let stop = Arc::new(AtomicBool::new(false));

    // Deadline-sweep thread (real-clock tick while the fleet runs).
    let ticker = {
        let server = Arc::clone(server);
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                server.tick();
                thread::sleep(Duration::from_millis(20));
            }
        })
    };

    let mut rng = Rng::new(cfg.seed);
    let profiles: Vec<DeviceProfile> = (0..cfg.n_devices)
        .map(|_| cfg.heterogeneity.sample(&mut rng))
        .collect();

    let reports: Vec<ExecutionReport> = thread::scope(|scope| {
        let mut joins = Vec::with_capacity(cfg.n_devices);
        for i in 0..cfg.n_devices {
            let server = Arc::clone(server);
            let profile = profiles[i];
            let trainer = make_trainer(i);
            let seed = cfg.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let poll_sleep = cfg.poll_sleep_ms;
            let base_ms = cfg.base_compute_ms;
            let builder = thread::Builder::new()
                .name(format!("device-{i}"))
                .stack_size(1 << 20);
            joins.push(
                builder
                    .spawn_scoped(scope, move || {
                        run_device(server, task_id, i, trainer, profile, seed, poll_sleep, base_ms)
                    })
                    .expect("spawn device"),
            );
        }
        joins
            .into_iter()
            .map(|j| j.join().unwrap_or_default())
            .collect()
    });

    stop.store(true, Ordering::SeqCst);
    let _ = ticker.join();
    reports
}

#[allow(clippy::too_many_arguments)]
fn run_device<T: Trainer + 'static>(
    server: Arc<FloridaServer>,
    task_id: u64,
    index: usize,
    trainer: T,
    profile: DeviceProfile,
    seed: u64,
    poll_sleep_ms: u64,
    base_compute_ms: u64,
) -> ExecutionReport {
    let device_id = format!("sim-device-{index}");
    // Obtain a verdict from the simulated integrity authority.
    let verdict = server.auth.authority().issue(
        &device_id,
        IntegrityTier::Device,
        seed, // unique nonce per device
        u64::MAX / 2,
    );
    let api: Box<dyn ServerApi> = Box::new(DelayedApi {
        inner: Box::new(DirectApi {
            server: Arc::clone(&server),
        }),
        delay_ms: profile.network_delay_ms,
    });
    let mut client = FederatedLearningClient::new(
        api,
        &device_id,
        verdict,
        DeviceCaps::default(),
        seed,
    );
    client.profile = profile.wire_profile();
    client.dropout_prob = profile.dropout_prob;
    client.poll_sleep_ms = poll_sleep_ms;
    let mut report = ExecutionReport::default();
    // Session protocol v2: negotiate a session (falls back to the v1
    // one-shot register against servers that don't speak it).
    if client.open_session().is_err() {
        return report;
    }
    let mut sim = SimulatedCompute {
        inner: trainer,
        base_ms: base_compute_ms,
        profile,
    };
    match client.run_task(task_id, &mut sim, &mut report) {
        Ok(()) => report,
        Err(e) => {
            log::debug!("device {index}: {e}");
            report
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::ConstantTrainer;
    use crate::orchestrator::{TaskBuilder, TaskEvent};
    use crate::proto::TaskState;

    fn sim_server() -> Arc<FloridaServer> {
        Arc::new(FloridaServer::with_evaluator(
            true,
            Arc::new(crate::services::management::NoEval),
            42,
            true, // real clock — fleet threads need real deadlines
        ))
    }

    fn dummy_task(n: usize, rounds: u64, secagg: bool) -> TaskBuilder {
        let b = TaskBuilder::new("dummy")
            .clients_per_round(n)
            .rounds(rounds)
            .round_timeout_ms(20_000);
        if secagg {
            b.secure_agg(8)
        } else {
            b
        }
    }

    fn dummy_server_task(n: usize, rounds: u64, secagg: bool) -> (Arc<FloridaServer>, u64) {
        let server = sim_server();
        let id = dummy_task(n, rounds, secagg)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))
            .unwrap()
            .id();
        (server, id)
    }

    #[test]
    fn fleet_completes_dummy_task() {
        let (server, task) = dummy_server_task(8, 2, false);
        // Observe the lifecycle through the event stream, not polling.
        let events = server.task_handle(task).subscribe();
        let cfg = FleetConfig {
            n_devices: 8,
            ..Default::default()
        };
        let reports = run_fleet(&server, task, &cfg, |_| ConstantTrainer { step: 1.0 });
        assert!(reports.iter().all(|r| r.task_completed));
        let seen = events.drain();
        assert!(seen.iter().any(|ev| ev.kind() == "task_completed"));
        assert_eq!(
            seen.iter()
                .filter(|ev| matches!(ev, TaskEvent::RoundCommitted { .. }))
                .count(),
            2
        );
        let (desc, metrics, _) = server.task_handle(task).status().unwrap();
        assert_eq!(desc.state, TaskState::Completed);
        assert_eq!(metrics.rounds.len(), 2);
        // All-ones aggregation: model should be +1 per round.
        server
            .management
            .with_task(task, |t| {
                for p in &t.global.params {
                    assert!((p - 2.0).abs() < 1e-4, "{p}");
                }
                Ok(())
            })
            .unwrap();
    }

    #[test]
    fn fleet_with_secagg_matches_plain_aggregation() {
        let (server, task) = dummy_server_task(8, 1, true);
        let cfg = FleetConfig {
            n_devices: 8,
            ..Default::default()
        };
        let reports = run_fleet(&server, task, &cfg, |_| ConstantTrainer { step: 0.5 });
        assert!(reports.iter().all(|r| r.task_completed));
        server
            .management
            .with_task(task, |t| {
                for p in &t.global.params {
                    // 0.5 recovered through quantize→mask→sum→dequantize.
                    assert!((p - 0.5).abs() < 0.01, "{p}");
                }
                Ok(())
            })
            .unwrap();
    }

    #[test]
    fn fleet_survives_dropouts_with_secagg() {
        let server = sim_server();
        // Short timeout so dropped uploads trigger the unmask path quickly.
        let task = dummy_task(8, 1, true)
            .round_timeout_ms(1500)
            .min_report_fraction(0.5)
            .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 5]))
            .unwrap()
            .id();
        let mut cfg = FleetConfig {
            n_devices: 8,
            ..Default::default()
        };
        cfg.heterogeneity.dropout_prob = 0.25;
        let _reports = run_fleet(&server, task, &cfg, |_| ConstantTrainer { step: 1.0 });
        let (desc, metrics, _) = server.task_handle(task).status().unwrap();
        // Either the round committed with survivors or was retried and
        // then committed — the task must end Completed with >=1 round.
        assert_eq!(desc.state, TaskState::Completed);
        assert!(!metrics.rounds.is_empty());
        assert!(metrics.rounds[0].participants >= 4);
    }

    #[test]
    fn heterogeneity_sampling_shapes() {
        let h = Heterogeneity::moderate();
        let mut rng = Rng::new(1);
        let profiles: Vec<DeviceProfile> = (0..200).map(|_| h.sample(&mut rng)).collect();
        let speeds: Vec<f64> = profiles.iter().map(|p| p.speed_mult).collect();
        let mean = speeds.iter().sum::<f64>() / speeds.len() as f64;
        assert!(mean > 0.8 && mean < 1.6, "{mean}");
        assert!(speeds.iter().any(|&s| s > 1.5));
        assert!(speeds.iter().any(|&s| s < 0.7));
        assert!(profiles.iter().all(|p| p.network_delay_ms >= 2));
    }
}
