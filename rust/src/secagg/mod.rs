//! Secure-aggregation key schedule and mask math (§4.1) — shared by the
//! client SDK participant and the server-side Secure Aggregator so the
//! two sides agree bit-for-bit (the paper's "cross-platform compatible
//! KDF" requirement).
//!
//! Protocol (Bonawitz et al. 2016 pairwise masks, one DH keypair per
//! client per round):
//!
//! 1. Each VG member i advertises a per-round X25519 public key pk_i.
//! 2. For each peer pair (i, j): shared_ij = DH(sk_i, pk_j) = DH(sk_j, pk_i);
//!    mask stream m_ij = AES-CTR(HKDF(shared_ij, "mask|task|round|lo|hi")).
//! 3. Client i uploads y_i = q(x_i) + Σ_{j>i} m_ij − Σ_{j<i} m_ij (mod 2³²).
//!    Σ_i y_i = Σ_i q(x_i) by cancellation.
//! 4. Dropout recovery: i Shamir-shares its DH *seed* among the VG
//!    (shares encrypted under HKDF(shared_ij, "share|...")); the Secure
//!    Aggregator reconstructs a dropped seed from t survivor shares and
//!    removes the orphaned masks.

use crate::crypto::hkdf;
use crate::crypto::prg::MaskPrg;
use crate::crypto::x25519::{KeyPair, PublicKey, SharedSecret};

/// Domain-separation salt for all secagg derivations.
const SALT: &[u8] = b"florida-secagg-v1";

/// Pairwise mask key — symmetric in (a, b).
pub fn mask_key(shared: &SharedSecret, task_id: u64, round: u64, a: u64, b: u64) -> [u8; 16] {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let mut info = Vec::with_capacity(5 + 32);
    info.extend_from_slice(b"mask|");
    info.extend_from_slice(&task_id.to_le_bytes());
    info.extend_from_slice(&round.to_le_bytes());
    info.extend_from_slice(&lo.to_le_bytes());
    info.extend_from_slice(&hi.to_le_bytes());
    hkdf::derive_key16(SALT, &shared.0, &info)
}

/// Directional share-encryption key (from → to).
pub fn share_enc_key(
    shared: &SharedSecret,
    task_id: u64,
    round: u64,
    from: u64,
    to: u64,
) -> [u8; 16] {
    let mut info = Vec::with_capacity(6 + 32);
    info.extend_from_slice(b"share|");
    info.extend_from_slice(&task_id.to_le_bytes());
    info.extend_from_slice(&round.to_le_bytes());
    info.extend_from_slice(&from.to_le_bytes());
    info.extend_from_slice(&to.to_le_bytes());
    hkdf::derive_key16(SALT, &shared.0, &info)
}

/// XOR-encrypt/decrypt with the AES-CTR keystream (symmetric).
pub fn stream_xor(key: [u8; 16], data: &[u8]) -> Vec<u8> {
    let mut prg = MaskPrg::new(key);
    let words = prg.mask_vec((data.len() + 3) / 4);
    let mut ks = Vec::with_capacity(data.len());
    for w in words {
        ks.extend_from_slice(&w.to_le_bytes());
    }
    data.iter().zip(ks).map(|(d, k)| d ^ k).collect()
}

/// Apply all pairwise masks for member `me` of `roster` onto `acc`
/// (already containing the quantized update). Sign convention:
/// +m for peers with larger id, −m for smaller.
pub fn apply_pairwise_masks(
    acc: &mut [u32],
    me: u64,
    kp: &KeyPair,
    roster: &[(u64, [u8; 32])],
    task_id: u64,
    round: u64,
) {
    for &(peer, pk) in roster {
        if peer == me {
            continue;
        }
        let shared = kp.agree(&PublicKey(pk));
        let key = mask_key(&shared, task_id, round, me, peer);
        let sign = if peer > me { 1 } else { -1 };
        MaskPrg::new(key).apply_mask(acc, sign);
    }
}

/// Recompute the mask stream between a reconstructed dropped client and a
/// survivor, as seen *from the survivor's upload*, and remove it from
/// the VG sum. The survivor `surv` applied sign = +1 if dropped > surv
/// else −1; we apply the opposite.
pub fn remove_orphan_mask(
    acc: &mut [u32],
    dropped_kp: &KeyPair,
    dropped_id: u64,
    surv_id: u64,
    surv_pk: &[u8; 32],
    task_id: u64,
    round: u64,
) {
    let shared = dropped_kp.agree(&PublicKey(*surv_pk));
    let key = mask_key(&shared, task_id, round, dropped_id, surv_id);
    let sign_applied_by_survivor = if dropped_id > surv_id { 1 } else { -1 };
    MaskPrg::new(key).apply_mask(acc, -sign_applied_by_survivor);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{add_mod, Quantizer};
    use crate::util::Rng;

    fn keypairs(n: usize, seed: u64) -> Vec<KeyPair> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| KeyPair::generate(&mut rng)).collect()
    }

    #[test]
    fn mask_key_symmetric_in_pair() {
        let kps = keypairs(2, 1);
        let s01 = kps[0].agree(&kps[1].public());
        let s10 = kps[1].agree(&kps[0].public());
        assert_eq!(
            mask_key(&s01, 7, 3, 10, 20),
            mask_key(&s10, 7, 3, 20, 10)
        );
        // Different round/task/pair → different key.
        assert_ne!(mask_key(&s01, 7, 3, 10, 20), mask_key(&s01, 7, 4, 10, 20));
        assert_ne!(mask_key(&s01, 8, 3, 10, 20), mask_key(&s01, 7, 3, 10, 20));
    }

    #[test]
    fn stream_xor_roundtrip() {
        let key = [9u8; 16];
        let msg = b"shamir share payload xyz".to_vec();
        let ct = stream_xor(key, &msg);
        assert_ne!(ct, msg);
        assert_eq!(stream_xor(key, &ct), msg);
    }

    #[test]
    fn full_vg_masks_cancel() {
        // 5 clients, random updates: Σ masked == Σ quantized.
        let n = 5;
        let dim = 301;
        let kps = keypairs(n, 2);
        let ids: Vec<u64> = (0..n as u64).map(|i| i * 3 + 1).collect();
        let roster: Vec<(u64, [u8; 32])> = ids
            .iter()
            .zip(&kps)
            .map(|(&id, kp)| (id, kp.public().0))
            .collect();
        let q = Quantizer::new(1.0, 16).unwrap();
        let mut rng = Rng::new(3);
        let mut plain_sum = vec![0u32; dim];
        let mut masked_sum = vec![0u32; dim];
        for (i, kp) in kps.iter().enumerate() {
            let x: Vec<f32> = (0..dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let qx = q.quantize(&x);
            add_mod(&mut plain_sum, &qx);
            let mut y = qx;
            apply_pairwise_masks(&mut y, ids[i], kp, &roster, 42, 7, );
            add_mod(&mut masked_sum, &y);
        }
        assert_eq!(masked_sum, plain_sum);
    }

    #[test]
    fn single_masked_update_looks_random() {
        // One masked upload must not equal the quantized plaintext.
        let kps = keypairs(2, 4);
        let roster = vec![(1u64, kps[0].public().0), (2u64, kps[1].public().0)];
        let q = Quantizer::new(1.0, 16).unwrap();
        let x = vec![0.5f32; 64];
        let qx = q.quantize(&x);
        let mut y = qx.clone();
        apply_pairwise_masks(&mut y, 1, &kps[0], &roster, 1, 1);
        assert_ne!(y, qx);
        let diffs = y.iter().zip(&qx).filter(|(a, b)| a != b).count();
        assert!(diffs > 60);
    }

    #[test]
    fn orphan_mask_removal_recovers_survivor_sum() {
        // 4 clients; client with id ids[3] uploads nothing. Survivors'
        // masked sum + orphan removal == survivors' plain sum.
        let n = 4;
        let dim = 129;
        let kps = keypairs(n, 5);
        let ids: Vec<u64> = vec![2, 5, 9, 11];
        let roster: Vec<(u64, [u8; 32])> = ids
            .iter()
            .zip(&kps)
            .map(|(&id, kp)| (id, kp.public().0))
            .collect();
        let q = Quantizer::new(1.0, 16).unwrap();
        let mut rng = Rng::new(6);
        let mut plain_sum = vec![0u32; dim];
        let mut masked_sum = vec![0u32; dim];
        let dropped = 3usize; // index of dropped client
        for i in 0..n {
            if i == dropped {
                continue;
            }
            let x: Vec<f32> = (0..dim).map(|_| rng.next_f32() - 0.5).collect();
            let qx = q.quantize(&x);
            add_mod(&mut plain_sum, &qx);
            let mut y = qx;
            apply_pairwise_masks(&mut y, ids[i], &kps[i], &roster, 9, 2);
            add_mod(&mut masked_sum, &y);
        }
        assert_ne!(masked_sum, plain_sum); // orphaned masks present
        for i in 0..n {
            if i == dropped {
                continue;
            }
            remove_orphan_mask(
                &mut masked_sum,
                &kps[dropped],
                ids[dropped],
                ids[i],
                &kps[i].public().0,
                9,
                2,
            );
        }
        assert_eq!(masked_sum, plain_sum);
    }
}
