//! Platform-wide error type.

use thiserror::Error;

/// Unified error type for the Florida platform.
#[derive(Error, Debug)]
pub enum Error {
    /// Transport-level failure (connection dropped, framing error, ...).
    #[error("transport error: {0}")]
    Transport(String),

    /// The server answered with a protocol-level error (`ErrorReply`) or
    /// a negative acknowledgement (`Ack { ok: false }`). Raised by the
    /// typed stub layer so protocol errors are never silently dropped.
    #[error("server error: {0}")]
    Server(String),

    /// Wire-format decode failure.
    #[error("codec error: {0}")]
    Codec(String),

    /// Device attestation failed verification.
    #[error("attestation rejected: {0}")]
    Attestation(String),

    /// Secure-aggregation protocol violation or failure.
    #[error("secure aggregation error: {0}")]
    SecAgg(String),

    /// Task lifecycle error (unknown task, invalid transition, ...).
    #[error("task error: {0}")]
    Task(String),

    /// Client selection error.
    #[error("selection error: {0}")]
    Selection(String),

    /// Model snapshot / parameter-vector error.
    #[error("model error: {0}")]
    Model(String),

    /// PJRT runtime error (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Differential-privacy configuration or accounting error.
    #[error("dp error: {0}")]
    Dp(String),

    /// Configuration parse/validation error.
    #[error("config error: {0}")]
    Config(String),

    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Anything else.
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Other(s)
    }
}

impl Error {
    /// Helper for stub call sites expecting a specific reply shape.
    pub fn unexpected_reply(m: &crate::proto::Msg) -> Error {
        Error::Transport(format!("unexpected reply {m:?}"))
    }
}

/// Platform-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
