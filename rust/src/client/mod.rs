//! Client SDK (§3.2): the on-device side of the platform.
//!
//! Mirrors the paper's sample client: the application developer supplies a
//! `Trainer` (the paper's `trainer(model, iteration_id)` callback) inside
//! a [`WorkflowDetails`], and [`FederatedLearningClient::execute`] runs
//! the full protocol — attest, open a session (negotiating the protocol
//! version and submitting the device's heterogeneity profile), poll,
//! join, (secagg setup), train, privatize, quantize+mask, upload, unmask
//! service — until the task completes. The SDK holds the liveness lease:
//! it auto-renews at half-life via `SessionHeartbeat`, transparently
//! reopens the session when the lease is lost, and negotiates down to
//! the v1 one-shot `Register` flow against servers that don't speak v2.

pub mod api;
pub mod secagg_participant;
pub mod stub;

use std::time::Instant;

use crate::crypto::attest::Verdict;
use crate::crypto::x25519::KeyPair;
use crate::dp::{DpConfig, GaussianMechanism};
use crate::error::{Error, Result};
use crate::model::ModelSnapshot;
use crate::proto::{rpc, DeviceProfile, LoadHints, RoundRole, PROTO_V2};
use crate::quant::Quantizer;
use crate::util::Rng;

pub use api::{DirectApi, RemoteApi, ServerApi};
pub use secagg_participant::SecAggParticipant;
pub use stub::FloridaClient;

/// What local training produced.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// Updated local parameters (same dim as the snapshot trained from).
    pub new_params: Vec<f32>,
    /// Example-count weight for FedAvg.
    pub weight: f64,
    /// Mean training loss over the local steps.
    pub loss: f64,
}

/// The application developer's training callback (paper Fig. 3).
pub trait Trainer: Send {
    /// Train from `model` for one round; `lr`/`prox_mu` come from the
    /// server's TrainParams. `round` is the paper's `iteration_id`.
    fn train(&mut self, model: &ModelSnapshot, round: u64, lr: f32, prox_mu: f32)
        -> Result<TrainOutcome>;
}

/// Paper-style workflow registration.
pub struct WorkflowDetails {
    pub app_name: String,
    pub workflow_name: String,
    pub trainer: Box<dyn Trainer>,
}

/// Client-local DP configuration (applied when the task ran with local DP;
/// in this reproduction the device owns its DP knobs, matching §4.2's
/// "local ... noise addition").
#[derive(Clone, Copy, Debug)]
pub struct LocalDp {
    pub cfg: DpConfig,
}

/// Outcome of `execute`.
#[derive(Clone, Debug, Default)]
pub struct ExecutionReport {
    pub rounds_participated: u64,
    pub rounds_not_selected: u64,
    pub unmask_services: u64,
    pub uploads_rejected: u64,
    pub task_completed: bool,
}

/// The SDK's side of a live session: the renewal credential plus the
/// wall-clock bookkeeping for half-life auto-renewal.
struct SessionState {
    token: u64,
    lease_ms: u64,
    renewed_at: Instant,
    /// Negotiated protocol version (v2 unless the server clamped it).
    proto: u32,
}

/// The device-side client.
pub struct FederatedLearningClient {
    stub: FloridaClient,
    device_id: String,
    verdict: Verdict,
    caps: crate::proto::DeviceCaps,
    /// Heterogeneity profile submitted at `SessionOpen` (compute tier,
    /// bandwidth class, availability window).
    pub profile: DeviceProfile,
    client_id: u64,
    session: Option<SessionState>,
    rng: Rng,
    /// Local DP (None → follow task config only for clipping-free upload).
    pub local_dp: Option<DpConfig>,
    /// Injected test hook: drop after training with this probability.
    pub dropout_prob: f64,
    /// Base poll interval between FetchRound calls; idle polls back off
    /// exponentially (with jitter) from here up to
    /// [`MAX_BACKOFF_DOUBLINGS`] doublings, so a waiting fleet does not
    /// hammer the server in lockstep. 0 disables sleeping entirely.
    pub poll_sleep_ms: u64,
    /// Consecutive idle polls since the last round of real work (drives
    /// the exponential backoff; reset whenever the server gives us work).
    backoff_level: u32,
}

/// Cap on backoff doublings: idle polls plateau at base × 2^6 = 64×.
const MAX_BACKOFF_DOUBLINGS: u32 = 6;

impl FederatedLearningClient {
    pub fn new(
        api: Box<dyn ServerApi>,
        device_id: &str,
        verdict: Verdict,
        caps: crate::proto::DeviceCaps,
        seed: u64,
    ) -> FederatedLearningClient {
        FederatedLearningClient {
            stub: FloridaClient::new(api),
            device_id: device_id.to_string(),
            verdict,
            caps,
            profile: DeviceProfile::default(),
            client_id: 0,
            session: None,
            rng: Rng::new(seed),
            local_dp: None,
            dropout_prob: 0.0,
            poll_sleep_ms: 1,
            backoff_level: 0,
        }
    }

    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// The negotiated protocol version, if a session is live.
    pub fn session_proto(&self) -> Option<u32> {
        self.session.as_ref().map(|s| s.proto)
    }

    /// Attest + register with the selection service (the v1 one-shot
    /// flow, kept as the negotiation fallback).
    pub fn register(&mut self) -> Result<u64> {
        let ack =
            self.stub
                .register(&self.device_id, self.verdict.clone(), self.caps.clone())?;
        if ack.accepted {
            self.client_id = ack.client_id;
            Ok(ack.client_id)
        } else {
            Err(Error::Attestation(ack.reason))
        }
    }

    /// Open a negotiated v2 session: attest, register, submit the device
    /// profile, receive a token + liveness lease. A server that cannot
    /// route `SessionOpen` (a v1 deployment) answers with an
    /// `ErrorReply`, which the stub surfaces as `Err(Error::Server)` —
    /// the SDK then falls back to the one-shot `register` flow, so the
    /// protocol redesign is a migration, not a break.
    pub fn open_session(&mut self) -> Result<u64> {
        match self.stub.open_session(
            &self.device_id,
            self.verdict.clone(),
            self.caps.clone(),
            self.profile,
            PROTO_V2,
        ) {
            Ok(grant) if grant.accepted => {
                self.client_id = grant.client_id;
                self.session = Some(SessionState {
                    token: grant.token,
                    lease_ms: grant.lease_ms.max(1),
                    // florida-lint: allow(wall-clock-in-core): SDK lease half-life runs on device real time
                    renewed_at: Instant::now(),
                    proto: grant.proto,
                });
                Ok(grant.client_id)
            }
            Ok(grant) => Err(Error::Attestation(grant.reason)),
            // Fall back to the one-shot flow ONLY when the server cannot
            // speak the frame at all (a v1 router answers "unexpected
            // message …" / "… cannot handle …"). Transient server errors
            // (backpressure sheds, auth hiccups) propagate instead — a
            // retry must not burn the attestation verdict on `register`.
            Err(Error::Server(message))
                if message.contains("unexpected message")
                    || message.contains("cannot handle") =>
            {
                self.session = None;
                self.register()
            }
            Err(e) => Err(e),
        }
    }

    /// Make sure the device can act as a principal: open a session (with
    /// v1 fallback) the first time, and best-effort *reopen* one when the
    /// device is registered but lease-less (e.g. the previous task closed
    /// its session) — so a multi-task client keeps its profile and lease
    /// instead of degrading to sessionless forever. Reopen failures (v1
    /// server, single-use attestation verdict) are non-fatal.
    pub fn ensure_session(&mut self) -> Result<u64> {
        if self.client_id == 0 {
            return self.open_session();
        }
        if self.session.is_none() {
            if let Err(e) = self.open_session() {
                log::debug!(
                    "device {}: session reopen failed ({e}); continuing sessionless",
                    self.device_id
                );
            }
        }
        Ok(self.client_id)
    }

    /// Auto-renew the lease at half-life. A refused renewal (lease
    /// expired, token rotated, server restarted) transparently reopens
    /// the session; if reopening fails too (e.g. single-use attestation
    /// verdicts), the client degrades to the sessionless v1 flow rather
    /// than aborting the round loop.
    fn maybe_renew(&mut self) {
        let (token, due) = match &self.session {
            Some(s) => (
                s.token,
                s.renewed_at.elapsed().as_millis() as u64 >= s.lease_ms / 2,
            ),
            None => return,
        };
        if !due {
            return;
        }
        let hints = LoadHints {
            load: 0.0,
            battery: 1.0,
            charging: self.caps.charging,
        };
        match self.stub.session_heartbeat(self.client_id, token, hints) {
            Ok(ack) if ack.renewed => {
                if let Some(s) = &mut self.session {
                    s.lease_ms = ack.lease_ms.max(1);
                    // florida-lint: allow(wall-clock-in-core): SDK lease half-life runs on device real time
                    s.renewed_at = Instant::now();
                }
            }
            Ok(_) | Err(Error::Server(_)) => {
                log::debug!("device {}: lease lost — reopening session", self.device_id);
                self.session = None;
                if let Err(e) = self.open_session() {
                    log::debug!(
                        "device {}: session reopen failed ({e}); continuing sessionless",
                        self.device_id
                    );
                }
            }
            // Transport hiccup: keep the session, retry at the next poll.
            Err(_) => {}
        }
    }

    /// Release the lease (graceful departure); best-effort.
    pub fn close_session(&mut self) {
        if let Some(s) = self.session.take() {
            let _ = self.stub.session_close(self.client_id, s.token);
        }
    }

    /// Poll for an available task for (app, workflow).
    pub fn poll_task(&mut self, app: &str, workflow: &str) -> Result<Option<u64>> {
        Ok(self
            .stub
            .poll_task(self.client_id, app, workflow)?
            .map(|t| t.task_id))
    }

    /// Run a workflow to completion (the paper's `client.execute(...)`).
    pub fn execute(&mut self, workflow: &mut WorkflowDetails) -> Result<ExecutionReport> {
        let mut report = ExecutionReport::default();
        self.ensure_session()?;
        let task_id = loop {
            if let Some(t) = self.poll_task(&workflow.app_name, &workflow.workflow_name)? {
                self.reset_backoff();
                break t;
            }
            self.sleep();
        };
        self.run_task(task_id, &mut *workflow.trainer, &mut report)?;
        Ok(report)
    }

    /// Participate in one specific task until it completes.
    pub fn run_task(
        &mut self,
        task_id: u64,
        trainer: &mut dyn Trainer,
        report: &mut ExecutionReport,
    ) -> Result<()> {
        // Per-join round keypair for secure aggregation. Keypairs used in
        // past trained rounds are retained so later unmask requests (which
        // reference those rounds) can still be served.
        let mut kp = KeyPair::generate(&mut self.rng);
        let mut train_keys: Vec<(u64, KeyPair)> = Vec::new();
        let mut joined = false;
        let mut idle_polls = 0u32;
        self.ensure_session()?;
        loop {
            // Keep the liveness lease alive across the whole round loop;
            // an expired lease would evict us from the open cohort.
            self.maybe_renew();
            if !joined {
                // Fresh keypair per join attempt; committed only if the
                // join is accepted — the server's roster keeps the pubkey
                // from the accepted join, so a device that re-enters the
                // same round (e.g. after a crash) must keep using it.
                let fresh = KeyPair::generate(&mut self.rng);
                let ack = self
                    .stub
                    .join_round(self.client_id, task_id, fresh.public().0)?;
                if ack.accepted {
                    kp = fresh;
                    joined = true;
                } else {
                    if ack.reason.contains("criteria") {
                        return Err(Error::Task(ack.reason));
                    }
                    // Task completed/cancelled → FetchRound will report
                    // TaskDone. Already-joined: keep the OLD keypair.
                    joined = ack.reason.contains("already joined");
                }
            }
            let role = match self.stub.fetch_round(self.client_id, task_id) {
                Ok(role) => role,
                // Protocol-level refusal (unknown task, …) is a task error.
                Err(Error::Server(message)) => return Err(Error::Task(message)),
                Err(e) => return Err(e),
            };
            match role {
                RoundRole::TaskDone => {
                    report.task_completed = true;
                    self.close_session(); // graceful departure: release the lease
                    return Ok(());
                }
                RoundRole::Wait => {
                    idle_polls += 1;
                    if idle_polls > 100_000 {
                        return Err(Error::Task("starved waiting for round".into()));
                    }
                    self.sleep();
                }
                RoundRole::RoundDone => {
                    joined = false; // rejoin for the next round
                    self.sleep();
                }
                RoundRole::NotSelected => {
                    report.rounds_not_selected += 1;
                    joined = false;
                    self.sleep();
                }
                RoundRole::Unmask(req) => {
                    report.unmask_services += 1;
                    let round_kp = train_keys
                        .iter()
                        .find(|(r, _)| *r == req.round)
                        .map(|(_, k)| k)
                        .unwrap_or(&kp);
                    let participant = SecAggParticipant::new(task_id, req.round, round_kp);
                    let shares = participant.answer_unmask(&req, self.client_id)?;
                    tolerate_rejection(
                        self.stub
                            .unmask_response(self.client_id, task_id, req.round, shares),
                        "unmask response",
                    )?;
                    self.sleep();
                }
                RoundRole::Train(ri) => {
                    idle_polls = 0;
                    self.reset_backoff();
                    // Secure-aggregation SETUP happens before local
                    // training (Bonawitz et al. round structure): the
                    // encrypted Shamir shares of this round's DH seed
                    // must reach the server first, so a device that dies
                    // during/after training remains recoverable.
                    if let Some(setup) = &ri.secagg {
                        train_keys.push((ri.round, kp.clone()));
                        if train_keys.len() > 8 {
                            train_keys.remove(0);
                        }
                        SecAggParticipant::remember_roster(task_id, ri.round, &setup.roster);
                        let participant = SecAggParticipant::new(task_id, ri.round, &kp);
                        let shares =
                            participant.make_shares(setup, self.client_id, &mut self.rng)?;
                        tolerate_rejection(
                            self.stub
                                .secagg_shares(self.client_id, task_id, ri.round, shares),
                            "secagg shares",
                        )?;
                    }
                    let model = ModelSnapshot::from_compressed(&ri.model_blob)?;
                    let outcome =
                        trainer.train(&model, ri.round, ri.train.lr, ri.train.prox_mu)?;
                    // Training can outlast the lease half-life; renew
                    // before uploading so the slot is still ours.
                    self.maybe_renew();
                    if self.rng.chance(self.dropout_prob) {
                        // Simulated device failure after training — the
                        // upload never happens; the server recovers via
                        // the shares distributed above.
                        joined = false;
                        continue;
                    }
                    let mut delta = model.delta_from(&outcome.new_params)?;
                    if let Some(dp) = &self.local_dp {
                        GaussianMechanism::privatize(&mut delta, dp, &mut self.rng);
                    }
                    let accepted = match &ri.secagg {
                        None => self.upload_plain(task_id, &ri, &model, delta, &outcome)?,
                        Some(setup) => {
                            let participant =
                                SecAggParticipant::new(task_id, ri.round, &kp);
                            let quant = Quantizer::new(setup.quant_range, setup.quant_bits)?;
                            let masked =
                                participant.mask_update(setup, self.client_id, &quant, &delta);
                            upload_outcome(self.stub.upload_masked(rpc::UploadMasked {
                                client_id: self.client_id,
                                task_id,
                                round: ri.round,
                                vg_id: setup.vg_id,
                                masked,
                                loss: outcome.loss,
                            }))?
                        }
                    };
                    if accepted {
                        report.rounds_participated += 1;
                    } else {
                        report.uploads_rejected += 1;
                    }
                    joined = false;
                }
            }
        }
    }

    fn upload_plain(
        &mut self,
        task_id: u64,
        ri: &crate::proto::RoundInstruction,
        model: &ModelSnapshot,
        delta: Vec<f32>,
        outcome: &TrainOutcome,
    ) -> Result<bool> {
        upload_outcome(self.stub.upload_plain(rpc::UploadPlain {
            client_id: self.client_id,
            task_id,
            round: ri.round,
            base_version: model.version,
            delta,
            weight: outcome.weight,
            loss: outcome.loss,
        }))
    }

    /// The next idle-poll sleep: jittered exponential backoff. Doubles
    /// from `poll_sleep_ms` up to 2^[`MAX_BACKOFF_DOUBLINGS`]× base,
    /// jittered uniformly over [½·bound, bound] so a fleet that went
    /// idle together does not wake (and re-poll) in lockstep. Returns 0
    /// (and stays at level 0) when sleeping is disabled.
    fn next_backoff_ms(&mut self) -> u64 {
        if self.poll_sleep_ms == 0 {
            return 0;
        }
        let bound = self
            .poll_sleep_ms
            .saturating_mul(1 << self.backoff_level.min(MAX_BACKOFF_DOUBLINGS));
        if self.backoff_level < MAX_BACKOFF_DOUBLINGS {
            self.backoff_level += 1;
        }
        let half = (bound / 2).max(1);
        half + self.rng.below(bound - half + 1)
    }

    /// Forget accumulated backoff — the server gave us real work.
    fn reset_backoff(&mut self) {
        self.backoff_level = 0;
    }

    fn sleep(&mut self) {
        let ms = self.next_backoff_ms();
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Map an upload result: accepted → `true`, server-side rejection
/// (stale round, deadline missed, …) → `false` so the protocol loop can
/// record it and move on; transport failures stay fatal.
fn upload_outcome(r: Result<()>) -> Result<bool> {
    match r {
        Ok(()) => Ok(true),
        Err(Error::Server(reason)) => {
            log::debug!("upload rejected: {reason}");
            Ok(false)
        }
        Err(e) => Err(e),
    }
}

/// Best-effort protocol steps (share deposit, unmask duty): a server
/// rejection means the round moved on without us — log and continue;
/// transport failures stay fatal.
fn tolerate_rejection(r: Result<()>, what: &str) -> Result<()> {
    match r {
        Ok(()) => Ok(()),
        Err(Error::Server(reason)) => {
            log::debug!("{what} rejected: {reason}");
            Ok(())
        }
        Err(e) => Err(e),
    }
}

/// A trivial trainer: adds a constant to every parameter (scaling tests —
/// the paper §5.2 "dummy task": each client sends an all-ones array).
pub struct ConstantTrainer {
    pub step: f32,
}

impl Trainer for ConstantTrainer {
    fn train(
        &mut self,
        model: &ModelSnapshot,
        _round: u64,
        _lr: f32,
        _prox_mu: f32,
    ) -> Result<TrainOutcome> {
        Ok(TrainOutcome {
            new_params: model.params.iter().map(|p| p + self.step).collect(),
            weight: 1.0,
            loss: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_backoff_doubles_with_jitter_and_resets() {
        use std::sync::Arc;
        let server = Arc::new(crate::services::FloridaServer::for_testing(false, 3));
        let authority = crate::crypto::attest::Authority::new(b"florida-test-authority");
        let verdict = authority.issue(
            "backoff-dev",
            crate::crypto::attest::IntegrityTier::Device,
            1,
            u64::MAX / 2,
        );
        let mut c = FederatedLearningClient::new(
            api::direct(&server),
            "backoff-dev",
            verdict,
            crate::proto::DeviceCaps::default(),
            42,
        );
        c.poll_sleep_ms = 8;
        // Each idle poll's sleep lands in [½·bound, bound] with the
        // bound doubling per level, then plateaus at 2^6 × base.
        let mut prev_bound = 0u64;
        for level in 0..10u32 {
            let bound = 8u64 * (1 << level.min(MAX_BACKOFF_DOUBLINGS));
            let ms = c.next_backoff_ms();
            assert!(
                ms >= bound / 2 && ms <= bound,
                "level {level}: {ms} outside [{}, {bound}]",
                bound / 2
            );
            assert!(bound >= prev_bound, "bound must never shrink");
            prev_bound = bound;
        }
        // Progress resets the schedule to the base interval.
        c.reset_backoff();
        let ms = c.next_backoff_ms();
        assert!((4..=8).contains(&ms), "post-reset sleep {ms} not in [4, 8]");
        // Disabled sleeping stays disabled (simulators rely on 0 = spin).
        c.poll_sleep_ms = 0;
        assert_eq!(c.next_backoff_ms(), 0);
    }

    #[test]
    fn constant_trainer_shifts_params() {
        let mut t = ConstantTrainer { step: 1.0 };
        let m = ModelSnapshot::new(0, vec![0.0, 2.0]);
        let out = t.train(&m, 0, 0.0, 0.0).unwrap();
        assert_eq!(out.new_params, vec![1.0, 3.0]);
        assert_eq!(m.delta_from(&out.new_params).unwrap(), vec![1.0, 1.0]);
    }
}
