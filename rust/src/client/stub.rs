//! Typed client stubs over any [`ServerApi`].
//!
//! `FloridaClient` is the generated-stub equivalent of the paper's
//! gRPC surface: one method per RPC, typed request in, typed reply out.
//! Protocol errors are never silently dropped — an `ErrorReply` or a
//! negative `Ack` surfaces as [`Error::Server`] from every method.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

use crate::crypto::attest::Verdict;
use crate::error::Result;
use crate::proto::msg::{PeerShare, RecoveredShare};
use crate::proto::rpc::{self, Reply, Rpc};
use crate::proto::{DeviceCaps, DeviceProfile, LoadHints, RoundRole, TaskDescriptor, WireCodec};
use crate::services::FloridaServer;
use crate::transport::Dialer;

use super::api::{DirectApi, RemoteApi, ServerApi};

/// Typed stub layer over a transport-shaped [`ServerApi`].
pub struct FloridaClient {
    api: Box<dyn ServerApi>,
    /// Trace id attached to every outgoing request frame; 0 = tracing
    /// off (the default), which keeps requests byte-identical to v1.
    trace: AtomicU64,
}

impl FloridaClient {
    /// Wrap an existing transport (direct, remote, or a test double).
    pub fn new(api: Box<dyn ServerApi>) -> FloridaClient {
        FloridaClient {
            api,
            trace: AtomicU64::new(0),
        }
    }

    /// Attach `trace_id` to every subsequent request (0 turns tracing
    /// back off). Traced requests carry the id as the optional wire
    /// trailer; the server records per-RPC child spans under it.
    pub fn set_trace(&self, trace_id: u64) {
        self.trace.store(trace_id, Relaxed);
    }

    /// Zero-serialization stub for an in-process server.
    pub fn direct(server: &Arc<FloridaServer>) -> FloridaClient {
        FloridaClient::new(Box::new(DirectApi {
            server: Arc::clone(server),
        }))
    }

    /// Dial a served platform over any transport/codec.
    pub fn connect(dialer: &dyn Dialer, addr: &str, codec: WireCodec) -> Result<FloridaClient> {
        Ok(FloridaClient::new(Box::new(RemoteApi::connect(
            dialer, addr, codec,
        )?)))
    }

    /// Generic typed call: any [`Rpc`] request to its typed reply.
    pub fn call<R: Rpc>(&self, req: R) -> Result<R::Reply> {
        let trace = self.trace.load(Relaxed);
        let reply = if trace == 0 {
            // Zero-cost when disabled: the untraced path is the plain
            // `call`, with no trailer encode and no `Some` branch.
            self.api.call(req.into_msg())?
        } else {
            self.api.call_traced(req.into_msg(), Some(trace))?
        };
        R::Reply::from_msg(reply)
    }

    // ---- one stub method per RPC -----------------------------------------

    pub fn register(
        &self,
        device_id: &str,
        verdict: Verdict,
        caps: DeviceCaps,
    ) -> Result<rpc::RegisterAck> {
        self.call(rpc::Register {
            device_id: device_id.to_string(),
            verdict,
            caps,
        })
    }

    pub fn poll_task(
        &self,
        client_id: u64,
        app_name: &str,
        workflow_name: &str,
    ) -> Result<Option<TaskDescriptor>> {
        Ok(self
            .call(rpc::PollTask {
                client_id,
                app_name: app_name.to_string(),
                workflow_name: workflow_name.to_string(),
            })?
            .task)
    }

    pub fn join_round(
        &self,
        client_id: u64,
        task_id: u64,
        dh_pubkey: [u8; 32],
    ) -> Result<rpc::JoinAck> {
        self.call(rpc::JoinRound {
            client_id,
            task_id,
            dh_pubkey,
        })
    }

    pub fn fetch_round(&self, client_id: u64, task_id: u64) -> Result<RoundRole> {
        self.call(rpc::FetchRound { client_id, task_id })
    }

    pub fn secagg_shares(
        &self,
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<PeerShare>,
    ) -> Result<()> {
        self.call(rpc::SecAggShares {
            client_id,
            task_id,
            round,
            shares,
        })
        .map(|_| ())
    }

    pub fn upload_plain(&self, req: rpc::UploadPlain) -> Result<()> {
        self.call(req).map(|_| ())
    }

    pub fn upload_masked(&self, req: rpc::UploadMasked) -> Result<()> {
        self.call(req).map(|_| ())
    }

    pub fn unmask_response(
        &self,
        client_id: u64,
        task_id: u64,
        round: u64,
        shares: Vec<RecoveredShare>,
    ) -> Result<()> {
        self.call(rpc::UnmaskResponse {
            client_id,
            task_id,
            round,
            shares,
        })
        .map(|_| ())
    }

    pub fn task_status(&self, task_id: u64) -> Result<rpc::TaskStatus> {
        self.call(rpc::GetTaskStatus { task_id })
    }

    /// Fetch the server's telemetry export: `format` 0 = JSON, 1 =
    /// Prometheus text exposition (see `crate::obs::export`).
    pub fn get_telemetry(&self, format: u32) -> Result<rpc::TelemetryReport> {
        self.call(rpc::GetTelemetry { format })
    }

    pub fn heartbeat(&self, client_id: u64) -> Result<()> {
        self.call(rpc::Heartbeat { client_id }).map(|_| ())
    }

    // ---- session protocol v2 ---------------------------------------------

    /// Open a negotiated session (attest + register + device profile).
    /// Against a v1 server this surfaces as `Err(Error::Server)` — the
    /// SDK's cue to fall back to the one-shot `register` flow.
    pub fn open_session(
        &self,
        device_id: &str,
        verdict: Verdict,
        caps: DeviceCaps,
        profile: DeviceProfile,
        proto_max: u32,
    ) -> Result<rpc::SessionGrant> {
        self.call(rpc::SessionOpen {
            device_id: device_id.to_string(),
            verdict,
            caps,
            profile,
            proto_max,
        })
    }

    /// Renew the liveness lease with load/battery hints.
    pub fn session_heartbeat(
        &self,
        client_id: u64,
        token: u64,
        hints: LoadHints,
    ) -> Result<rpc::LeaseAck> {
        self.call(rpc::SessionHeartbeat {
            client_id,
            token,
            hints,
        })
    }

    /// Release the lease early (graceful departure).
    pub fn session_close(&self, client_id: u64, token: u64) -> Result<()> {
        self.call(rpc::SessionClose { client_id, token }).map(|_| ())
    }

    // ---- hierarchical aggregation (leaf data plane) ----------------------

    /// Ask for the leaf's slice of the open round's cohort. A
    /// structured refusal (`accepted: false`) is data: no open round
    /// yet, or the round is secagg and leaves must stand down.
    pub fn leaf_assign(
        &self,
        leaf_id: u64,
        task_id: u64,
        leaf_index: u32,
        leaf_count: u32,
    ) -> Result<rpc::LeafAssignment> {
        self.call(rpc::LeafAssign {
            leaf_id,
            task_id,
            leaf_index,
            leaf_count,
        })
    }

    /// Forward a folded partial accumulator to the master. A rejected
    /// partial (stale round, duplicate members) is `Err(Error::Server)`.
    pub fn forward_partial(&self, req: rpc::ForwardPartial) -> Result<rpc::LeafAck> {
        self.call(req)
    }
}
