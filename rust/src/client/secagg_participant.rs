//! Client-side secure-aggregation participant (§4.1).
//!
//! Owns the per-round DH keypair and performs the three client-side
//! duties: Shamir-share its seed to the VG, mask its quantized update,
//! and decrypt+return shares of dropped peers during unmasking.

use crate::crypto::shamir;
use crate::crypto::x25519::{KeyPair, PublicKey};
use crate::error::{Error, Result};
use crate::proto::msg::{PeerShare, RecoveredShare};
use crate::proto::{SecAggSetup, UnmaskRequest};
use crate::quant::Quantizer;
use crate::secagg;
use crate::util::Rng;

/// One round's participant state (wraps the round keypair).
pub struct SecAggParticipant<'a> {
    task_id: u64,
    round: u64,
    kp: &'a KeyPair,
}

impl<'a> SecAggParticipant<'a> {
    pub fn new(task_id: u64, round: u64, kp: &'a KeyPair) -> SecAggParticipant<'a> {
        SecAggParticipant { task_id, round, kp }
    }

    /// Shamir-share this client's DH seed among its VG peers, each share
    /// encrypted under the pairwise stream key.
    pub fn make_shares(
        &self,
        setup: &SecAggSetup,
        me: u64,
        rng: &mut Rng,
    ) -> Result<Vec<PeerShare>> {
        let peers: Vec<&(u64, [u8; 32])> =
            setup.roster.iter().filter(|&&(id, _)| id != me).collect();
        if peers.is_empty() {
            return Err(Error::SecAgg("VG has no peers".into()));
        }
        let seed = self.kp.seed_bytes();
        let shares = shamir::split(&seed, setup.threshold as usize, peers.len(), rng);
        Ok(peers
            .iter()
            .zip(shares)
            .map(|(&&(pid, ppk), sh)| {
                let shared = self.kp.agree(&PublicKey(ppk));
                let key = secagg::share_enc_key(&shared, self.task_id, self.round, me, pid);
                let mut plain = Vec::with_capacity(1 + sh.y.len());
                plain.push(sh.x);
                plain.extend_from_slice(&sh.y);
                PeerShare {
                    peer: pid,
                    enc: secagg::stream_xor(key, &plain),
                }
            })
            .collect())
    }

    /// Quantize a pseudo-gradient and apply all pairwise masks.
    pub fn mask_update(
        &self,
        setup: &SecAggSetup,
        me: u64,
        quant: &Quantizer,
        delta: &[f32],
    ) -> Vec<u32> {
        let mut acc = quant.quantize(delta);
        secagg::apply_pairwise_masks(
            &mut acc,
            me,
            self.kp,
            &setup.roster,
            self.task_id,
            self.round,
        );
        acc
    }

    /// Decrypt the encrypted shares of dropped peers addressed to `me`.
    /// Requires the dropped peers' public keys, which arrive inside the
    /// request via the stored roster — the server includes only (id, enc);
    /// the participant must have kept the round roster. To keep the SDK
    /// stateless here, the dropped peer's public key is recovered from the
    /// UnmaskRequest context: the server addressed the share with the
    /// pairwise key derived from DH(dropped_sk, my_pk) == DH(my_sk,
    /// dropped_pk) — so the SDK keeps the roster in the setup it saw.
    pub fn answer_unmask_with_roster(
        &self,
        req: &UnmaskRequest,
        me: u64,
        roster: &[(u64, [u8; 32])],
    ) -> Result<Vec<RecoveredShare>> {
        let mut out = Vec::with_capacity(req.dropped.len());
        for (dropped, enc) in &req.dropped {
            let pk = roster
                .iter()
                .find(|&&(id, _)| id == *dropped)
                .map(|&(_, pk)| pk)
                .ok_or_else(|| {
                    Error::SecAgg(format!("dropped peer {dropped} not in my roster"))
                })?;
            let shared = self.kp.agree(&PublicKey(pk));
            let key = secagg::share_enc_key(&shared, self.task_id, self.round, *dropped, me);
            let plain = secagg::stream_xor(key, enc);
            if plain.is_empty() {
                return Err(Error::SecAgg("empty share".into()));
            }
            out.push(RecoveredShare {
                dropped: *dropped,
                x: plain[0],
                y: plain[1..].to_vec(),
            });
        }
        Ok(out)
    }

    /// Roster-less variant used by the SDK loop: the roster travelled
    /// inside the round's SecAggSetup; the SDK stores it per round. When
    /// unavailable (client restarted), unmasking is refused.
    pub fn answer_unmask(&self, req: &UnmaskRequest, me: u64) -> Result<Vec<RecoveredShare>> {
        let roster = ROSTER_CACHE.with(|c| {
            c.borrow()
                .get(&(self.task_id, req.round))
                .cloned()
        });
        match roster {
            Some(r) => self.answer_unmask_with_roster(req, me, &r),
            None => Err(Error::SecAgg(
                "no cached roster for unmask request (client restarted?)".into(),
            )),
        }
    }

    /// Cache the roster for later unmask duty (called by the SDK when it
    /// receives a Train instruction with secagg).
    pub fn remember_roster(task_id: u64, round: u64, roster: &[(u64, [u8; 32])]) {
        ROSTER_CACHE.with(|c| {
            c.borrow_mut().insert((task_id, round), roster.to_vec());
        });
    }
}

thread_local! {
    /// (task, round) → roster. Client sessions are thread-confined in the
    /// simulator, so a thread-local cache gives process isolation between
    /// simulated devices for free.
    static ROSTER_CACHE: std::cell::RefCell<std::collections::HashMap<(u64, u64), Vec<(u64, [u8; 32])>>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kp(seed: u64) -> KeyPair {
        let mut rng = Rng::new(seed);
        KeyPair::generate(&mut rng)
    }

    fn setup(ids: &[u64], kps: &[KeyPair]) -> SecAggSetup {
        SecAggSetup {
            vg_id: 0,
            roster: ids
                .iter()
                .zip(kps)
                .map(|(&id, k)| (id, k.public().0))
                .collect(),
            quant_range: 1.0,
            quant_bits: 16,
            threshold: 2,
        }
    }

    #[test]
    fn shares_decrypt_and_reconstruct_seed() {
        let ids = [1u64, 2, 3, 4];
        let kps: Vec<KeyPair> = (0..4).map(|i| kp(100 + i)).collect();
        let s = setup(&ids, &kps);
        let mut rng = Rng::new(9);
        let alice = SecAggParticipant::new(5, 1, &kps[0]);
        let shares = alice.make_shares(&s, 1, &mut rng).unwrap();
        assert_eq!(shares.len(), 3);

        // Two peers decrypt their shares → reconstruct alice's seed.
        let mut plain_shares = Vec::new();
        for (i, peer_id) in [(1usize, 2u64), (2usize, 3u64)] {
            let peer = SecAggParticipant::new(5, 1, &kps[i]);
            let req = UnmaskRequest {
                round: 1,
                vg_id: 0,
                dropped: vec![(
                    1,
                    shares.iter().find(|ps| ps.peer == peer_id).unwrap().enc.clone(),
                )],
            };
            let rec = peer
                .answer_unmask_with_roster(&req, peer_id, &s.roster)
                .unwrap();
            plain_shares.push(shamir::Share {
                x: rec[0].x,
                y: rec[0].y.clone(),
            });
        }
        let seed = shamir::reconstruct(&plain_shares).unwrap();
        assert_eq!(seed, kps[0].seed_bytes().to_vec());
        // And the seed regenerates the public key.
        let rebuilt = KeyPair::from_seed(seed.try_into().unwrap());
        assert_eq!(rebuilt.public().0, kps[0].public().0);
    }

    #[test]
    fn mask_update_roundtrip_via_sum() {
        let ids = [1u64, 2];
        let kps: Vec<KeyPair> = (0..2).map(|i| kp(200 + i)).collect();
        let s = setup(&ids, &kps);
        let q = Quantizer::new(1.0, 16).unwrap();
        let d1 = vec![0.5f32; 32];
        let d2 = vec![-0.25f32; 32];
        let p1 = SecAggParticipant::new(5, 2, &kps[0]);
        let p2 = SecAggParticipant::new(5, 2, &kps[1]);
        let m1 = p1.mask_update(&s, 1, &q, &d1);
        let m2 = p2.mask_update(&s, 2, &q, &d2);
        let mut sum = m1;
        crate::quant::add_mod(&mut sum, &m2);
        let mean = q.dequantize_sum_to_mean(&sum, 2).unwrap();
        for m in mean {
            assert!((m - 0.125).abs() < q.step(), "{m}");
        }
    }

    #[test]
    fn unmask_requires_roster() {
        let kps = [kp(1)];
        let p = SecAggParticipant::new(1, 1, &kps[0]);
        let req = UnmaskRequest {
            round: 1,
            vg_id: 0,
            dropped: vec![(9, vec![1, 2, 3])],
        };
        // Unknown dropped peer → error.
        assert!(p.answer_unmask_with_roster(&req, 1, &[]).is_err());
    }
}
