//! How the SDK reaches the server: direct (in-process) or remote (wire).

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::proto::{decode_frame, encode_frame, Msg, WireCodec};
use crate::services::FloridaServer;
use crate::transport::{Connection, Dialer};

/// Request/response channel to the platform.
pub trait ServerApi: Send {
    fn call(&self, msg: Msg) -> Result<Msg>;
}

/// Zero-serialization path used by the large-scale simulator.
pub struct DirectApi {
    pub server: Arc<FloridaServer>,
}

impl ServerApi for DirectApi {
    fn call(&self, msg: Msg) -> Result<Msg> {
        Ok(self.server.handle(msg))
    }
}

/// Wire path over any [`crate::transport::Dialer`] — the paper's
/// `isEndpointHttp1` flag maps to the codec choice here.
pub struct RemoteApi {
    conn: Mutex<Box<dyn Connection>>,
    codec: WireCodec,
}

impl RemoteApi {
    pub fn connect(dialer: &dyn Dialer, addr: &str, codec: WireCodec) -> Result<RemoteApi> {
        Ok(RemoteApi {
            conn: Mutex::new(dialer.dial(addr)?),
            codec,
        })
    }
}

impl ServerApi for RemoteApi {
    fn call(&self, msg: Msg) -> Result<Msg> {
        let frame = encode_frame(&msg, self.codec)?;
        let mut conn = self.conn.lock().unwrap();
        conn.send(&frame)?;
        let reply = conn.recv()?;
        let (m, _) = decode_frame(&reply)?;
        if let Msg::ErrorReply { ref message } = m {
            // Surface protocol-level errors but let callers inspect too.
            log::debug!("server error reply: {message}");
        }
        Ok(m)
    }
}

/// Dialer-independent convenience: direct API from a shared server.
pub fn direct(server: &Arc<FloridaServer>) -> Box<dyn ServerApi> {
    Box::new(DirectApi {
        server: Arc::clone(server),
    })
}

impl Error {
    /// Helper for SDK call sites expecting a specific reply shape.
    pub fn unexpected_reply(m: &Msg) -> Error {
        Error::Transport(format!("unexpected reply {m:?}"))
    }
}
