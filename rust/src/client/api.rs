//! How the SDK reaches the server: direct (in-process) or remote (wire).
//!
//! `ServerApi` is the transport-shaped seam — one `Msg` in, one `Msg`
//! out. It deliberately does NOT interpret replies: protocol errors
//! (`ErrorReply`, negative acks) are surfaced as `Err(Error::Server)` by
//! the typed stub layer ([`crate::client::FloridaClient`]) sitting on
//! top of any `ServerApi`.

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::proto::{decode_frame, encode_frame_traced, Msg, WireCodec};
use crate::services::FloridaServer;
use crate::transport::{Connection, Dialer};

/// Request/response channel to the platform.
pub trait ServerApi: Send {
    fn call(&self, msg: Msg) -> Result<Msg> {
        self.call_traced(msg, None)
    }

    /// `call` with an optional trace id attached to the request frame
    /// (the v1-compatible wire trailer). Implementations that cannot
    /// carry a trace (test doubles) may ignore it — the default `call`
    /// passes `None`, so untraced traffic is byte-identical to v1.
    fn call_traced(&self, msg: Msg, trace_id: Option<u64>) -> Result<Msg>;
}

/// Zero-serialization path used by the large-scale simulator.
pub struct DirectApi {
    pub server: Arc<FloridaServer>,
}

impl ServerApi for DirectApi {
    fn call_traced(&self, msg: Msg, trace_id: Option<u64>) -> Result<Msg> {
        Ok(self.server.handle_with_trace(msg, trace_id))
    }
}

/// Wire path over any [`crate::transport::Dialer`] — the paper's
/// `isEndpointHttp1` flag maps to the codec choice here.
pub struct RemoteApi {
    conn: Mutex<Box<dyn Connection>>,
    codec: WireCodec,
}

impl RemoteApi {
    pub fn connect(dialer: &dyn Dialer, addr: &str, codec: WireCodec) -> Result<RemoteApi> {
        Ok(RemoteApi {
            conn: Mutex::new(dialer.dial(addr)?),
            codec,
        })
    }
}

impl ServerApi for RemoteApi {
    fn call_traced(&self, msg: Msg, trace_id: Option<u64>) -> Result<Msg> {
        let frame = encode_frame_traced(&msg, self.codec, trace_id)?;
        // A thread that panicked mid-call poisons the connection mutex.
        // That is a transport fault for *this* caller, not a reason to
        // propagate the panic into every SDK user sharing the connection.
        let mut conn = self.conn.lock().map_err(|_| {
            Error::Transport(
                "connection mutex poisoned (a previous caller panicked mid-call)".into(),
            )
        })?;
        conn.send_owned(frame)?;
        let reply = conn.recv()?;
        let (m, _) = decode_frame(&reply)?;
        // An `ErrorReply` passes through untouched: the stub layer turns
        // it into `Err(Error::Server)`. Transport stays interpretation-free.
        Ok(m)
    }
}

/// Dialer-independent convenience: direct API from a shared server.
pub fn direct(server: &Arc<FloridaServer>) -> Box<dyn ServerApi> {
    Box::new(DirectApi {
        server: Arc::clone(server),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::proto::encode_frame;

    struct EchoConn;

    impl Connection for EchoConn {
        fn send(&mut self, _frame: &[u8]) -> Result<()> {
            Ok(())
        }

        fn recv(&mut self) -> Result<Vec<u8>> {
            encode_frame(
                &Msg::Ack {
                    ok: true,
                    reason: String::new(),
                },
                WireCodec::Binary,
            )
        }

        fn peer(&self) -> String {
            "echo".into()
        }
    }

    #[test]
    fn poisoned_connection_mutex_is_a_transport_error_not_a_panic() {
        let api = Arc::new(RemoteApi {
            conn: Mutex::new(Box::new(EchoConn) as Box<dyn Connection>),
            codec: WireCodec::Binary,
        });
        assert!(api.call(Msg::Heartbeat { client_id: 1 }).is_ok());
        // One caller thread panics while holding the connection lock…
        {
            let api = Arc::clone(&api);
            let _ = std::thread::spawn(move || {
                let _guard = api.conn.lock().unwrap();
                panic!("caller died mid-call");
            })
            .join();
        }
        // …and every other SDK user sees a clean transport error.
        match api.call(Msg::Heartbeat { client_id: 1 }) {
            Err(Error::Transport(m)) => assert!(m.contains("poisoned"), "{m}"),
            other => panic!("expected Err(Error::Transport), got {other:?}"),
        }
    }
}
