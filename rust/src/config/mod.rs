//! Task configuration (§3.3.1 task creation) and artifact manifest.

use crate::dp::{DpConfig, DpMode};
use crate::error::{Error, Result};
use crate::proto::SelectionCriteria;
use crate::util::json::{parse as json_parse, Json};

/// Synchronous rounds vs buffered asynchronous federation (§2, §4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlMode {
    Sync,
    /// Buffered async: flush the buffer every `buffer_size` uploads.
    Async { buffer_size: usize },
}

/// Config-expressible cohort policy (§4.2): which
/// `orchestrator::CohortPolicy` the task's round engine runs. Serialized
/// with the task so "user-defined logic" ships as configuration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum CohortSpec {
    /// `clients_per_round` joiners chosen uniformly at random (default).
    #[default]
    UniformRandom,
    /// Prefer higher-integrity devices (ranked by `DeviceCaps::tier`).
    Tiered,
    /// Draft `ceil(clients_per_round × spawn_factor)` joiners so rounds
    /// tolerate dropouts instead of stalling (§4.2).
    OverProvision { spawn_factor: f64 },
}

impl CohortSpec {
    /// Stable name used on the JSON config surface.
    pub fn name(&self) -> &'static str {
        match self {
            CohortSpec::UniformRandom => "uniform",
            CohortSpec::Tiered => "tiered",
            CohortSpec::OverProvision { .. } => "overprovision",
        }
    }
}

/// When the durability subsystem (`crate::storage`) fsyncs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every journal append and checkpoint — full durability; a
    /// power cut loses nothing the server acknowledged.
    Always,
    /// fsync checkpoints and journal truncations only (default): a
    /// power cut may tear the journal tail — which recovery already
    /// treats as an in-flight round to retry — but never a checkpoint.
    #[default]
    Commit,
    /// Never fsync (tests/benches; the OS flushes eventually).
    Never,
}

impl FsyncPolicy {
    /// Stable name used on the CLI/JSON config surface.
    pub fn name(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Commit => "commit",
            FsyncPolicy::Never => "never",
        }
    }

    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "commit" => Ok(FsyncPolicy::Commit),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(Error::Config(format!(
                "bad fsync policy {other:?} (expected always|commit|never)"
            ))),
        }
    }
}

/// Server-side knobs for the session protocol v2 (liveness leases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionConfig {
    /// Liveness lease granted at `SessionOpen` and on every heartbeat
    /// renewal, ms. An un-renewed lease is swept and the client evicted
    /// from any open cohort (its slot backfilled from the join pool).
    pub lease_ms: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { lease_ms: 30_000 }
    }
}

/// Shape of the hierarchical-aggregation tree a deployment runs
/// (`crate::aggtree`): `depth = 1` is the flat path (devices upload
/// straight to the master), `depth = 2` puts `leaves` leaf aggregators
/// between devices and the master. Deeper trees are not implemented —
/// partials compose associatively, so adding levels is a wiring
/// exercise, but two levels already collapse root fan-in from
/// O(cohort) to O(leaves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeSpec {
    pub depth: u32,
    pub leaves: u32,
}

impl Default for TreeSpec {
    fn default() -> Self {
        TreeSpec { depth: 1, leaves: 0 }
    }
}

impl TreeSpec {
    /// Parse the CLI surface: `"depth=2"` (with `leaves` supplied
    /// separately) or a bare depth like `"2"`.
    pub fn parse(spec: &str, leaves: u32) -> Result<TreeSpec> {
        let depth_str = spec.strip_prefix("depth=").unwrap_or(spec);
        let depth: u32 = depth_str
            .parse()
            .map_err(|_| Error::Config(format!("bad tree spec {spec:?} (expected depth=N)")))?;
        let t = TreeSpec {
            depth,
            leaves: if depth <= 1 { 0 } else { leaves },
        };
        t.validate()?;
        Ok(t)
    }

    pub fn validate(&self) -> Result<()> {
        match self.depth {
            1 => Ok(()),
            2 if self.leaves >= 1 => Ok(()),
            2 => Err(Error::Config("tree depth=2 needs leaves >= 1".into())),
            d => Err(Error::Config(format!(
                "tree depth {d} unsupported (1 = flat, 2 = leaf/master)"
            ))),
        }
    }

    /// Does this topology interpose leaf aggregators?
    pub fn uses_leaves(&self) -> bool {
        self.depth >= 2 && self.leaves >= 1
    }
}

/// Where (and how durably) the orchestrator persists task state.
#[derive(Clone, Debug)]
pub struct StorageConfig {
    /// Directory holding per-task checkpoints + journals.
    pub state_dir: std::path::PathBuf,
    pub fsync: FsyncPolicy,
}

impl StorageConfig {
    pub fn new(state_dir: impl Into<std::path::PathBuf>) -> StorageConfig {
        StorageConfig {
            state_dir: state_dir.into(),
            fsync: FsyncPolicy::default(),
        }
    }

    pub fn fsync(mut self, policy: FsyncPolicy) -> StorageConfig {
        self.fsync = policy;
        self
    }
}

/// Admission-policy knobs for the server's policy engine
/// (`services::policy`): token-bucket rate limits keyed by client id,
/// per-tenant (app) request quotas, and the reputation ledger fed by
/// eviction/upload-rejection history. `Default` is **disabled** — the
/// engine admits everything until a deployment opts in, so a plain
/// simulator run behaves exactly as before.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PolicyConfig {
    pub enabled: bool,
    /// Token-bucket burst capacity per client principal.
    pub bucket_capacity: f64,
    /// Token refill rate per client, tokens/second.
    pub refill_per_sec: f64,
    /// Max requests per tenant (app) per quota window; 0 = unlimited.
    pub tenant_quota: u64,
    pub quota_window_ms: u64,
    /// Clients whose reputation sinks below this are refused.
    pub min_reputation: f64,
    /// Reputation lost per offense (eviction, rejected ingest).
    pub reputation_penalty: f64,
    /// Reputation regained per second, back toward the 1.0 ceiling.
    pub reputation_recovery_per_sec: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            enabled: false,
            bucket_capacity: 256.0,
            refill_per_sec: 64.0,
            tenant_quota: 0,
            quota_window_ms: 1_000,
            min_reputation: 0.25,
            reputation_penalty: 0.25,
            reputation_recovery_per_sec: 0.01,
        }
    }
}

impl PolicyConfig {
    /// An enabled profile with the default limits.
    pub fn enabled() -> PolicyConfig {
        PolicyConfig {
            enabled: true,
            ..PolicyConfig::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.bucket_capacity.is_finite() && self.bucket_capacity >= 1.0) {
            return Err(Error::Config(format!(
                "bucket_capacity {} must be >= 1",
                self.bucket_capacity
            )));
        }
        if !(self.refill_per_sec.is_finite() && self.refill_per_sec >= 0.0) {
            return Err(Error::Config(format!(
                "refill_per_sec {} must be >= 0",
                self.refill_per_sec
            )));
        }
        if self.quota_window_ms == 0 {
            return Err(Error::Config("quota_window_ms must be > 0".into()));
        }
        for (name, v) in [
            ("min_reputation", self.min_reputation),
            ("reputation_penalty", self.reputation_penalty),
            ("reputation_recovery_per_sec", self.reputation_recovery_per_sec),
        ] {
            if !(v.is_finite() && (0.0..=1.0).contains(&v)) {
                return Err(Error::Config(format!("{name} {v} must be in [0, 1]")));
            }
        }
        Ok(())
    }

    /// Parse from JSON (server deployment spec / scenario config).
    pub fn from_json(j: &Json) -> Result<PolicyConfig> {
        let d = PolicyConfig::default();
        let cfg = PolicyConfig {
            enabled: j.opt_bool("enabled", d.enabled),
            bucket_capacity: j.opt_f64("bucket_capacity", d.bucket_capacity),
            refill_per_sec: j.opt_f64("refill_per_sec", d.refill_per_sec),
            tenant_quota: j.opt_usize("tenant_quota", d.tenant_quota as usize) as u64,
            quota_window_ms: j.opt_usize("quota_window_ms", d.quota_window_ms as usize) as u64,
            min_reputation: j.opt_f64("min_reputation", d.min_reputation),
            reputation_penalty: j.opt_f64("reputation_penalty", d.reputation_penalty),
            reputation_recovery_per_sec: j.opt_f64(
                "reputation_recovery_per_sec",
                d.reputation_recovery_per_sec,
            ),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<PolicyConfig> {
        Self::from_json(&json_parse(s).map_err(Error::Config)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("enabled", self.enabled)
            .set("bucket_capacity", self.bucket_capacity)
            .set("refill_per_sec", self.refill_per_sec)
            .set("tenant_quota", self.tenant_quota as usize)
            .set("quota_window_ms", self.quota_window_ms as usize)
            .set("min_reputation", self.min_reputation)
            .set("reputation_penalty", self.reputation_penalty)
            .set("reputation_recovery_per_sec", self.reputation_recovery_per_sec)
    }
}

/// Everything the ML scientist specifies when creating a task (§3.3.1).
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub task_name: String,
    pub app_name: String,
    pub workflow_name: String,

    /// Artifact preset executed on-device ("tiny", "micro").
    pub preset: String,

    /// Clients per round (sync) / per buffer epoch (async).
    pub clients_per_round: usize,
    /// Degraded floor: with `min_clients ≤ pool < clients_per_round` and
    /// the join grace elapsed, a smaller cohort forms instead of the
    /// round stalling at Joining. 0 (default) disables degraded rounds.
    pub min_clients: usize,
    /// Cohort policy the round engine runs (§4.2).
    pub cohort: CohortSpec,
    /// Total rounds (sync) or buffer flushes (async).
    pub total_rounds: u64,

    pub mode: FlMode,
    /// Aggregation strategy name: fedavg | fedprox | dga | fedbuff.
    pub aggregator: String,
    /// Server learning rate applied to the aggregated pseudo-gradient.
    pub server_lr: f32,
    /// Client learning rate (paper §5.1: 5e-4).
    pub client_lr: f32,
    /// FedProx μ (0 disables the proximal term).
    pub prox_mu: f32,
    /// Robust aggregation (trimmed_mean | median): fraction trimmed
    /// from each end per coordinate. Ignored by linear strategies.
    pub trim_fraction: f32,
    /// Robust pre-filter L2 clip bound; 0 selects the adaptive
    /// median-norm bound. Ignored by linear strategies.
    pub clip_norm: f32,

    /// Secure aggregation on/off + virtual-group size (§3.1.2).
    pub secure_agg: bool,
    pub vg_size: usize,
    /// Quantizer for the masked path.
    pub quant_range: f32,
    pub quant_bits: u32,

    pub dp: DpConfig,
    /// Population size assumed by the privacy accountant (paper: 100).
    pub dp_population: usize,

    pub selection: SelectionCriteria,
    /// Round upload deadline in ms.
    pub round_timeout_ms: u64,
    /// Fraction of the cohort that must report for a sync round to commit
    /// (stragglers beyond this are dropped, §2 "fault-tolerant methods").
    pub min_report_fraction: f64,
}

impl Default for TaskConfig {
    fn default() -> Self {
        TaskConfig {
            task_name: "task".into(),
            app_name: "app".into(),
            workflow_name: "workflow".into(),
            preset: "tiny".into(),
            clients_per_round: 32,
            min_clients: 0,
            cohort: CohortSpec::UniformRandom,
            total_rounds: 10,
            mode: FlMode::Sync,
            aggregator: "fedavg".into(),
            server_lr: 1.0,
            client_lr: 5e-4,
            prox_mu: 0.0,
            trim_fraction: 0.2,
            clip_norm: 0.0,
            secure_agg: false,
            vg_size: 16,
            quant_range: 4.0,
            quant_bits: 18,
            dp: DpConfig::off(),
            dp_population: 100,
            selection: SelectionCriteria::default(),
            round_timeout_ms: 120_000,
            min_report_fraction: 0.8,
        }
    }
}

impl TaskConfig {
    /// Validate invariants at task-creation time.
    pub fn validate(&self) -> Result<()> {
        if self.clients_per_round == 0 {
            return Err(Error::Config("clients_per_round must be > 0".into()));
        }
        if self.min_clients > self.clients_per_round {
            return Err(Error::Config(format!(
                "min_clients {} exceeds clients_per_round {}",
                self.min_clients, self.clients_per_round
            )));
        }
        if let CohortSpec::OverProvision { spawn_factor } = self.cohort {
            if !(spawn_factor.is_finite() && spawn_factor >= 1.0) {
                return Err(Error::Config(format!(
                    "spawn_factor must be ≥ 1.0, got {spawn_factor}"
                )));
            }
        }
        if self.total_rounds == 0 {
            return Err(Error::Config("total_rounds must be > 0".into()));
        }
        if let FlMode::Async { buffer_size } = self.mode {
            if buffer_size == 0 {
                return Err(Error::Config("async buffer_size must be > 0".into()));
            }
            if self.secure_agg {
                return Err(Error::Config(
                    "async mode relies on an attested aggregator (§4.3); \
                     pairwise-mask secure aggregation requires sync rounds"
                        .into(),
                ));
            }
        }
        if self.secure_agg {
            if self.vg_size < 2 {
                return Err(Error::Config("vg_size must be >= 2".into()));
            }
            crate::quant::Quantizer::new(self.quant_range, self.quant_bits)?;
        }
        if !(self.min_report_fraction > 0.0 && self.min_report_fraction <= 1.0) {
            return Err(Error::Config("min_report_fraction must be in (0,1]".into()));
        }
        if !(self.server_lr.is_finite() && self.client_lr.is_finite()) {
            return Err(Error::Config("non-finite learning rate".into()));
        }
        crate::aggregation::for_task(&self.aggregator, self.prox_mu, self.robust_params())?;
        Ok(())
    }

    /// The robust-aggregation knobs as the aggregation layer's params.
    pub fn robust_params(&self) -> crate::aggregation::RobustParams {
        crate::aggregation::RobustParams {
            trim_fraction: self.trim_fraction,
            clip_norm: self.clip_norm,
        }
    }

    /// Parse from JSON (CLI `create-task --config file.json`).
    pub fn from_json(j: &Json) -> Result<TaskConfig> {
        let d = TaskConfig::default();
        let mode = match j.opt_str("mode", "sync").as_str() {
            "sync" => FlMode::Sync,
            "async" => FlMode::Async {
                buffer_size: j.opt_usize("buffer_size", 32),
            },
            other => return Err(Error::Config(format!("bad mode {other:?}"))),
        };
        let dp_mode = match j.opt_str("dp_mode", "off").as_str() {
            "off" => DpMode::Off,
            "local" => DpMode::Local,
            "central" => DpMode::Central,
            other => return Err(Error::Config(format!("bad dp_mode {other:?}"))),
        };
        let cohort = match j.opt_str("cohort_policy", "uniform").as_str() {
            "uniform" => CohortSpec::UniformRandom,
            "tiered" => CohortSpec::Tiered,
            "overprovision" => CohortSpec::OverProvision {
                spawn_factor: j.opt_f64("spawn_factor", 1.25),
            },
            other => return Err(Error::Config(format!("bad cohort_policy {other:?}"))),
        };
        let cfg = TaskConfig {
            task_name: j.opt_str("task_name", &d.task_name),
            app_name: j.opt_str("app_name", &d.app_name),
            workflow_name: j.opt_str("workflow_name", &d.workflow_name),
            preset: j.opt_str("preset", &d.preset),
            clients_per_round: j.opt_usize("clients_per_round", d.clients_per_round),
            min_clients: j.opt_usize("min_clients", d.min_clients),
            cohort,
            total_rounds: j.opt_usize("total_rounds", d.total_rounds as usize) as u64,
            mode,
            aggregator: j.opt_str("aggregator", &d.aggregator),
            server_lr: j.opt_f64("server_lr", d.server_lr as f64) as f32,
            client_lr: j.opt_f64("client_lr", d.client_lr as f64) as f32,
            prox_mu: j.opt_f64("prox_mu", 0.0) as f32,
            trim_fraction: j.opt_f64("trim_fraction", d.trim_fraction as f64) as f32,
            clip_norm: j.opt_f64("clip_norm", d.clip_norm as f64) as f32,
            secure_agg: j.opt_bool("secure_agg", d.secure_agg),
            vg_size: j.opt_usize("vg_size", d.vg_size),
            quant_range: j.opt_f64("quant_range", d.quant_range as f64) as f32,
            quant_bits: j.opt_usize("quant_bits", d.quant_bits as usize) as u32,
            dp: DpConfig {
                mode: dp_mode,
                clip_norm: j.opt_f64("dp_clip", 0.5),
                noise_multiplier: j.opt_f64("dp_sigma", 0.08),
            },
            dp_population: j.opt_usize("dp_population", d.dp_population),
            selection: SelectionCriteria::default(),
            round_timeout_ms: j.opt_usize("round_timeout_ms", d.round_timeout_ms as usize) as u64,
            min_report_fraction: j.opt_f64("min_report_fraction", d.min_report_fraction),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<TaskConfig> {
        Self::from_json(&json_parse(s).map_err(Error::Config)?)
    }

    pub fn to_json(&self) -> Json {
        let (mode, buffer) = match self.mode {
            FlMode::Sync => ("sync", 0usize),
            FlMode::Async { buffer_size } => ("async", buffer_size),
        };
        let dp_mode = match self.dp.mode {
            DpMode::Off => "off",
            DpMode::Local => "local",
            DpMode::Central => "central",
        };
        let spawn_factor = match self.cohort {
            CohortSpec::OverProvision { spawn_factor } => spawn_factor,
            _ => 1.0,
        };
        Json::obj()
            .set("task_name", self.task_name.as_str())
            .set("app_name", self.app_name.as_str())
            .set("workflow_name", self.workflow_name.as_str())
            .set("preset", self.preset.as_str())
            .set("clients_per_round", self.clients_per_round)
            .set("min_clients", self.min_clients)
            .set("cohort_policy", self.cohort.name())
            .set("spawn_factor", spawn_factor)
            .set("total_rounds", self.total_rounds)
            .set("mode", mode)
            .set("buffer_size", buffer)
            .set("aggregator", self.aggregator.as_str())
            .set("server_lr", self.server_lr as f64)
            .set("client_lr", self.client_lr as f64)
            .set("prox_mu", self.prox_mu as f64)
            .set("trim_fraction", self.trim_fraction as f64)
            .set("clip_norm", self.clip_norm as f64)
            .set("secure_agg", self.secure_agg)
            .set("vg_size", self.vg_size)
            .set("quant_range", self.quant_range as f64)
            .set("quant_bits", self.quant_bits as usize)
            .set("dp_mode", dp_mode)
            .set("dp_clip", self.dp.clip_norm)
            .set("dp_sigma", self.dp.noise_multiplier)
            .set("dp_population", self.dp_population)
            .set("round_timeout_ms", self.round_timeout_ms as usize)
            .set("min_report_fraction", self.min_report_fraction)
    }
}

/// One preset entry from `artifacts/manifest.json` (written by aot.py).
#[derive(Clone, Debug)]
pub struct ArtifactPreset {
    pub name: String,
    pub param_count: usize,
    pub train_path: String,
    pub eval_path: String,
    pub init_path: String,
    pub local_steps: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub vocab: usize,
    pub seq_len: usize,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub presets: Vec<ArtifactPreset>,
    /// Directory the paths are relative to.
    pub dir: String,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Config(format!("read {path}: {e}")))?;
        let j = json_parse(&text).map_err(Error::Config)?;
        let presets = j
            .get("presets")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("manifest missing presets".into()))?;
        let mut out = Vec::new();
        for p in presets {
            let train = p
                .get("train")
                .ok_or_else(|| Error::Config("preset missing train".into()))?;
            let eval = p
                .get("eval")
                .ok_or_else(|| Error::Config("preset missing eval".into()))?;
            let model = p
                .get("model")
                .ok_or_else(|| Error::Config("preset missing model".into()))?;
            out.push(ArtifactPreset {
                name: p.req_str("preset").map_err(Error::Config)?.to_string(),
                param_count: p.req_usize("param_count").map_err(Error::Config)?,
                train_path: train.req_str("path").map_err(Error::Config)?.to_string(),
                eval_path: eval.req_str("path").map_err(Error::Config)?.to_string(),
                init_path: p.req_str("init_params").map_err(Error::Config)?.to_string(),
                local_steps: train.req_usize("local_steps").map_err(Error::Config)?,
                batch: train.req_usize("batch").map_err(Error::Config)?,
                eval_batch: eval.req_usize("batch").map_err(Error::Config)?,
                vocab: model.req_usize("vocab").map_err(Error::Config)?,
                seq_len: model.req_usize("seq_len").map_err(Error::Config)?,
            });
        }
        Ok(Manifest {
            presets: out,
            dir: dir.to_string(),
        })
    }

    pub fn preset(&self, name: &str) -> Result<&ArtifactPreset> {
        self.presets
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| Error::Config(format!("preset {name:?} not in manifest")))
    }

    pub fn path_of(&self, rel: &str) -> String {
        format!("{}/{}", self.dir, rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        TaskConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = TaskConfig::default();
        cfg.secure_agg = true;
        cfg.vg_size = 8;
        cfg.dp = DpConfig::paper_local();
        cfg.min_clients = 16;
        cfg.cohort = CohortSpec::OverProvision { spawn_factor: 1.5 };
        let j = cfg.to_json();
        let back = TaskConfig::from_json(&j).unwrap();
        assert_eq!(back.task_name, cfg.task_name);
        assert!(back.secure_agg);
        assert_eq!(back.vg_size, 8);
        assert_eq!(back.dp.mode, DpMode::Local);
        assert!((back.dp.clip_norm - 0.5).abs() < 1e-12);
        assert_eq!(back.min_clients, 16);
        assert_eq!(back.cohort, CohortSpec::OverProvision { spawn_factor: 1.5 });
    }

    #[test]
    fn cohort_policy_json_variants() {
        let cfg = TaskConfig::from_json_str(r#"{"cohort_policy":"tiered"}"#).unwrap();
        assert_eq!(cfg.cohort, CohortSpec::Tiered);
        let cfg = TaskConfig::from_json_str(r#"{"cohort_policy":"uniform"}"#).unwrap();
        assert_eq!(cfg.cohort, CohortSpec::UniformRandom);
        assert!(TaskConfig::from_json_str(r#"{"cohort_policy":"psychic"}"#).is_err());
    }

    #[test]
    fn async_config_roundtrip() {
        let mut cfg = TaskConfig::default();
        cfg.mode = FlMode::Async { buffer_size: 32 };
        cfg.aggregator = "fedbuff".into();
        let back = TaskConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.mode, FlMode::Async { buffer_size: 32 });
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = TaskConfig::default();
        c.clients_per_round = 0;
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.mode = FlMode::Async { buffer_size: 0 };
        assert!(c.validate().is_err());

        // secagg + async is a documented incompatibility
        let mut c = TaskConfig::default();
        c.mode = FlMode::Async { buffer_size: 8 };
        c.secure_agg = true;
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.secure_agg = true;
        c.vg_size = 1;
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.aggregator = "nope".into();
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.min_report_fraction = 0.0;
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.min_clients = c.clients_per_round + 1;
        assert!(c.validate().is_err());

        let mut c = TaskConfig::default();
        c.cohort = CohortSpec::OverProvision { spawn_factor: 0.5 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn robust_knobs_roundtrip_and_validate() {
        let mut cfg = TaskConfig::default();
        cfg.aggregator = "trimmed_mean".into();
        cfg.trim_fraction = 0.3;
        cfg.clip_norm = 12.5;
        let back = TaskConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.aggregator, "trimmed_mean");
        assert!((back.trim_fraction - 0.3).abs() < 1e-6);
        assert!((back.clip_norm - 12.5).abs() < 1e-6);

        // validate() threads the knobs into the aggregation registry.
        let mut bad = TaskConfig::default();
        bad.aggregator = "median".into();
        bad.clip_norm = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = TaskConfig::default();
        bad.aggregator = "trimmed_mean".into();
        bad.trim_fraction = 0.5;
        assert!(bad.validate().is_err());
        // The knobs are inert for linear strategies.
        let mut ok = TaskConfig::default();
        ok.trim_fraction = 0.9;
        ok.validate().unwrap();
    }

    #[test]
    fn policy_config_roundtrip_and_validate() {
        assert!(!PolicyConfig::default().enabled);
        PolicyConfig::default().validate().unwrap();
        let mut cfg = PolicyConfig::enabled();
        cfg.bucket_capacity = 4.0;
        cfg.tenant_quota = 100;
        cfg.min_reputation = 0.5;
        let back = PolicyConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        let parsed =
            PolicyConfig::from_json_str(r#"{"enabled":true,"refill_per_sec":2.5}"#).unwrap();
        assert!(parsed.enabled);
        assert!((parsed.refill_per_sec - 2.5).abs() < 1e-12);

        let mut bad = PolicyConfig::default();
        bad.bucket_capacity = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = PolicyConfig::default();
        bad.quota_window_ms = 0;
        assert!(bad.validate().is_err());
        let mut bad = PolicyConfig::default();
        bad.reputation_penalty = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_json_str_defaults() {
        let cfg = TaskConfig::from_json_str(r#"{"task_name":"t1","mode":"sync"}"#).unwrap();
        assert_eq!(cfg.task_name, "t1");
        assert_eq!(cfg.clients_per_round, 32);
    }

    #[test]
    fn bad_mode_rejected() {
        assert!(TaskConfig::from_json_str(r#"{"mode":"quantum"}"#).is_err());
        assert!(TaskConfig::from_json_str(r#"{"dp_mode":"??"}"#).is_err());
    }

    #[test]
    fn tree_spec_parses_and_validates() {
        assert_eq!(
            TreeSpec::parse("depth=2", 4).unwrap(),
            TreeSpec { depth: 2, leaves: 4 }
        );
        assert_eq!(TreeSpec::parse("1", 4).unwrap(), TreeSpec { depth: 1, leaves: 0 });
        assert!(!TreeSpec::default().uses_leaves());
        assert!(TreeSpec { depth: 2, leaves: 4 }.uses_leaves());
        assert!(TreeSpec::parse("depth=3", 4).is_err());
        assert!(TreeSpec::parse("depth=2", 0).is_err());
        assert!(TreeSpec::parse("depth=x", 4).is_err());
    }

    #[test]
    fn fsync_policy_parse_roundtrip() {
        for p in [FsyncPolicy::Always, FsyncPolicy::Commit, FsyncPolicy::Never] {
            assert_eq!(FsyncPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert_eq!(FsyncPolicy::default(), FsyncPolicy::Commit);
        let s = StorageConfig::new("/tmp/state").fsync(FsyncPolicy::Always);
        assert_eq!(s.fsync, FsyncPolicy::Always);
        assert_eq!(s.state_dir, std::path::PathBuf::from("/tmp/state"));
    }
}
