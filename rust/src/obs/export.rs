//! Telemetry export surface: one [`Report`] snapshot rendered as
//! Prometheus text-exposition format, JSON (the repo's own
//! `util::json`, no serde), or an aligned phase-breakdown table for the
//! `scale` scenarios. Assembled pull-side by
//! `FloridaServer::telemetry_report` — recording never serializes.

use crate::obs::histogram::HistogramSnapshot;
use crate::obs::trace::RoundTrace;
use crate::util::json::Json;

/// `GetTelemetry` wire format selector: JSON body.
pub const FORMAT_JSON: u32 = 0;
/// `GetTelemetry` wire format selector: Prometheus text exposition.
pub const FORMAT_PROMETHEUS: u32 = 1;

/// Per-method RPC latency digest (from the lock-free `RpcMetrics`
/// histograms).
#[derive(Clone, Debug)]
pub struct RpcReport {
    pub method: &'static str,
    pub calls: u64,
    pub errors: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// Point-in-time copy of every instrument, ready to render.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistogramSnapshot)>,
    pub rpc: Vec<RpcReport>,
    /// Slowest buffered rounds, longest first, with phase breakdown.
    pub rounds: Vec<RoundTrace>,
    /// Per-shard hot-path counters, `(shard index, name → value)`; one
    /// row even for an unsharded server (shard 0).
    pub shards: Vec<(usize, Vec<(&'static str, u64)>)>,
}

impl Report {
    /// Prometheus text exposition. Histograms render cumulative
    /// `_bucket{le=…}` lines (non-empty buckets + `+Inf`), `_sum`,
    /// `_count`, then explicit `{quantile=…}` and `_max` convenience
    /// lines so p50/p95/p99 need no server-side PromQL.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for &(name, v) in &self.counters {
            out.push_str(&format!(
                "# TYPE florida_{name} counter\nflorida_{name} {v}\n"
            ));
        }
        for &(name, v) in &self.gauges {
            out.push_str(&format!(
                "# TYPE florida_{name} gauge\nflorida_{name} {v}\n"
            ));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!("# TYPE florida_{name} histogram\n"));
            let mut cumulative = 0u64;
            for (i, &n) in h.buckets.iter().enumerate() {
                if n == 0 {
                    continue;
                }
                cumulative += n;
                out.push_str(&format!(
                    "florida_{name}_bucket{{le=\"{}\"}} {cumulative}\n",
                    crate::obs::Histogram::bucket_upper(i)
                ));
            }
            out.push_str(&format!(
                "florida_{name}_bucket{{le=\"+Inf\"}} {}\n",
                h.count
            ));
            out.push_str(&format!("florida_{name}_sum {}\n", h.sum));
            out.push_str(&format!("florida_{name}_count {}\n", h.count));
            for (q, v) in [(0.5, h.p50()), (0.95, h.p95()), (0.99, h.p99())] {
                out.push_str(&format!("florida_{name}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("florida_{name}_max {}\n", h.max));
        }
        // Per-shard rows carry the shard index as a label so a single
        // scrape shows whether the partition is spreading load. Emitted
        // name-major: a family's samples must be contiguous under its
        // TYPE line, and every shard reports the same counter set.
        if let Some((_, first)) = self.shards.first() {
            for (i, &(name, _)) in first.iter().enumerate() {
                out.push_str(&format!("# TYPE florida_{name} counter\n"));
                for (shard, counters) in &self.shards {
                    let v = counters.get(i).map(|&(_, v)| v).unwrap_or(0);
                    out.push_str(&format!("florida_{name}{{shard=\"{shard}\"}} {v}\n"));
                }
            }
        }
        if !self.rpc.is_empty() {
            out.push_str("# TYPE florida_rpc_latency_ns summary\n");
            for r in &self.rpc {
                let m = r.method;
                for (q, v) in [(0.5, r.p50_ns), (0.95, r.p95_ns), (0.99, r.p99_ns)] {
                    out.push_str(&format!(
                        "florida_rpc_latency_ns{{method=\"{m}\",quantile=\"{q}\"}} {v}\n"
                    ));
                }
                out.push_str(&format!(
                    "florida_rpc_latency_ns_sum{{method=\"{m}\"}} {}\n",
                    (r.mean_ns * r.calls as f64) as u64
                ));
                out.push_str(&format!(
                    "florida_rpc_latency_ns_count{{method=\"{m}\"}} {}\n",
                    r.calls
                ));
                out.push_str(&format!(
                    "florida_rpc_latency_ns_max{{method=\"{m}\"}} {}\n",
                    r.max_ns
                ));
                out.push_str(&format!(
                    "florida_rpc_errors_total{{method=\"{m}\"}} {}\n",
                    r.errors
                ));
            }
        }
        out
    }

    /// JSON rendering. Values ride as numbers (all far below 2^53 in
    /// practice) except `trace_id`, a full 64-bit hash that gets the
    /// string encoding — the same rule the wire codec follows for ids.
    pub fn to_json_value(&self) -> Json {
        let mut counters = Json::obj();
        for &(name, v) in &self.counters {
            counters = counters.set(name, v);
        }
        let mut gauges = Json::obj();
        for &(name, v) in &self.gauges {
            gauges = gauges.set(name, v);
        }
        let mut hists = Json::obj();
        for (name, h) in &self.hists {
            hists = hists.set(
                name,
                Json::obj()
                    .set("count", h.count)
                    .set("sum", h.sum)
                    .set("mean", h.mean())
                    .set("p50", h.p50())
                    .set("p95", h.p95())
                    .set("p99", h.p99())
                    .set("max", h.max),
            );
        }
        let rpc: Vec<Json> = self
            .rpc
            .iter()
            .map(|r| {
                Json::obj()
                    .set("method", r.method)
                    .set("calls", r.calls)
                    .set("errors", r.errors)
                    .set("mean_ns", r.mean_ns)
                    .set("p50_ns", r.p50_ns)
                    .set("p95_ns", r.p95_ns)
                    .set("p99_ns", r.p99_ns)
                    .set("max_ns", r.max_ns)
            })
            .collect();
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|t| {
                Json::obj()
                    .set("task_id", t.task_id)
                    .set("round", t.round)
                    .set("trace_id", format!("{}", t.trace_id))
                    .set("started_ms", t.started_ms)
                    .set("ended_ms", t.ended_ms)
                    .set("joining_ms", t.joining_ms)
                    .set("training_ms", t.training_ms)
                    .set("unmasking_ms", t.unmasking_ms)
                    .set("commit_ms", t.commit_ms)
                    .set("participants", t.participants as u64)
                    .set("committed", t.committed)
            })
            .collect();
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|(shard, counters)| {
                let mut row = Json::obj().set("shard", *shard as u64);
                for &(name, v) in counters {
                    row = row.set(name, v);
                }
                row
            })
            .collect();
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("rpc", rpc)
            .set("rounds", rounds)
            .set("shards", shards)
    }

    pub fn to_json(&self) -> String {
        self.to_json_value().pretty()
    }

    /// Aligned per-round phase-breakdown table for the `scale` scenario
    /// consoles ("slowest N rounds" order).
    pub fn phase_table(&self) -> String {
        let mut out = String::from(
            "task  round  join(ms)  train(ms)  unmask(ms)  commit(ms)  total(ms)  clients  state\n",
        );
        for t in &self.rounds {
            out.push_str(&format!(
                "{:>4}  {:>5}  {:>8}  {:>9}  {:>10}  {:>10}  {:>9}  {:>7}  {}\n",
                t.task_id,
                t.round,
                t.joining_ms,
                t.training_ms,
                t.unmasking_ms,
                t.commit_ms,
                t.total_ms(),
                t.participants,
                if t.committed { "committed" } else { "failed" },
            ));
        }
        out
    }

    /// Render in the `GetTelemetry` wire format: [`FORMAT_PROMETHEUS`]
    /// or (default, any other value) [`FORMAT_JSON`].
    pub fn render(&self, format: u32) -> String {
        if format == FORMAT_PROMETHEUS {
            self.to_prometheus()
        } else {
            self.to_json()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::trace_id_for;
    use crate::obs::Histogram;

    fn sample_report() -> Report {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 4000] {
            h.record(v);
        }
        Report {
            counters: vec![("rounds_committed", 2), ("evictions", 1)],
            gauges: vec![("sessions_live", 9)],
            hists: vec![("round_phase_training_ms", h.snapshot())],
            rpc: vec![RpcReport {
                method: "upload_plain",
                calls: 4,
                errors: 1,
                mean_ns: 1500.0,
                p50_ns: 1023,
                p95_ns: 4095,
                p99_ns: 4095,
                max_ns: 3900,
            }],
            rounds: vec![RoundTrace {
                task_id: 1,
                round: 0,
                trace_id: trace_id_for(1, 0),
                started_ms: 100,
                ended_ms: 400,
                joining_ms: 50,
                training_ms: 200,
                unmasking_ms: 0,
                commit_ms: 0,
                participants: 6,
                committed: true,
            }],
            shards: vec![
                (0, vec![("shard_polls", 3), ("shard_uploads", 2)]),
                (1, vec![("shard_polls", 4), ("shard_uploads", 1)]),
            ],
        }
    }

    #[test]
    fn prometheus_rendering_has_types_buckets_and_quantiles() {
        let text = sample_report().to_prometheus();
        assert!(text.contains("# TYPE florida_rounds_committed counter"));
        assert!(text.contains("florida_rounds_committed 2"));
        assert!(text.contains("# TYPE florida_sessions_live gauge"));
        assert!(text.contains("# TYPE florida_round_phase_training_ms histogram"));
        assert!(text.contains("florida_round_phase_training_ms_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("florida_round_phase_training_ms_count 4"));
        assert!(text.contains("florida_round_phase_training_ms{quantile=\"0.5\"}"));
        assert!(text.contains("florida_round_phase_training_ms{quantile=\"0.99\"}"));
        assert!(text
            .contains("florida_rpc_latency_ns{method=\"upload_plain\",quantile=\"0.95\"} 4095"));
        assert!(text.contains("florida_rpc_errors_total{method=\"upload_plain\"} 1"));
        // Per-shard counters: one TYPE line, contiguous labelled samples.
        assert_eq!(text.matches("# TYPE florida_shard_polls counter").count(), 1);
        assert!(text.contains("florida_shard_polls{shard=\"0\"} 3"));
        assert!(text.contains("florida_shard_polls{shard=\"1\"} 4"));
        assert!(text.contains("florida_shard_uploads{shard=\"1\"} 1"));
        // Cumulative bucket counts are monotone.
        let mut last = 0u64;
        for line in text.lines().filter(|l| {
            l.starts_with("florida_round_phase_training_ms_bucket")
        }) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket lines must be cumulative: {line}");
            last = v;
        }
    }

    #[test]
    fn json_rendering_parses_back() {
        let r = sample_report();
        let parsed = crate::util::json::parse(&r.to_json()).unwrap();
        assert_eq!(
            parsed
                .get("counters")
                .unwrap()
                .get("rounds_committed")
                .unwrap()
                .as_u64(),
            Some(2)
        );
        let hist = parsed
            .get("histograms")
            .unwrap()
            .get("round_phase_training_ms")
            .unwrap();
        assert_eq!(hist.get("count").unwrap().as_u64(), Some(4));
        assert!(hist.get("p95").unwrap().as_u64().unwrap() >= 30);
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        // trace_id rides as a string (full 64-bit value, f64-unsafe).
        assert!(rounds[0].get("trace_id").unwrap().as_str().is_some());
        assert_eq!(rounds[0].get("participants").unwrap().as_u64(), Some(6));
        let rpc = parsed.get("rpc").unwrap().as_arr().unwrap();
        assert_eq!(rpc[0].get("method").unwrap().as_str(), Some("upload_plain"));
        assert_eq!(rpc[0].get("p99_ns").unwrap().as_u64(), Some(4095));
        let shards = parsed.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[1].get("shard").unwrap().as_u64(), Some(1));
        assert_eq!(shards[1].get("shard_polls").unwrap().as_u64(), Some(4));
    }

    #[test]
    fn phase_table_lists_rounds() {
        let table = sample_report().phase_table();
        assert!(table.contains("join(ms)"));
        assert!(table.contains("committed"));
        assert!(table.lines().count() >= 2);
    }

    #[test]
    fn render_selects_format() {
        let r = sample_report();
        assert!(r.render(FORMAT_PROMETHEUS).starts_with("# TYPE"));
        assert!(r.render(FORMAT_JSON).trim_start().starts_with('{'));
        assert!(r.render(42).trim_start().starts_with('{'), "unknown → JSON");
    }
}
