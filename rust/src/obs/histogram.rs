//! Lock-free log2-bucketed histogram — the latency/size primitive behind
//! the [`crate::obs::Telemetry`] registry.
//!
//! 65 power-of-two buckets cover the full u64 range: bucket 0 holds the
//! value 0, bucket `i` (i ≥ 1) holds `[2^(i-1), 2^i)`. That is coarse
//! (each bucket spans a 2× band) but makes `record` a handful of relaxed
//! atomic adds — no lock, no allocation — which is what the poll/upload
//! fast path requires, and p50/p95/p99/max stay derivable from the fixed
//! buckets. Histograms `merge` associatively, so per-shard registries
//! (ROADMAP: sharded data plane) can fold into one export later.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: one zero bucket + one per possible leading-bit position.
pub const BUCKETS: usize = 65;

/// Lock-free histogram of u64 samples (durations in ns/ms, counts, …).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Index of the bucket holding `v`: 0 for 0, else `64 - clz(v)`
    /// (monotone in `v`; bucket `i ≥ 1` covers `[2^(i-1), 2^i)`).
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Inclusive lower bound of bucket `i`.
    #[inline]
    pub fn bucket_lower(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Inclusive upper bound of bucket `i`.
    #[inline]
    pub fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one sample. Relaxed atomics only — safe on the hot path.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Fold another histogram into this one (bucket-wise add, max of
    /// maxes) — `merge(h1, h2)` ≡ the histogram of the concatenated
    /// sample streams.
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy for export. Concurrent
    /// recording may skew individual cells by in-flight samples; totals
    /// are conserved (every `record` lands exactly once).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of a [`Histogram`] for quantile math and export.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate for `q` in [0, 1]: the upper bound of the bucket
    /// where the cumulative count crosses `ceil(q · count)`, capped at
    /// the observed max — always within the true quantile's bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cumulative = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cumulative += n;
            if cumulative >= target {
                return Histogram::bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_bounds_are_monotone_and_cover() {
        // Property: bucket_index is monotone, every value lands inside
        // its bucket's [lower, upper] band, and bands tile the u64 line.
        let mut prev = 0usize;
        for i in 0..BUCKETS {
            assert!(Histogram::bucket_lower(i) <= Histogram::bucket_upper(i));
            if i > 0 {
                assert_eq!(
                    Histogram::bucket_lower(i),
                    Histogram::bucket_upper(i - 1).wrapping_add(1),
                    "bands must tile with no gap at bucket {i}"
                );
            }
        }
        let mut rng = Rng::new(0xB0C4);
        let mut samples: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();
        samples.extend([0, 1, 2, 3, u64::MAX, u64::MAX - 1, 1 << 32]);
        samples.sort_unstable();
        for &v in &samples {
            let i = Histogram::bucket_index(v);
            assert!(i >= prev, "bucket_index must be monotone in v");
            assert!(Histogram::bucket_lower(i) <= v && v <= Histogram::bucket_upper(i));
            prev = i;
        }
    }

    #[test]
    fn prop_quantile_within_true_quantile_bucket() {
        let mut rng = Rng::new(0x51AB);
        for trial in 0..20 {
            let n = 100 + (trial * 137) % 2000;
            let h = Histogram::new();
            let mut samples: Vec<u64> = (0..n)
                .map(|_| rng.next_u64() >> (rng.below(60) as u32))
                .collect();
            for &v in &samples {
                h.record(v);
            }
            samples.sort_unstable();
            let snap = h.snapshot();
            for &q in &[0.0, 0.01, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let target = ((q * n as f64).ceil() as usize).clamp(1, n);
                let truth = samples[target - 1];
                let est = snap.quantile(q);
                let bucket = Histogram::bucket_index(truth);
                assert!(
                    Histogram::bucket_lower(bucket) <= est
                        && est <= Histogram::bucket_upper(bucket),
                    "q={q}: estimate {est} outside true-quantile bucket \
                     [{}, {}] (truth {truth})",
                    Histogram::bucket_lower(bucket),
                    Histogram::bucket_upper(bucket)
                );
            }
            assert_eq!(snap.max, *samples.last().unwrap());
            assert_eq!(snap.count, n as u64);
        }
    }

    #[test]
    fn prop_merge_equals_concatenated_samples() {
        let mut rng = Rng::new(0x3E26);
        for _ in 0..10 {
            let (h1, h2, h_all) = (Histogram::new(), Histogram::new(), Histogram::new());
            let xs: Vec<u64> = (0..500).map(|_| rng.next_u64() >> 20).collect();
            let ys: Vec<u64> = (0..300).map(|_| rng.next_u64() >> 44).collect();
            for &x in &xs {
                h1.record(x);
                h_all.record(x);
            }
            for &y in &ys {
                h2.record(y);
                h_all.record(y);
            }
            h1.merge(&h2);
            assert_eq!(h1.snapshot(), h_all.snapshot());
        }
    }

    #[test]
    fn concurrent_recording_conserves_totals() {
        use std::sync::Arc;
        const THREADS: u64 = 4;
        const PER_THREAD: u64 = 10_000;
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        h.record(t * PER_THREAD + i);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, THREADS * PER_THREAD);
        assert_eq!(snap.buckets.iter().sum::<u64>(), THREADS * PER_THREAD);
        let expect_sum: u64 = (0..THREADS * PER_THREAD).sum();
        assert_eq!(snap.sum, expect_sum);
        assert_eq!(snap.max, THREADS * PER_THREAD - 1);
    }

    #[test]
    fn empty_and_single_sample_edges() {
        let h = Histogram::new();
        let snap = h.snapshot();
        assert!(snap.is_empty());
        assert_eq!(snap.quantile(0.5), 0);
        assert_eq!(snap.mean(), 0.0);
        h.record(1500);
        let snap = h.snapshot();
        assert_eq!(snap.count, 1);
        assert_eq!(snap.max, 1500);
        // A single sample is every quantile; the estimate is capped at
        // the observed max, so it is exact here.
        assert_eq!(snap.p50(), 1500);
        assert_eq!(snap.p99(), 1500);
        assert_eq!(snap.mean(), 1500.0);
    }
}
