//! Round-phase tracing: a lightweight span layer clocked off the
//! server's manual-clock seam (`FloridaServer::now_ms`/`now_ns` — no
//! wall clock in core; the `wall-clock-in-core` lint enforces it).
//!
//! Each committed round yields one [`RoundTrace`] root span with its
//! phase breakdown (Joining → Training → Unmasking → Commit); per-RPC
//! child spans are recorded by the router when a request frame carries a
//! `trace_id` (the optional wire trailer — absent field = no trace, so
//! v1 clients cost nothing). Completed spans feed bounded in-memory
//! rings queryable as "slowest N rounds with phase breakdown".

use std::collections::VecDeque;
use std::sync::Mutex;

/// Deterministic trace id for `(task_id, round)` — splitmix64-style
/// finalizer over both coordinates. Client and server compute the same
/// id independently, so an upload correlates server-side without any
/// id-assignment round trip. Never returns 0 (0 is "no trace").
pub fn trace_id_for(task_id: u64, round: u64) -> u64 {
    let mut z = task_id
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(round)
        .wrapping_add(0x466C_6F72_6964_6121); // "Florida!" salt
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)).max(1)
}

/// Root span of one committed (or failed) round: the phase breakdown an
/// operator needs to answer "where did this round's time go?".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundTrace {
    pub task_id: u64,
    pub round: u64,
    pub trace_id: u64,
    /// Joining-phase start (server clock, ms).
    pub started_ms: u64,
    /// Commit/fail time (server clock, ms).
    pub ended_ms: u64,
    pub joining_ms: u64,
    pub training_ms: u64,
    pub unmasking_ms: u64,
    pub commit_ms: u64,
    pub participants: u32,
    pub committed: bool,
}

impl RoundTrace {
    /// Total root-span duration. Phase durations sum to at most this
    /// (the export integration test pins the invariant).
    pub fn total_ms(&self) -> u64 {
        self.ended_ms.saturating_sub(self.started_ms)
    }
}

/// Per-RPC child span, recorded only for requests that carried a
/// `trace_id` on the wire — zero cost when tracing is off.
#[derive(Clone, Debug)]
pub struct RpcSpan {
    pub trace_id: u64,
    pub method: &'static str,
    pub at_ms: u64,
    pub elapsed_ns: u64,
    pub error: bool,
}

/// Bounded ring of completed spans. Pushes happen at round boundaries /
/// traced RPCs (not the untraced fast path); the mutex is poison-
/// tolerant — a panicking writer degrades to dropped spans, never a
/// panicking reader.
pub struct Ring<T> {
    inner: Mutex<VecDeque<T>>,
    cap: usize,
}

impl<T: Clone> Ring<T> {
    pub fn new(cap: usize) -> Ring<T> {
        Ring {
            inner: Mutex::new(VecDeque::with_capacity(cap.min(64))),
            cap: cap.max(1),
        }
    }

    pub fn push(&self, item: T) {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if g.len() == self.cap {
            g.pop_front();
        }
        g.push_back(item);
    }

    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Newest-first copy of the buffered spans.
    pub fn items(&self) -> Vec<T> {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.iter().rev().cloned().collect()
    }
}

impl<T: Clone> Default for Ring<T> {
    /// Default capacity for the `Telemetry` registry rings: 256 spans is
    /// plenty for the "slowest N rounds" console queries while bounding
    /// memory regardless of uptime.
    fn default() -> Ring<T> {
        Ring::new(256)
    }
}

/// Ring of round root spans with the "slowest N" query.
pub type TraceRing = Ring<RoundTrace>;

impl TraceRing {
    /// The `n` slowest buffered rounds, longest total duration first
    /// (ties broken newest-first) — the ISSUE's "slowest N rounds with
    /// phase breakdown" query.
    pub fn slowest(&self, n: usize) -> Vec<RoundTrace> {
        let mut v = self.items();
        v.sort_by(|a, b| b.total_ms().cmp(&a.total_ms()));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(round: u64, total: u64) -> RoundTrace {
        RoundTrace {
            task_id: 1,
            round,
            trace_id: trace_id_for(1, round),
            started_ms: 1000 * round,
            ended_ms: 1000 * round + total,
            joining_ms: total / 4,
            training_ms: total / 2,
            unmasking_ms: 0,
            commit_ms: 0,
            participants: 8,
            committed: true,
        }
    }

    #[test]
    fn trace_ids_are_stable_nonzero_and_distinct() {
        assert_eq!(trace_id_for(1, 0), trace_id_for(1, 0));
        assert_ne!(trace_id_for(1, 0), trace_id_for(1, 1));
        assert_ne!(trace_id_for(1, 0), trace_id_for(2, 0));
        for t in 0..64 {
            for r in 0..64 {
                assert_ne!(trace_id_for(t, r), 0);
            }
        }
    }

    #[test]
    fn ring_bounds_and_orders() {
        let ring: TraceRing = Ring::new(4);
        assert!(ring.is_empty());
        for round in 0..10 {
            ring.push(trace(round, 100 + round * 10));
        }
        assert_eq!(ring.len(), 4, "ring must stay bounded");
        let items = ring.items();
        assert_eq!(items[0].round, 9, "newest first");
        // Only the last 4 pushes survive; slowest = highest total.
        let slow = ring.slowest(2);
        assert_eq!(slow[0].round, 9);
        assert_eq!(slow[1].round, 8);
        assert_eq!(slow[0].total_ms(), 190);
    }

    #[test]
    fn phase_sums_bounded_by_total() {
        let t = trace(3, 120);
        assert!(t.joining_ms + t.training_ms + t.unmasking_ms + t.commit_ms <= t.total_ms());
    }
}
