//! Observability subsystem (§3.3.1 "Metrics"/"Task" management views):
//! a process-wide telemetry registry of typed counters, gauges and
//! log2-bucketed histograms, a round-phase trace layer, and the
//! Prometheus/JSON export surface behind the `GetTelemetry` admin RPC.
//!
//! Design rules, in order:
//! 1. **No new lock on the hot path.** Every instrument a poll/upload
//!    dispatch touches is a relaxed `AtomicU64` cell ([`Counter`],
//!    [`Gauge`], [`histogram::Histogram`]). The only mutexes live in the
//!    bounded trace rings, pushed at round boundaries or for explicitly
//!    traced RPCs.
//! 2. **No wall clock in core.** Durations come from the server's
//!    `Clock` seam (`now_ms`/`now_ns`), so telemetry is deterministic
//!    under the manual clock; the two deliberate exceptions (journal
//!    append / checkpoint write disk latency) carry inline lint allows.
//! 3. **Export is pull-only.** Recording never formats, allocates or
//!    serializes; rendering happens in [`export`] when an operator asks.

pub mod export;
pub mod histogram;
pub mod trace;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

pub use histogram::{Histogram, HistogramSnapshot};
pub use trace::{trace_id_for, Ring, RoundTrace, RpcSpan, TraceRing};

/// Monotone event counter (relaxed atomic).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if n > 0 {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins level gauge (relaxed atomic).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The process-wide instrument registry: one per [`crate::services::FloridaServer`],
/// shared (`Arc`) with the round engines, persistence layer and router.
///
/// docs/architecture.md carries the full instrument inventory table;
/// keep the two in sync when adding an instrument.
#[derive(Default)]
pub struct Telemetry {
    // -- round engine --------------------------------------------------
    pub rounds_committed: Counter,
    pub rounds_failed: Counter,
    /// Mid-round lease evictions (cohort members removed).
    pub evictions: Counter,
    /// Cohort slots refilled from the join pool after an eviction.
    pub backfills: Counter,
    pub round_phase_joining_ms: Histogram,
    pub round_phase_training_ms: Histogram,
    pub round_phase_unmasking_ms: Histogram,
    pub round_phase_commit_ms: Histogram,
    /// Cohort size at formation.
    pub cohort_fill: Histogram,
    // -- aggregation ---------------------------------------------------
    /// Ingest-dispatch latency (upload accepted → fold returned).
    pub agg_fold_ns: Histogram,
    /// Uploads zero-scored by a Byzantine-robust fold.
    pub robust_zero_scored: Counter,
    /// Partial accumulators absorbed at the root (leaf forwards and
    /// shard-lane commits).
    pub partials_absorbed: Counter,
    // -- sessions ------------------------------------------------------
    pub sessions_opened: Counter,
    pub sessions_renewed: Counter,
    /// Expired leases removed by the tick sweep.
    pub sessions_swept: Counter,
    pub sessions_live: Gauge,
    // -- storage -------------------------------------------------------
    pub journal_append_ns: Histogram,
    pub checkpoint_write_ns: Histogram,
    pub fsyncs: Counter,
    // -- tracing -------------------------------------------------------
    /// Root spans of completed rounds (bounded; newest win).
    pub rounds: TraceRing,
    /// Child spans of traced RPCs (bounded; newest win).
    pub rpc_spans: Ring<RpcSpan>,
    /// Gates *client-side* trace-id attachment helpers; server-side span
    /// recording keys off the frame's trace id, so untraced traffic
    /// costs one `Option` check.
    tracing: AtomicBool,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn tracing_enabled(&self) -> bool {
        self.tracing.load(Ordering::Relaxed)
    }

    pub fn set_tracing(&self, on: bool) {
        self.tracing.store(on, Ordering::Relaxed);
    }

    /// Counter inventory for export, name → value.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("rounds_committed", self.rounds_committed.get()),
            ("rounds_failed", self.rounds_failed.get()),
            ("evictions", self.evictions.get()),
            ("backfills", self.backfills.get()),
            ("robust_zero_scored", self.robust_zero_scored.get()),
            ("partials_absorbed", self.partials_absorbed.get()),
            ("sessions_opened", self.sessions_opened.get()),
            ("sessions_renewed", self.sessions_renewed.get()),
            ("sessions_swept", self.sessions_swept.get()),
            ("fsyncs", self.fsyncs.get()),
        ]
    }

    /// Gauge inventory for export, name → value.
    pub fn gauges(&self) -> Vec<(&'static str, u64)> {
        vec![("sessions_live", self.sessions_live.get())]
    }

    /// Histogram inventory for export, name → snapshot.
    pub fn histograms(&self) -> Vec<(&'static str, HistogramSnapshot)> {
        vec![
            ("round_phase_joining_ms", self.round_phase_joining_ms.snapshot()),
            ("round_phase_training_ms", self.round_phase_training_ms.snapshot()),
            (
                "round_phase_unmasking_ms",
                self.round_phase_unmasking_ms.snapshot(),
            ),
            ("round_phase_commit_ms", self.round_phase_commit_ms.snapshot()),
            ("cohort_fill", self.cohort_fill.snapshot()),
            ("agg_fold_ns", self.agg_fold_ns.snapshot()),
            ("journal_append_ns", self.journal_append_ns.snapshot()),
            ("checkpoint_write_ns", self.checkpoint_write_ns.snapshot()),
        ]
    }
}

/// Per-shard hot-path instruments: one row of relaxed counters per
/// worker shard, so the scale report (and the `florida_shard_*`
/// export) can show whether the partition is actually spreading load.
#[derive(Default)]
pub struct ShardStats {
    pub polls: Counter,
    pub uploads: Counter,
    pub heartbeats: Counter,
    /// Lease evictions swept off this shard's session slice.
    pub evictions: Counter,
    /// Eviction batches this shard posted to the tick mailbox.
    pub mailbox_batches: Counter,
}

impl ShardStats {
    /// Counter inventory for export, name → value.
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("shard_polls", self.polls.get()),
            ("shard_uploads", self.uploads.get()),
            ("shard_heartbeats", self.heartbeats.get()),
            ("shard_evictions", self.evictions.get()),
            ("shard_mailbox_batches", self.mailbox_batches.get()),
        ]
    }
}

/// The per-shard instrument rows for one server (`shards` entries).
#[derive(Default)]
pub struct ShardSet {
    stats: Vec<ShardStats>,
}

impl ShardSet {
    pub fn new(shards: usize) -> ShardSet {
        ShardSet {
            stats: (0..shards.max(1)).map(|_| ShardStats::default()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.stats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stats.is_empty()
    }

    /// One shard's row. Panics on out-of-range — callers index with the
    /// same `ShardRouter` that sized the set.
    pub fn shard(&self, i: usize) -> &ShardStats {
        &self.stats[i]
    }

    /// Snapshot for the export surface: `(shard, counters)` per shard.
    pub fn report(&self) -> Vec<(usize, Vec<(&'static str, u64)>)> {
        self.stats.iter().enumerate().map(|(i, s)| (i, s.counters())).collect()
    }
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("rounds_committed", &self.rounds_committed.get())
            .field("rounds_failed", &self.rounds_failed.get())
            .field("tracing", &self.tracing_enabled())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let t = Telemetry::new();
        t.rounds_committed.inc();
        t.evictions.add(3);
        t.evictions.add(0);
        t.sessions_live.set(12);
        t.sessions_live.set(7);
        assert_eq!(t.rounds_committed.get(), 1);
        assert_eq!(t.evictions.get(), 3);
        assert_eq!(t.sessions_live.get(), 7);
        let names: Vec<&str> = t.counters().iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"rounds_committed"));
        assert!(names.contains(&"fsyncs"));
        assert_eq!(t.gauges()[0], ("sessions_live", 7));
    }

    #[test]
    fn histogram_inventory_covers_round_phases() {
        let t = Telemetry::new();
        t.round_phase_training_ms.record(42);
        let hists = t.histograms();
        for phase in [
            "round_phase_joining_ms",
            "round_phase_training_ms",
            "round_phase_unmasking_ms",
            "round_phase_commit_ms",
        ] {
            assert!(hists.iter().any(|(n, _)| *n == phase), "missing {phase}");
        }
        let train = &hists
            .iter()
            .find(|(n, _)| *n == "round_phase_training_ms")
            .unwrap()
            .1;
        assert_eq!(train.count, 1);
    }

    #[test]
    fn shard_set_reports_per_shard_rows() {
        let s = ShardSet::new(3);
        assert_eq!(s.len(), 3);
        s.shard(0).polls.inc();
        s.shard(2).uploads.add(5);
        s.shard(2).mailbox_batches.inc();
        let report = s.report();
        assert_eq!(report.len(), 3);
        assert_eq!(report[0].0, 0);
        assert!(report[0].1.contains(&("shard_polls", 1)));
        assert!(report[1].1.contains(&("shard_polls", 0)));
        assert!(report[2].1.contains(&("shard_uploads", 5)));
        assert!(report[2].1.contains(&("shard_mailbox_batches", 1)));
        // Degenerate size clamps to one shard, never zero rows.
        assert_eq!(ShardSet::new(0).len(), 1);
    }

    #[test]
    fn tracing_gate_defaults_off() {
        let t = Telemetry::new();
        assert!(!t.tracing_enabled());
        t.set_tracing(true);
        assert!(t.tracing_enabled());
    }
}
