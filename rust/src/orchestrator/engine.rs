//! Per-task round engine: the typed phase state machine at the heart of
//! the orchestrator (§3.1.1), parameterized by pluggable policies.
//!
//! ```text
//!            ┌────────────── CohortPolicy::form ──────────────┐
//!            ▼                                                │
//!   Joining ──► Training ──(PacingPolicy: Commit)──► Committed ──► next round
//!      ▲            │                                    ▲
//!      │            ├──(secagg dropouts)──► Unmasking ───┘
//!      │            │                          │
//!      └── Failed ◄─┴──(PacingPolicy: Fail)────┘
//! ```
//!
//! `Committed`/`Failed` are the explicit transition points
//! ([`RoundEngine::commit_round`] / [`RoundEngine::fail_round`]): a
//! committed round advances the model and re-enters `Joining` for the
//! next round (or completes the task); a failed round re-enters
//! `Joining` with the waiting pool intact. Every transition is emitted
//! on the [`EventBus`], so dashboards and the simulator observe the
//! lifecycle instead of polling `task_status`.
//!
//! Async tasks (§4.3) skip the barrier: every joiner trains immediately
//! against the newest model; uploads fill a buffer that the pacing
//! policy flushes (goal counts) with staleness-aware weighting (Papaya).

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::Arc;

use crate::aggregation::{self, AggregatorFold, PartialFold, UpdateStats};
use crate::config::{FlMode, TaskConfig};
use crate::dp::{DpMode, RdpAccountant};
use crate::error::{Error, Result};
use crate::metrics::{RoundRecord, TaskMetrics};
use crate::model::{ModelSnapshot, SnapshotStore};
use crate::obs::{trace_id_for, RoundTrace, Telemetry};
use crate::proto::msg::{PeerShare, RecoveredShare};
use crate::proto::rpc::LeafAssignment;
use crate::proto::{RoundInstruction, RoundRole, TaskDescriptor, TaskState, TrainParams};
use crate::quant::Quantizer;
use crate::services::master_aggregator::MasterAggregator;
use crate::services::secure_aggregator::SecAggRound;
use crate::services::selection::SelectionService;
use crate::storage::{CheckpointView, NoopPersistence, Persistence};
use crate::util::Rng;

use super::events::{EventBus, TaskEvent};
use super::policy::{
    ClientDirectory, CohortContext, CohortPolicy, PacingDecision, PacingPolicy, RoundProgress,
};

/// Server-side model evaluation hook (wired to the PJRT runtime by the
/// simulator / server binary; `NoEval` for dummy tasks).
pub trait Evaluator: Send + Sync {
    /// Returns (eval_loss, eval_accuracy) for the given global params.
    fn evaluate(&self, preset: &str, params: &[f32]) -> Option<(f64, f64)>;
}

/// No-op evaluator.
pub struct NoEval;

impl Evaluator for NoEval {
    fn evaluate(&self, _preset: &str, _params: &[f32]) -> Option<(f64, f64)> {
        None
    }
}

/// Phase of the current sync round (internal to the engine — nothing
/// outside `orchestrator/` matches on it).
enum Phase {
    /// Accumulating joiners; the pool holds (client, round pubkey).
    Joining,
    /// Cohort selected, clients training. The model blob clients fetch
    /// comes from the global [`SnapshotStore`] cache (the version is
    /// pinned by `base_version` until commit).
    Training {
        secagg: Option<SecAggRound>,
        /// Plaintext rounds: O(dim) streaming ingest (None under secagg,
        /// whose masked running sums live in `SecAggRound`).
        ingest: Option<StreamingIngest>,
        uploaded: BTreeSet<u64>,
        base_version: u64,
        deadline_ms: u64,
    },
    /// Waiting for survivors' unmask shares.
    Unmasking {
        secagg: SecAggRound,
        deadline_ms: u64,
    },
}

/// Streaming upload ingest: each arriving delta is folded into the
/// task's aggregation strategy immediately, so resident state is the
/// fold's O(dim) accumulator plus per-upload scalars — never a
/// cohort × dim buffer of deltas.
struct StreamingIngest {
    fold: Box<dyn AggregatorFold>,
    loss_sum: f64,
}

impl StreamingIngest {
    fn new(fold: Box<dyn AggregatorFold>) -> StreamingIngest {
        StreamingIngest {
            fold,
            loss_sum: 0.0,
        }
    }

    fn accept(&mut self, delta: &[f32], stats: &UpdateStats) -> Result<()> {
        self.fold.accept(delta, stats)?;
        self.loss_sum += stats.loss;
        Ok(())
    }

    /// Merge a leaf aggregator's exported partial — O(dim) regardless
    /// of how many member updates the leaf folded.
    fn absorb(&mut self, part: &PartialFold, loss_sum: f64) -> Result<()> {
        self.fold.absorb(part)?;
        self.loss_sum += loss_sum;
        Ok(())
    }

    fn count(&self) -> usize {
        self.fold.count()
    }

    fn mean_loss(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.loss_sum / n as f64
        }
    }
}

/// One federated task's orchestration state machine.
pub struct RoundEngine {
    pub id: u64,
    pub config: TaskConfig,
    pub state: TaskState,
    /// Completed sync rounds / async flushes.
    pub round: u64,
    /// The global model behind its version-keyed distribution cache —
    /// every poll hands out an `Arc` of the compressed blob; zlib runs
    /// once per version bump.
    pub global: SnapshotStore,
    pub metrics: TaskMetrics,
    pub accountant: Option<RdpAccountant>,

    master: MasterAggregator,
    rng: Rng,
    phase: Phase,
    /// Durability hooks (`crate::storage`): journal appends on every
    /// transition, checkpoint + truncate on commit. Defaults to
    /// [`NoopPersistence`], so in-memory paths pay nothing.
    persistence: Box<dyn Persistence>,
    cohort_policy: Box<dyn CohortPolicy>,
    pacing: Box<dyn PacingPolicy>,
    events: EventBus,
    /// Sync: waiting joiners (client, per-round pubkey), FIFO.
    join_pool: VecDeque<(u64, [u8; 32])>,
    /// When the current joining phase started waiting (first joiner).
    joining_since_ms: Option<u64>,
    /// Current-round cohort (empty outside Training/Unmasking).
    cohort: BTreeSet<u64>,
    round_started_ms: u64,

    /// Shared instrument registry (None until the management service
    /// injects it — in-memory unit tests pay nothing).
    telemetry: Option<Arc<Telemetry>>,
    /// Root-span start for the current round's trace: when the joining
    /// phase began waiting (== `round_started_ms` when no one waited).
    trace_started_ms: u64,
    /// Joining-phase duration captured at cohort formation.
    trace_joining_ms: u64,
    /// When the unmask detour began (None on the direct commit path).
    trace_unmasking_since_ms: Option<u64>,

    // Async state: the in-flight buffer epoch's streaming fold (None
    // between flushes) plus the joined set.
    ingest: Option<StreamingIngest>,
    async_joined: BTreeSet<u64>,
    last_flush_ms: u64,
}

impl RoundEngine {
    /// Build an engine with policies derived from the config
    /// (`config.cohort` spec; pacing from the sync/async mode).
    pub fn new(
        id: u64,
        config: TaskConfig,
        global: ModelSnapshot,
        seed: u64,
        events: EventBus,
    ) -> Result<RoundEngine> {
        let cohort_policy = config.cohort.build();
        let pacing = super::policy::default_pacing(config.mode);
        Self::with_policies(id, config, global, seed, events, cohort_policy, pacing)
    }

    /// Build an engine with explicit policy objects (custom policies the
    /// config cannot express — tests, experiments).
    pub fn with_policies(
        id: u64,
        config: TaskConfig,
        global: ModelSnapshot,
        seed: u64,
        events: EventBus,
        cohort_policy: Box<dyn CohortPolicy>,
        pacing: Box<dyn PacingPolicy>,
    ) -> Result<RoundEngine> {
        config.validate()?;
        let strategy =
            aggregation::for_task(&config.aggregator, config.prox_mu, config.robust_params())?;
        let master = MasterAggregator::new(strategy, config.dp, config.server_lr);
        let accountant = if config.dp.mode != DpMode::Off {
            Some(RdpAccountant::new())
        } else {
            None
        };
        Ok(RoundEngine {
            id,
            config,
            state: TaskState::Created,
            round: 0,
            global: SnapshotStore::new(global),
            metrics: TaskMetrics::default(),
            accountant,
            master,
            rng: Rng::new(seed),
            phase: Phase::Joining,
            persistence: Box::new(NoopPersistence),
            cohort_policy,
            pacing,
            events,
            join_pool: VecDeque::new(),
            joining_since_ms: None,
            cohort: BTreeSet::new(),
            round_started_ms: 0,
            telemetry: None,
            trace_started_ms: 0,
            trace_joining_ms: 0,
            trace_unmasking_since_ms: None,
            ingest: None,
            async_joined: BTreeSet::new(),
            last_flush_ms: 0,
        })
    }

    /// Rebuild an engine at a committed round boundary (crash
    /// recovery). No events are emitted; the phase re-enters `Joining`.
    /// A round that was open at crash time is deliberately
    /// failed-and-retried by the caller — streaming aggregation folds
    /// are not replayable mid-round. The DP accountant is re-stepped
    /// from the recovered round history, so epsilon survives restarts.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        id: u64,
        config: TaskConfig,
        global: SnapshotStore,
        seed: u64,
        events: EventBus,
        state: TaskState,
        round: u64,
        metrics: TaskMetrics,
    ) -> Result<RoundEngine> {
        let mut e = Self::new(id, config, ModelSnapshot::new(0, Vec::new()), seed, events)?;
        e.global = global;
        e.state = state;
        e.round = round;
        e.metrics = metrics;
        if let Some(acc) = &mut e.accountant {
            for r in &e.metrics.rounds {
                let q = (r.participants as f64 / e.config.dp_population as f64).min(1.0);
                let _ = acc.step(q, e.config.dp.noise_multiplier);
            }
        }
        Ok(e)
    }

    /// Attach durable persistence to a fresh task: writes the initial
    /// checkpoint + journal birth record, then installs the hooks.
    pub fn persist_to(&mut self, mut persistence: Box<dyn Persistence>) -> Result<()> {
        persistence.task_created(&self.checkpoint_view())?;
        if let Some(t) = &self.telemetry {
            persistence.set_telemetry(Arc::clone(t));
        }
        self.persistence = persistence;
        Ok(())
    }

    /// Re-attach persistence after recovery (no initial checkpoint).
    pub fn resume_persistence(&mut self, mut persistence: Box<dyn Persistence>) {
        if let Some(t) = &self.telemetry {
            persistence.set_telemetry(Arc::clone(t));
        }
        self.persistence = persistence;
    }

    /// Inject the shared instrument registry, fanning it into the
    /// attached persistence layer (journal/checkpoint latency).
    pub fn set_telemetry(&mut self, telemetry: Arc<Telemetry>) {
        self.persistence.set_telemetry(Arc::clone(&telemetry));
        self.telemetry = Some(telemetry);
    }

    /// The engine's current committed-round boundary image.
    pub fn checkpoint_view(&self) -> CheckpointView<'_> {
        CheckpointView {
            task_id: self.id,
            config: &self.config,
            state: self.state,
            round: self.round,
            store: &self.global,
            metrics: &self.metrics,
        }
    }

    /// Force a checkpoint at the current committed-round boundary
    /// (graceful shutdown, admin op). An in-flight round is *not*
    /// captured — it restarts cleanly after recovery, by design.
    pub fn checkpoint(&mut self) -> Result<()> {
        let view = CheckpointView {
            task_id: self.id,
            config: &self.config,
            state: self.state,
            round: self.round,
            store: &self.global,
            metrics: &self.metrics,
        };
        self.persistence.checkpoint(&view)
    }

    /// Run a journal hook, downgrading failures to a warning: the
    /// in-memory round proceeds (availability), and recovery treats any
    /// missing tail records as an in-flight round to retry.
    fn persist(&mut self, f: impl FnOnce(&mut dyn Persistence) -> Result<()>) {
        if let Err(e) = f(self.persistence.as_mut()) {
            log::warn!("task {}: journal write failed: {e}", self.id);
        }
    }

    pub fn descriptor(&self) -> TaskDescriptor {
        TaskDescriptor {
            task_id: self.id,
            task_name: self.config.task_name.clone(),
            app_name: self.config.app_name.clone(),
            workflow_name: self.config.workflow_name.clone(),
            state: self.state,
            round: self.round,
            total_rounds: self.config.total_rounds,
        }
    }

    /// Current phase, for status surfaces ("joining" | "training" |
    /// "unmasking") — the phase itself never leaves the orchestrator.
    pub fn phase_name(&self) -> &'static str {
        match self.phase {
            Phase::Joining => "joining",
            Phase::Training { .. } => "training",
            Phase::Unmasking { .. } => "unmasking",
        }
    }

    pub fn epsilon(&self) -> Option<f64> {
        self.accountant
            .as_ref()
            .and_then(|a| a.epsilon(1e-5).ok())
            .map(|(e, _)| e)
    }

    fn train_params(&self) -> TrainParams {
        TrainParams {
            preset: self.config.preset.clone(),
            lr: self.config.client_lr,
            prox_mu: self.config.prox_mu,
        }
    }

    fn emit(&self, event: TaskEvent) {
        self.events.emit(event);
    }

    fn set_state(&mut self, state: TaskState) {
        self.state = state;
        self.emit(TaskEvent::TaskStateChanged {
            task_id: self.id,
            state,
        });
        self.persist(|p| p.state_changed(state));
    }

    // -----------------------------------------------------------------
    // Lifecycle transitions
    // -----------------------------------------------------------------

    pub fn start(&mut self) -> Result<()> {
        match self.state {
            TaskState::Created | TaskState::Paused => {
                self.set_state(TaskState::Running);
                Ok(())
            }
            s => Err(Error::Task(format!("cannot start task in state {}", s.name()))),
        }
    }

    pub fn pause(&mut self) -> Result<()> {
        if self.state == TaskState::Running {
            self.set_state(TaskState::Paused);
            Ok(())
        } else {
            Err(Error::Task(format!("cannot pause {}", self.state.name())))
        }
    }

    pub fn cancel(&mut self) {
        self.set_state(TaskState::Cancelled);
    }

    // -----------------------------------------------------------------
    // Client-facing transitions
    // -----------------------------------------------------------------

    /// A client asks to participate in the next round.
    pub fn join(
        &mut self,
        client_id: u64,
        pubkey: [u8; 32],
        now_ms: u64,
    ) -> Result<(bool, String)> {
        if self.state != TaskState::Running {
            return Ok((false, format!("task is {}", self.state.name())));
        }
        match self.config.mode {
            FlMode::Sync => {
                if self.cohort.contains(&client_id)
                    || self.join_pool.iter().any(|&(c, _)| c == client_id)
                {
                    return Ok((false, "already joined".into()));
                }
                self.join_pool.push_back((client_id, pubkey));
                if self.joining_since_ms.is_none() {
                    self.joining_since_ms = Some(now_ms);
                }
                self.emit(TaskEvent::ClientJoined {
                    task_id: self.id,
                    client_id,
                });
                Ok((true, String::new()))
            }
            FlMode::Async { .. } => {
                if self.async_joined.insert(client_id) {
                    self.emit(TaskEvent::ClientJoined {
                        task_id: self.id,
                        client_id,
                    });
                }
                Ok((true, String::new()))
            }
        }
    }

    /// A client polls for its current obligation.
    pub fn fetch(
        &mut self,
        client_id: u64,
        dir: &dyn ClientDirectory,
        now_ms: u64,
    ) -> Result<RoundRole> {
        match self.state {
            TaskState::Completed | TaskState::Cancelled | TaskState::Failed => {
                return Ok(RoundRole::TaskDone)
            }
            TaskState::Paused | TaskState::Created => return Ok(RoundRole::Wait),
            TaskState::Running => {}
        }
        if let FlMode::Async { .. } = self.config.mode {
            if !self.async_joined.contains(&client_id) {
                return Ok(RoundRole::RoundDone); // join first
            }
            // Train against the freshest model, no barrier. The blob is
            // the store's cached compressed bytes — an Arc clone per
            // poll, one zlib pass per version.
            let blob = self.global.compressed()?;
            return Ok(RoundRole::Train(RoundInstruction {
                round: self.round,
                model_blob: blob,
                train: self.train_params(),
                secagg: None,
                deadline_ms: now_ms + self.config.round_timeout_ms,
            }));
        }
        // Sync path: try to advance Joining → Training first.
        self.maybe_form_cohort(dir, now_ms)?;
        match &self.phase {
            Phase::Joining => {
                if self.join_pool.iter().any(|&(c, _)| c == client_id) {
                    Ok(RoundRole::Wait)
                } else {
                    Ok(RoundRole::RoundDone)
                }
            }
            Phase::Training {
                secagg,
                uploaded,
                deadline_ms,
                ..
            } => {
                if !self.cohort.contains(&client_id) {
                    if self.join_pool.iter().any(|&(c, _)| c == client_id) {
                        return Ok(RoundRole::Wait); // queued for next round
                    }
                    return Ok(RoundRole::NotSelected);
                }
                if uploaded.contains(&client_id) {
                    return Ok(RoundRole::Wait);
                }
                let sa = match secagg {
                    Some(s) => Some(s.setup_for(client_id)?),
                    None => None,
                };
                // The version is pinned for the phase's lifetime, so the
                // whole cohort shares one compression via the cache.
                Ok(RoundRole::Train(RoundInstruction {
                    round: self.round,
                    model_blob: self.global.compressed()?,
                    train: self.train_params(),
                    secagg: sa,
                    deadline_ms: *deadline_ms,
                }))
            }
            Phase::Unmasking { secagg, .. } => {
                if let Some(req) = secagg.unmask_request_for(client_id) {
                    Ok(RoundRole::Unmask(req))
                } else if self.cohort.contains(&client_id) {
                    Ok(RoundRole::Wait)
                } else {
                    Ok(RoundRole::NotSelected)
                }
            }
        }
    }

    /// Plaintext upload (secure_agg = false, or async).
    #[allow(clippy::too_many_arguments)]
    pub fn accept_plain(
        &mut self,
        client_id: u64,
        round: u64,
        base_version: u64,
        delta: Vec<f32>,
        weight: f64,
        loss: f64,
        eval: &dyn Evaluator,
        now_ms: u64,
    ) -> Result<(bool, String)> {
        if self.state != TaskState::Running {
            return Ok((false, format!("task is {}", self.state.name())));
        }
        if delta.len() != self.global.dim() {
            return Ok((
                false,
                format!("dim {} != {}", delta.len(), self.global.dim()),
            ));
        }
        if !(weight.is_finite() && weight > 0.0 && weight < 1e9) {
            return Ok((false, format!("bad weight {weight}")));
        }
        if !loss.is_finite() {
            return Ok((false, format!("bad loss {loss}")));
        }
        if let FlMode::Async { buffer_size } = self.config.mode {
            if !self.async_joined.contains(&client_id) {
                return Ok((false, "join first".into()));
            }
            let staleness = self.global.version.saturating_sub(base_version);
            // Fold the delta in at arrival — the buffer epoch keeps only
            // the strategy's O(dim) accumulator, never the deltas.
            if self.ingest.is_none() {
                self.ingest = Some(StreamingIngest::new(
                    self.master.begin_fold(self.global.dim())?,
                ));
            }
            let reported = {
                let ingest = self.ingest.as_mut().expect("ingest initialized above");
                let accepted = ingest.accept(
                    &delta,
                    &UpdateStats {
                        client_id,
                        weight,
                        loss,
                        staleness,
                    },
                );
                if let Err(e) = accepted {
                    return Ok((false, e.to_string()));
                }
                ingest.count()
            };
            // Counted (and journaled) only on acceptance, so the metric
            // survives crash recovery exactly.
            self.metrics.total_uploads += 1;
            let upload_round = self.round;
            self.persist(|p| p.upload_accepted(client_id, upload_round, weight, loss));
            let progress = RoundProgress {
                cohort: buffer_size,
                reported,
                now_ms,
                deadline_ms: u64::MAX,
                min_report_fraction: self.config.min_report_fraction,
            };
            if self.pacing.assess(&progress) == PacingDecision::Commit {
                self.flush_async(eval, now_ms)?;
            }
            return Ok((true, String::new()));
        }
        // Sync plaintext round.
        let progress = match &mut self.phase {
            Phase::Training {
                secagg: None,
                ingest,
                uploaded,
                base_version: bv,
                deadline_ms,
            } => {
                if round != self.round {
                    return Ok((false, format!("stale round {round} (now {})", self.round)));
                }
                if !self.cohort.contains(&client_id) {
                    return Ok((false, "not in cohort".into()));
                }
                // Validate before marking uploaded: a rejected upload
                // must leave the client free to retry.
                if base_version != *bv {
                    return Ok((false, format!("base version {base_version} != {bv}")));
                }
                if uploaded.contains(&client_id) {
                    return Ok((false, "duplicate upload".into()));
                }
                // Fold before marking uploaded: a rejected fold must
                // leave the client free to retry, and `uploaded` must
                // only ever count deltas actually folded in.
                let accepted = ingest
                    .as_mut()
                    .ok_or_else(|| Error::Task("plaintext round missing ingest fold".into()))?
                    .accept(
                        &delta,
                        &UpdateStats {
                            client_id,
                            weight,
                            loss,
                            staleness: 0,
                        },
                    );
                if let Err(e) = accepted {
                    // Robust folds refuse (zero-score) malformed or
                    // oversized deltas at ingest — count them so an
                    // attack burst is visible on the export surface.
                    if aggregation::is_robust(&self.config.aggregator) {
                        if let Some(t) = &self.telemetry {
                            t.robust_zero_scored.inc();
                        }
                    }
                    return Ok((false, e.to_string()));
                }
                uploaded.insert(client_id);
                RoundProgress {
                    cohort: self.cohort.len(),
                    reported: uploaded.len(),
                    now_ms,
                    deadline_ms: *deadline_ms,
                    min_report_fraction: self.config.min_report_fraction,
                }
            }
            Phase::Training { secagg: Some(_), .. } => {
                return Ok((false, "task requires masked uploads".into()))
            }
            _ => return Ok((false, "no round in progress".into())),
        };
        self.metrics.total_uploads += 1;
        self.persist(|p| p.upload_accepted(client_id, round, weight, loss));
        // Uploads only ever commit; deadline failure stays tick()'s job.
        if self.pacing.assess(&progress) == PacingDecision::Commit {
            self.try_commit(eval, now_ms);
        }
        Ok((true, String::new()))
    }

    // -----------------------------------------------------------------
    // Hierarchical aggregation (leaf → master ingest seam)
    // -----------------------------------------------------------------

    /// The `leaf_index`-th of `leaf_count` deterministic slices of the
    /// open plaintext round's cohort (sorted ids, round-robin by
    /// position — every leaf asking with the same `leaf_count` sees a
    /// disjoint cover of the cohort). A structured refusal is data the
    /// leaf uses to back off: no open round yet, a secagg round (whose
    /// masked sums must reach the root unmerged), or a bad index.
    pub fn leaf_slice(&self, leaf_index: u32, leaf_count: u32) -> LeafAssignment {
        let refuse = |reason: &str| LeafAssignment {
            accepted: false,
            round: 0,
            base_version: 0,
            members: Vec::new(),
            reason: reason.into(),
        };
        if self.state != TaskState::Running {
            return refuse(&format!("task is {}", self.state.name()));
        }
        if leaf_count == 0 || leaf_index >= leaf_count {
            return refuse(&format!("bad leaf index {leaf_index}/{leaf_count}"));
        }
        if let FlMode::Async { .. } = self.config.mode {
            return refuse("async tasks ingest directly at the root");
        }
        if aggregation::is_robust(&self.config.aggregator) {
            // A trimmed mean/median is not a function of per-leaf sums;
            // a leaf fold could neither export its buffer nor be
            // absorbed faithfully. Robust reduction stays at the root.
            return refuse("robust strategies reduce at the root only");
        }
        match &self.phase {
            Phase::Training {
                secagg: None,
                base_version,
                ..
            } => LeafAssignment {
                accepted: true,
                round: self.round,
                base_version: *base_version,
                members: self
                    .cohort
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % leaf_count as usize == leaf_index as usize)
                    .map(|(_, &c)| c)
                    .collect(),
                reason: String::new(),
            },
            Phase::Training { secagg: Some(_), .. } => {
                refuse("secure-aggregation rounds do not use leaves")
            }
            _ => refuse("no open plaintext round"),
        }
    }

    /// Merge a leaf's forwarded partial accumulator into the open
    /// round's streaming fold — the tree-aware twin of [`accept_plain`].
    /// All `members` are marked reported at once; a member that already
    /// uploaded directly (or arrived via another leaf) rejects the
    /// whole partial, so no update can be double-counted. Returns
    /// `(ok, folded, reason)` with `folded` the member updates credited.
    ///
    /// [`accept_plain`]: RoundEngine::accept_plain
    #[allow(clippy::too_many_arguments)]
    pub fn accept_partial(
        &mut self,
        leaf_id: u64,
        round: u64,
        base_version: u64,
        members: &[u64],
        part: &PartialFold,
        loss_sum: f64,
        eval: &dyn Evaluator,
        now_ms: u64,
    ) -> Result<(bool, u64, String)> {
        if self.state != TaskState::Running {
            return Ok((false, 0, format!("task is {}", self.state.name())));
        }
        if let FlMode::Async { .. } = self.config.mode {
            return Ok((false, 0, "async tasks ingest directly at the root".into()));
        }
        if aggregation::is_robust(&self.config.aggregator) {
            // Mirrors `leaf_slice`: even a well-formed partial would
            // bypass the trim/median, so the root refuses it outright.
            return Ok((false, 0, "robust strategies reduce at the root only".into()));
        }
        if members.is_empty() || part.count != members.len() {
            return Ok((
                false,
                0,
                format!(
                    "partial counts {} updates for {} members",
                    part.count,
                    members.len()
                ),
            ));
        }
        if !loss_sum.is_finite() {
            return Ok((false, 0, format!("bad loss sum {loss_sum}")));
        }
        let progress = match &mut self.phase {
            Phase::Training {
                secagg: None,
                ingest,
                uploaded,
                base_version: bv,
                deadline_ms,
            } => {
                if round != self.round {
                    return Ok((
                        false,
                        0,
                        format!("stale round {round} (now {})", self.round),
                    ));
                }
                if base_version != *bv {
                    return Ok((false, 0, format!("base version {base_version} != {bv}")));
                }
                // Validate the whole member slice before the fold: a
                // rejected partial must leave nothing half-credited.
                for m in members {
                    if !self.cohort.contains(m) {
                        return Ok((false, 0, format!("member {m} not in cohort")));
                    }
                    if uploaded.contains(m) {
                        return Ok((false, 0, format!("member {m} already reported")));
                    }
                }
                // Absorb before marking members reported — an absorb
                // error (dim mismatch, bad weights) leaves the round
                // exactly as it was and the leaf free to retry.
                let absorbed = ingest
                    .as_mut()
                    .ok_or_else(|| Error::Task("plaintext round missing ingest fold".into()))?
                    .absorb(part, loss_sum);
                if let Err(e) = absorbed {
                    return Ok((false, 0, e.to_string()));
                }
                uploaded.extend(members.iter().copied());
                RoundProgress {
                    cohort: self.cohort.len(),
                    reported: uploaded.len(),
                    now_ms,
                    deadline_ms: *deadline_ms,
                    min_report_fraction: self.config.min_report_fraction,
                }
            }
            Phase::Training { secagg: Some(_), .. } => {
                return Ok((
                    false,
                    0,
                    "secure-aggregation rounds do not accept partials".into(),
                ))
            }
            _ => return Ok((false, 0, "no round in progress".into())),
        };
        self.metrics.total_uploads += members.len() as u64;
        if let Some(t) = &self.telemetry {
            t.partials_absorbed.inc();
        }
        // Journal per member so recovery's upload accounting matches the
        // flat path; per-member weight/loss ride as the partial's means
        // (the journal is bookkeeping — folds are not replayed from it).
        let mean_weight = part.total_weight / part.count as f64;
        let mean_loss = loss_sum / part.count as f64;
        for &m in members {
            self.persist(|p| p.upload_accepted(m, round, mean_weight, mean_loss));
        }
        log::debug!(
            "task {}: round {round} leaf {leaf_id} merged {} member update(s)",
            self.id,
            members.len()
        );
        // Partials only ever commit; deadline failure stays tick()'s job.
        if self.pacing.assess(&progress) == PacingDecision::Commit {
            self.try_commit(eval, now_ms);
        }
        Ok((true, members.len() as u64, String::new()))
    }

    /// Masked upload (secure aggregation path).
    pub fn accept_masked(
        &mut self,
        client_id: u64,
        round: u64,
        vg_id: u32,
        masked: &[u32],
        loss: f64,
        eval: &dyn Evaluator,
        now_ms: u64,
    ) -> Result<(bool, String)> {
        if self.state != TaskState::Running {
            return Ok((false, format!("task is {}", self.state.name())));
        }
        if round != self.round {
            return Ok((false, format!("stale round {round}")));
        }
        if !loss.is_finite() {
            return Ok((false, format!("bad loss {loss}")));
        }
        let progress = match &mut self.phase {
            Phase::Training {
                secagg: Some(sa),
                uploaded,
                deadline_ms,
                ..
            } => {
                if let Err(e) = sa.accept_masked(client_id, vg_id, masked, loss) {
                    return Ok((false, e.to_string()));
                }
                uploaded.insert(client_id);
                RoundProgress {
                    cohort: self.cohort.len(),
                    reported: uploaded.len(),
                    now_ms,
                    deadline_ms: *deadline_ms,
                    min_report_fraction: self.config.min_report_fraction,
                }
            }
            _ => return Ok((false, "no masked round in progress".into())),
        };
        self.metrics.total_uploads += 1;
        // Masked uploads carry no plaintext weight; journal unit weight.
        self.persist(|p| p.upload_accepted(client_id, round, 1.0, loss));
        // Uploads only ever commit; deadline failure stays tick()'s job.
        if self.pacing.assess(&progress) == PacingDecision::Commit {
            self.try_commit(eval, now_ms);
        }
        Ok((true, String::new()))
    }

    /// Encrypted Shamir shares for the current secagg round.
    pub fn accept_shares(
        &mut self,
        client_id: u64,
        round: u64,
        shares: Vec<PeerShare>,
    ) -> Result<(bool, String)> {
        if round != self.round {
            return Ok((false, format!("stale round {round}")));
        }
        match &mut self.phase {
            Phase::Training {
                secagg: Some(sa), ..
            } => match sa.accept_shares(client_id, shares) {
                Ok(()) => Ok((true, String::new())),
                Err(e) => Ok((false, e.to_string())),
            },
            _ => Ok((false, "no secagg round in progress".into())),
        }
    }

    /// Plaintext shares recovered by survivors (unmask phase).
    pub fn accept_unmask(
        &mut self,
        client_id: u64,
        round: u64,
        shares: Vec<RecoveredShare>,
        eval: &dyn Evaluator,
        now_ms: u64,
    ) -> Result<(bool, String)> {
        if round != self.round {
            return Ok((false, format!("stale round {round}")));
        }
        let complete = match &mut self.phase {
            Phase::Unmasking { secagg, .. } => {
                if let Err(e) = secagg.accept_recovered(client_id, shares) {
                    return Ok((false, e.to_string()));
                }
                !secagg.needs_unmasking()
            }
            _ => return Ok((false, "no unmask phase in progress".into())),
        };
        if complete {
            self.try_commit(eval, now_ms);
        }
        Ok((true, String::new()))
    }

    /// Remove clients whose liveness lease expired (session sweep) and
    /// repair the open cohort instead of waiting out the deadline.
    ///
    /// Evicted clients leave the waiting pools in every mode. In a
    /// plaintext sync round, a cohort member that has not uploaded is
    /// dropped from the cohort and its slot backfilled from the join
    /// pool (the over-provisioned extras, when the task runs the
    /// `OverProvision` policy); if that leaves the shrunken cohort fully
    /// reported, the round commits immediately. Secure-aggregation
    /// rounds are left alone: an evicted member is an ordinary dropout
    /// there, and the unmask path already recovers its pairwise masks.
    pub fn evict_clients(&mut self, evicted: &[u64], eval: &dyn Evaluator, now_ms: u64) {
        if evicted.is_empty() || self.state != TaskState::Running {
            return;
        }
        self.join_pool.retain(|&(c, _)| !evicted.contains(&c));
        for c in evicted {
            self.async_joined.remove(c);
        }
        let mut removed: Vec<u64> = Vec::new();
        let mut drafted: Vec<u64> = Vec::new();
        let progress = match &mut self.phase {
            Phase::Training {
                secagg: None,
                uploaded,
                deadline_ms,
                ..
            } => {
                for &c in evicted {
                    // An already-folded upload stays counted; only
                    // members the round is still waiting on are replaced.
                    if !uploaded.contains(&c) && self.cohort.remove(&c) {
                        removed.push(c);
                        if let Some((draftee, _pk)) = self.join_pool.pop_front() {
                            self.cohort.insert(draftee);
                            drafted.push(draftee);
                        }
                    }
                }
                if removed.is_empty() {
                    None
                } else {
                    Some(RoundProgress {
                        cohort: self.cohort.len(),
                        reported: uploaded.len(),
                        now_ms,
                        deadline_ms: *deadline_ms,
                        min_report_fraction: self.config.min_report_fraction,
                    })
                }
            }
            _ => None,
        };
        if removed.is_empty() && drafted.is_empty() {
            return;
        }
        if let Some(t) = &self.telemetry {
            t.evictions.add(removed.len() as u64);
            t.backfills.add(drafted.len() as u64);
        }
        let round = self.round;
        log::info!(
            "task {}: round {round} evicted {} expired client(s), backfilled {}",
            self.id,
            removed.len(),
            drafted.len()
        );
        for &c in &removed {
            self.emit(TaskEvent::ClientEvicted {
                task_id: self.id,
                client_id: c,
                round,
            });
        }
        for &c in &drafted {
            self.emit(TaskEvent::CohortBackfilled {
                task_id: self.id,
                client_id: c,
                round,
            });
        }
        // The shrunken cohort may already be fully reported.
        if let Some(p) = progress {
            if p.cohort > 0 && self.pacing.assess(&p) == PacingDecision::Commit {
                self.try_commit(eval, now_ms);
            }
        }
    }

    /// Deadline sweep: advance degraded cohorts and consult the pacing
    /// policy once the open round's deadline has passed.
    pub fn tick(&mut self, eval: &dyn Evaluator, dir: &dyn ClientDirectory, now_ms: u64) {
        if self.state != TaskState::Running {
            return;
        }
        if matches!(self.phase, Phase::Joining) {
            // Degraded cohort formation after the join grace (min_clients).
            if let Err(e) = self.maybe_form_cohort(dir, now_ms) {
                log::warn!("task {}: cohort formation failed: {e}", self.id);
            }
            return;
        }
        let (deadline_ms, reported) = match &self.phase {
            Phase::Training {
                secagg,
                uploaded,
                deadline_ms,
                ..
            } => (
                *deadline_ms,
                match secagg {
                    Some(sa) => sa.uploaded_count(),
                    None => uploaded.len(),
                },
            ),
            // Unmasking only begins once upload quorum was met; the
            // deadline decision reuses that quorum.
            Phase::Unmasking { deadline_ms, .. } => (*deadline_ms, self.cohort.len()),
            Phase::Joining => unreachable!("handled above"),
        };
        if now_ms < deadline_ms {
            return;
        }
        let progress = RoundProgress {
            cohort: self.cohort.len(),
            reported,
            now_ms,
            deadline_ms,
            min_report_fraction: self.config.min_report_fraction,
        };
        match self.pacing.assess(&progress) {
            PacingDecision::Wait => {}
            PacingDecision::Commit => self.try_commit(eval, now_ms),
            PacingDecision::Fail => {
                let quorum = progress.quorum();
                log::warn!(
                    "task {}: round {} missed quorum ({reported}/{quorum}) — retrying",
                    self.id,
                    self.round
                );
                self.emit(TaskEvent::QuorumMissed {
                    task_id: self.id,
                    round: self.round,
                    reported,
                    quorum,
                });
                self.fail_round();
            }
        }
    }

    // -----------------------------------------------------------------
    // Internal transitions (Joining → Training → Unmasking → Committed/Failed)
    // -----------------------------------------------------------------

    /// Joining → Training, when the cohort policy says the pool is ready.
    fn maybe_form_cohort(&mut self, dir: &dyn ClientDirectory, now_ms: u64) -> Result<()> {
        if !matches!(self.phase, Phase::Joining) || self.state != TaskState::Running {
            return Ok(());
        }
        if self.joining_since_ms.is_none() && !self.join_pool.is_empty() {
            self.joining_since_ms = Some(now_ms);
        }
        let pool: Vec<u64> = self.join_pool.iter().map(|&(c, _)| c).collect();
        let target = self.config.clients_per_round;
        let min_clients = if self.config.min_clients == 0 {
            target
        } else {
            self.config.min_clients.min(target)
        };
        let waited_ms = self
            .joining_since_ms
            .map(|t0| now_ms.saturating_sub(t0))
            .unwrap_or(0);
        let ctx = CohortContext {
            pool: &pool,
            target,
            min_clients,
            waited_ms,
            grace_ms: self.config.round_timeout_ms,
            directory: dir,
        };
        let cohort_ids = match self.cohort_policy.form(&ctx, &mut self.rng) {
            Some(ids) => ids,
            None => return Ok(()),
        };
        let cohort_set: BTreeSet<u64> = cohort_ids.iter().copied().collect();
        let mut keys: HashMap<u64, [u8; 32]> = HashMap::new();
        self.join_pool.retain(|&(c, pk)| {
            if cohort_set.contains(&c) {
                keys.insert(c, pk);
                false
            } else {
                true
            }
        });
        let secagg = if self.config.secure_agg {
            let groups_ids =
                SelectionService::form_virtual_groups(&cohort_ids, self.config.vg_size);
            let groups: Vec<Vec<(u64, [u8; 32])>> = groups_ids
                .iter()
                .map(|g| g.iter().map(|c| (*c, keys[c])).collect())
                .collect();
            let quant = Quantizer::new(self.config.quant_range, self.config.quant_bits)?;
            Some(SecAggRound::new(
                self.id,
                self.round,
                groups,
                quant,
                self.global.dim(),
                0.6,
            ))
        } else {
            None
        };
        // Plaintext rounds open their streaming ingest fold up front;
        // masked rounds accumulate inside `SecAggRound` instead.
        let ingest = if secagg.is_none() {
            Some(StreamingIngest::new(
                self.master.begin_fold(self.global.dim())?,
            ))
        } else {
            None
        };
        let cohort_size = cohort_set.len();
        self.cohort = cohort_set;
        // Close the joining span: the root span starts when the first
        // joiner began waiting (== now when nobody waited), so phase
        // durations sum exactly to the round's total by construction.
        self.trace_started_ms = self.joining_since_ms.unwrap_or(now_ms);
        self.trace_joining_ms = now_ms.saturating_sub(self.trace_started_ms);
        self.trace_unmasking_since_ms = None;
        if let Some(t) = &self.telemetry {
            t.round_phase_joining_ms.record(self.trace_joining_ms);
            t.cohort_fill.record(cohort_size as u64);
        }
        self.joining_since_ms = None;
        self.round_started_ms = now_ms;
        let deadline_ms = self
            .pacing
            .deadline_ms(now_ms, self.config.round_timeout_ms);
        self.phase = Phase::Training {
            secagg,
            ingest,
            uploaded: BTreeSet::new(),
            base_version: self.global.version,
            deadline_ms,
        };
        log::info!(
            "task {}: round {} cohort formed ({} clients, {} policy{})",
            self.id,
            self.round,
            cohort_size,
            self.cohort_policy.name(),
            if self.config.secure_agg { ", secagg" } else { "" }
        );
        self.emit(TaskEvent::RoundStarted {
            task_id: self.id,
            round: self.round,
            cohort: cohort_size,
        });
        let round = self.round;
        self.persist(|p| p.round_started(round, cohort_size));
        Ok(())
    }

    /// Commit with failure containment: a commit error fails the round
    /// (joiners stay queued, round retries) instead of leaving a
    /// half-torn phase behind. Shared by the upload paths and `tick()`.
    fn try_commit(&mut self, eval: &dyn Evaluator, now_ms: u64) {
        if let Err(e) = self.commit_round(eval, now_ms) {
            log::warn!("task {}: round finish failed: {e}", self.id);
            self.fail_round();
        }
    }

    /// Training/Unmasking → Committed: aggregate (possibly via the unmask
    /// detour), update the model, record metrics, advance or finish.
    fn commit_round(&mut self, eval: &dyn Evaluator, now_ms: u64) -> Result<()> {
        // Take the phase out to appease the borrow checker.
        let phase = std::mem::replace(&mut self.phase, Phase::Joining);
        match phase {
            Phase::Training {
                secagg: Some(mut sa),
                uploaded,
                deadline_ms,
                ..
            } => {
                if sa.needs_unmasking() {
                    log::info!(
                        "task {}: round {} has dropouts — entering unmask phase",
                        self.id,
                        self.round
                    );
                    let _ = uploaded;
                    self.enter_unmasking(sa, deadline_ms + self.config.round_timeout_ms, now_ms);
                    return Ok(());
                }
                let interims = sa.finalize()?;
                if interims.is_empty() {
                    return Err(Error::SecAgg("no usable VG interims".into()));
                }
                let participants =
                    self.master
                        .apply_interims(&mut self.global, &interims, &mut self.rng)?;
                let loss = interims.iter().map(|i| i.mean_loss).sum::<f64>()
                    / interims.len() as f64;
                self.record_round(eval, participants, loss, now_ms);
            }
            Phase::Training {
                secagg: None,
                ingest,
                ..
            } => {
                let ingest = match ingest {
                    Some(i) if i.count() > 0 => i,
                    _ => return Err(Error::Task("no uploads to aggregate".into())),
                };
                let loss = ingest.mean_loss();
                let participants =
                    self.master
                        .commit_fold(&mut self.global, ingest.fold, &mut self.rng)?;
                self.record_round(eval, participants, loss, now_ms);
            }
            Phase::Unmasking { mut secagg, .. } => {
                let interims = secagg.finalize()?;
                if interims.is_empty() {
                    return Err(Error::SecAgg("all VGs poisoned".into()));
                }
                let participants =
                    self.master
                        .apply_interims(&mut self.global, &interims, &mut self.rng)?;
                let loss = interims.iter().map(|i| i.mean_loss).sum::<f64>()
                    / interims.len() as f64;
                self.record_round(eval, participants, loss, now_ms);
            }
            Phase::Joining => return Err(Error::Task("commit_round in Joining".into())),
        }
        Ok(())
    }

    /// Training → Unmasking (secagg dropouts need share recovery).
    fn enter_unmasking(&mut self, secagg: SecAggRound, deadline_ms: u64, now_ms: u64) {
        self.trace_unmasking_since_ms = Some(now_ms);
        self.phase = Phase::Unmasking { secagg, deadline_ms };
    }

    fn record_round(
        &mut self,
        eval: &dyn Evaluator,
        participants: usize,
        train_loss: f64,
        now_ms: u64,
    ) {
        let committed_round = self.round;
        // Close the training (and optional unmasking) spans and publish
        // the round's root span. Commit work is synchronous at `now_ms`,
        // so its span is zero-width under the manual clock by design.
        let training_end_ms = self.trace_unmasking_since_ms.unwrap_or(now_ms);
        let training_ms = training_end_ms.saturating_sub(self.round_started_ms);
        let unmasking_ms = self
            .trace_unmasking_since_ms
            .map(|t0| now_ms.saturating_sub(t0))
            .unwrap_or(0);
        if let Some(t) = &self.telemetry {
            t.round_phase_training_ms.record(training_ms);
            if self.trace_unmasking_since_ms.is_some() {
                t.round_phase_unmasking_ms.record(unmasking_ms);
            }
            t.round_phase_commit_ms.record(0);
            t.rounds_committed.inc();
            t.rounds.push(RoundTrace {
                task_id: self.id,
                round: committed_round,
                trace_id: trace_id_for(self.id, committed_round),
                started_ms: self.trace_started_ms,
                ended_ms: now_ms,
                joining_ms: self.trace_joining_ms,
                training_ms,
                unmasking_ms,
                commit_ms: 0,
                participants: participants as u32,
                committed: true,
            });
        }
        self.trace_unmasking_since_ms = None;
        if let Some(acc) = &mut self.accountant {
            let q = (participants as f64 / self.config.dp_population as f64).min(1.0);
            let _ = acc.step(q, self.config.dp.noise_multiplier);
        }
        let evald = eval.evaluate(&self.config.preset, &self.global.params);
        let epsilon = self.epsilon();
        self.metrics.push(RoundRecord {
            round: self.round,
            started_ms: self.round_started_ms,
            ended_ms: now_ms,
            participants,
            train_loss,
            eval_loss: evald.map(|(l, _)| l),
            eval_accuracy: evald.map(|(_, a)| a),
            epsilon,
        });
        self.emit(TaskEvent::RoundCommitted {
            task_id: self.id,
            round: self.round,
            participants,
            train_loss,
        });
        self.cohort.clear();
        self.round += 1;
        if self.round >= self.config.total_rounds {
            self.set_state(TaskState::Completed);
            self.emit(TaskEvent::TaskCompleted { task_id: self.id });
            log::info!("task {}: completed after {} rounds", self.id, self.round);
        }
        // Durability point: journal the commit, checkpoint the new
        // model version atomically, truncate the absorbed journal tail.
        let view = CheckpointView {
            task_id: self.id,
            config: &self.config,
            state: self.state,
            round: self.round,
            store: &self.global,
            metrics: &self.metrics,
        };
        if let Err(e) = self.persistence.round_committed(committed_round, &view) {
            log::error!(
                "task {}: checkpoint failed — round {committed_round} is not durable: {e}",
                self.id
            );
        }
    }

    /// Training/Unmasking → Failed → Joining: abandon the round; joiners
    /// stay queued, stragglers may rejoin.
    fn fail_round(&mut self) {
        self.metrics.failed_rounds += 1;
        if let Some(t) = &self.telemetry {
            t.rounds_failed.inc();
        }
        self.trace_unmasking_since_ms = None;
        self.cohort.clear();
        self.phase = Phase::Joining;
        self.emit(TaskEvent::RoundFailed {
            task_id: self.id,
            round: self.round,
        });
        let round = self.round;
        self.persist(|p| p.round_failed(round));
    }

    /// Async path: commit the buffer epoch's fold into the model.
    fn flush_async(&mut self, eval: &dyn Evaluator, now_ms: u64) -> Result<()> {
        let ingest = self
            .ingest
            .take()
            .ok_or_else(|| Error::Task("no buffered uploads to flush".into()))?;
        let loss = ingest.mean_loss();
        let participants =
            self.master.commit_fold(&mut self.global, ingest.fold, &mut self.rng)?;
        self.round_started_ms = self.last_flush_ms;
        // Async flushes have no joining barrier: the root span covers
        // the buffer epoch, all of it accounted to training.
        self.trace_started_ms = self.last_flush_ms;
        self.trace_joining_ms = 0;
        self.trace_unmasking_since_ms = None;
        self.last_flush_ms = now_ms;
        self.record_round(eval, participants, loss, now_ms);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::policy::{GoalCount, NullDirectory, UniformRandom};

    fn engine(cfg: TaskConfig, dim: usize) -> (RoundEngine, EventBus) {
        let bus = EventBus::new();
        let mut e = RoundEngine::new(1, cfg, ModelSnapshot::new(0, vec![0.0; dim]), 7, bus.clone())
            .unwrap();
        e.start().unwrap();
        (e, bus)
    }

    fn small_cfg(n: usize, rounds: u64) -> TaskConfig {
        let mut c = TaskConfig::default();
        c.clients_per_round = n;
        c.total_rounds = rounds;
        c.round_timeout_ms = 1000;
        c
    }

    /// Join + fetch + upload for `uploaders` of `joiners` clients.
    fn drive_round(e: &mut RoundEngine, joiners: u64, uploaders: u64, now: u64) {
        for c in 1..=joiners {
            e.join(c, [0u8; 32], now).unwrap();
        }
        let dir = NullDirectory;
        for c in 1..=joiners {
            let _ = e.fetch(c, &dir, now).unwrap();
        }
        let round = e.round;
        let version = e.global.version;
        let dim = e.global.dim();
        for c in 1..=uploaders {
            let (ok, why) = e
                .accept_plain(c, round, version, vec![0.1; dim], 1.0, 0.5, &NoEval, now + 10)
                .unwrap();
            assert!(ok, "{why}");
        }
    }

    #[test]
    fn full_round_commits_and_advances_model() {
        let (mut e, bus) = engine(small_cfg(3, 2), 4);
        let stream = bus.subscribe();
        drive_round(&mut e, 3, 3, 0);
        assert_eq!(e.round, 1);
        assert_eq!(e.metrics.rounds.len(), 1);
        assert!((e.global.params[0] - 0.1).abs() < 1e-6);
        let kinds: Vec<&'static str> = stream.drain().iter().map(|ev| ev.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                "client_joined",
                "client_joined",
                "client_joined",
                "round_started",
                "round_committed",
            ]
        );
    }

    #[test]
    fn robust_round_commits_with_bounded_attacker() {
        // 4 honest clients push +0.1; one magnitude-bomber uploads 1e6.
        // Under trimmed_mean the bomb is trimmed and the model steps to
        // the honest value; under fedavg it would explode to ~2e5.
        let mut cfg = small_cfg(5, 1);
        cfg.aggregator = "trimmed_mean".into();
        cfg.trim_fraction = 0.25;
        let (mut e, _bus) = engine(cfg, 4);
        for c in 1..=5u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        for c in 1..=5u64 {
            let _ = e.fetch(c, &NullDirectory, 0).unwrap();
        }
        for c in 1..=4u64 {
            let (ok, why) = e
                .accept_plain(c, 0, 0, vec![0.1; 4], 1.0, 0.5, &NoEval, 10)
                .unwrap();
            assert!(ok, "{why}");
        }
        let (ok, why) = e
            .accept_plain(5, 0, 0, vec![1e6; 4], 1.0, 0.5, &NoEval, 10)
            .unwrap();
        assert!(ok, "{why}");
        assert_eq!(e.state, TaskState::Completed);
        assert!(
            (e.global.params[0] - 0.1).abs() < 1e-3,
            "robust commit leaked the bomb: {}",
            e.global.params[0]
        );
    }

    #[test]
    fn robust_round_zero_scores_nonfinite_upload() {
        let mut cfg = small_cfg(2, 1);
        cfg.aggregator = "median".into();
        let (mut e, _bus) = engine(cfg, 2);
        for c in 1..=2u64 {
            e.join(c, [0u8; 32], 0).unwrap();
            let _ = e.fetch(c, &NullDirectory, 0).unwrap();
        }
        let (ok, why) = e
            .accept_plain(1, 0, 0, vec![f32::NAN, 1.0], 1.0, 0.5, &NoEval, 5)
            .unwrap();
        assert!(!ok);
        assert!(why.contains("non-finite"), "{why}");
        // The rejected client is free to retry with a sane delta.
        let (ok, why) = e
            .accept_plain(1, 0, 0, vec![0.5, 0.5], 1.0, 0.5, &NoEval, 6)
            .unwrap();
        assert!(ok, "{why}");
    }

    #[test]
    fn robust_task_refuses_leaf_path() {
        let mut cfg = small_cfg(4, 1);
        cfg.aggregator = "trimmed_mean".into();
        let (mut e, _bus) = engine(cfg, 2);
        for c in 1..=4u64 {
            e.join(c, [0u8; 32], 0).unwrap();
            let _ = e.fetch(c, &NullDirectory, 0).unwrap();
        }
        assert_eq!(e.phase_name(), "training");
        let a = e.leaf_slice(0, 2);
        assert!(!a.accepted);
        assert!(a.reason.contains("root only"), "{}", a.reason);
        let part = PartialFold {
            sum: vec![1.0; 2],
            total_weight: 2.0,
            count: 2,
            min_loss: f64::INFINITY,
        };
        let (ok, folded, reason) = e
            .accept_partial(77, 0, 0, &[1, 2], &part, 0.4, &NoEval, 10)
            .unwrap();
        assert!(!ok);
        assert_eq!(folded, 0);
        assert!(reason.contains("root only"), "{reason}");
    }

    #[test]
    fn tick_deadline_with_quorum_commits_partial_round() {
        let mut cfg = small_cfg(4, 1);
        cfg.min_report_fraction = 0.5;
        let (mut e, bus) = engine(cfg, 4);
        let stream = bus.subscribe();
        drive_round(&mut e, 4, 3, 0); // only 3 of 4 upload
        assert_eq!(e.round, 0, "round must still be open");
        e.tick(&NoEval, &NullDirectory, 2000); // past deadline (1000)
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 3);
        assert_eq!(e.metrics.failed_rounds, 0);
        assert!(stream
            .drain()
            .iter()
            .any(|ev| ev.kind() == "task_completed"));
    }

    #[test]
    fn tick_deadline_without_quorum_fails_and_retries() {
        let mut cfg = small_cfg(4, 1);
        cfg.min_report_fraction = 0.9; // quorum 4
        let (mut e, bus) = engine(cfg, 4);
        let stream = bus.subscribe();
        drive_round(&mut e, 4, 1, 0);
        e.tick(&NoEval, &NullDirectory, 5000);
        assert_eq!(e.round, 0);
        assert_eq!(e.metrics.failed_rounds, 1);
        assert_eq!(e.state, TaskState::Running);
        assert_eq!(e.phase_name(), "joining");
        let events = stream.drain();
        let quorum_missed = events
            .iter()
            .find(|ev| ev.kind() == "quorum_missed")
            .expect("quorum_missed event");
        match quorum_missed {
            TaskEvent::QuorumMissed {
                reported, quorum, ..
            } => {
                assert_eq!(*reported, 1);
                assert_eq!(*quorum, 4);
            }
            other => panic!("{other:?}"),
        }
        assert!(events.iter().any(|ev| ev.kind() == "round_failed"));
    }

    #[test]
    fn tick_unmask_deadline_without_shares_fails_round() {
        // SecAgg round where one member never uploads and nobody ever
        // deposited Shamir shares: the Training deadline enters the
        // unmask phase, and the *Unmasking* deadline must fail the round
        // (all VGs poisoned) instead of hanging on "quorum known met".
        let mut cfg = small_cfg(4, 1);
        cfg.secure_agg = true;
        cfg.vg_size = 4;
        cfg.min_report_fraction = 0.5;
        let (mut e, bus) = engine(cfg, 4);
        let stream = bus.subscribe();
        let dir = NullDirectory;
        for c in 1..=4u64 {
            e.join(c, [c as u8; 32], 0).unwrap();
        }
        for c in 1..=4u64 {
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        assert_eq!(e.phase_name(), "training");
        for c in 1..=3u64 {
            let (ok, why) = e
                .accept_masked(c, 0, 0, &[7u32; 4], 0.2, &NoEval, 10)
                .unwrap();
            assert!(ok, "{why}");
        }
        // Training deadline: quorum met (3/4 ≥ 0.5) but client 4 dropped
        // → unmask phase with a fresh deadline.
        e.tick(&NoEval, &NullDirectory, 1500);
        assert_eq!(e.phase_name(), "unmasking");
        assert_eq!(e.state, TaskState::Running);
        // Unmask deadline passes with no recovered shares → VG poisoned
        // → round fails and retries; the task does not hang or complete.
        e.tick(&NoEval, &NullDirectory, 3000);
        assert_eq!(e.phase_name(), "joining");
        assert_eq!(e.round, 0);
        assert_eq!(e.metrics.failed_rounds, 1);
        assert_eq!(e.state, TaskState::Running);
        assert!(stream.drain().iter().any(|ev| ev.kind() == "round_failed"));
    }

    #[test]
    fn min_clients_floor_forms_degraded_cohort_after_grace() {
        let mut cfg = small_cfg(4, 1);
        cfg.min_clients = 2;
        let (mut e, _bus) = engine(cfg, 4);
        let dir = NullDirectory;
        // Only 2 of the 4 requested clients ever join.
        e.join(1, [0u8; 32], 0).unwrap();
        e.join(2, [0u8; 32], 0).unwrap();
        // Inside the join grace: still waiting.
        e.tick(&NoEval, &dir, 500);
        assert_eq!(e.phase_name(), "joining");
        // Grace (round_timeout_ms = 1000) elapsed: degraded cohort of 2.
        e.tick(&NoEval, &dir, 1100);
        assert_eq!(e.phase_name(), "training");
        let round = e.round;
        for c in 1..=2u64 {
            let (ok, why) = e
                .accept_plain(c, round, 0, vec![0.5; 4], 1.0, 0.1, &NoEval, 1200)
                .unwrap();
            assert!(ok, "{why}");
        }
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 2);
    }

    #[test]
    fn over_provision_policy_drafts_extra_clients() {
        let mut cfg = small_cfg(4, 1);
        cfg.cohort = crate::config::CohortSpec::OverProvision { spawn_factor: 1.5 };
        cfg.min_report_fraction = 0.5;
        let (mut e, _bus) = engine(cfg, 4);
        let dir = NullDirectory;
        for c in 1..=6u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        let mut training = 0;
        for c in 1..=6u64 {
            if matches!(e.fetch(c, &dir, 0).unwrap(), RoundRole::Train(_)) {
                training += 1;
            }
        }
        // ceil(4 × 1.5) = 6 drafted: dropouts no longer stall the round.
        assert_eq!(training, 6);
        // 4 of 6 report; deadline commits with the survivors.
        let round = e.round;
        for c in 1..=4u64 {
            e.accept_plain(c, round, 0, vec![1.0; 4], 1.0, 0.1, &NoEval, 10)
                .unwrap();
        }
        e.tick(&NoEval, &dir, 2000);
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 4);
    }

    #[test]
    fn eviction_mid_round_backfills_from_the_pool() {
        let (mut e, bus) = engine(small_cfg(2, 1), 2);
        let stream = bus.subscribe();
        let dir = NullDirectory;
        for c in 1..=3u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        // Cohort of 2 forms; the third joiner stays queued.
        let mut cohort = Vec::new();
        let mut queued = 0u64;
        for c in 1..=3u64 {
            match e.fetch(c, &dir, 0).unwrap() {
                RoundRole::Train(_) => cohort.push(c),
                RoundRole::Wait => queued = c,
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(cohort.len(), 2);
        assert_ne!(queued, 0);
        // One cohort member's lease expires before it uploads: its slot
        // is backfilled by the queued joiner, not waited out.
        let (evicted, survivor) = (cohort[0], cohort[1]);
        e.evict_clients(&[evicted], &NoEval, 100);
        assert!(matches!(
            e.fetch(queued, &dir, 100).unwrap(),
            RoundRole::Train(_)
        ));
        assert!(matches!(
            e.fetch(evicted, &dir, 100).unwrap(),
            RoundRole::NotSelected
        ));
        // The evicted client's upload is now refused.
        let (ok, why) = e
            .accept_plain(evicted, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 110)
            .unwrap();
        assert!(!ok);
        assert!(why.contains("not in cohort"), "{why}");
        for c in [survivor, queued] {
            let (ok, why) = e
                .accept_plain(c, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 120)
                .unwrap();
            assert!(ok, "{why}");
        }
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 2);
        let kinds: Vec<&'static str> = stream.drain().iter().map(|ev| ev.kind()).collect();
        assert!(kinds.contains(&"client_evicted"));
        assert!(kinds.contains(&"cohort_backfilled"));
    }

    #[test]
    fn eviction_with_empty_pool_commits_fully_reported_shrunken_cohort() {
        let (mut e, _bus) = engine(small_cfg(2, 1), 2);
        let dir = NullDirectory;
        for c in 1..=2u64 {
            e.join(c, [0u8; 32], 0).unwrap();
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        let (ok, why) = e
            .accept_plain(1, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 10)
            .unwrap();
        assert!(ok, "{why}");
        // Client 2 goes dark; no replacement available. The shrunken
        // cohort is fully reported → the round commits right away
        // instead of waiting for the deadline.
        e.evict_clients(&[2], &NoEval, 100);
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 1);
    }

    #[test]
    fn eviction_of_uploaded_member_keeps_its_contribution() {
        let (mut e, _bus) = engine(small_cfg(2, 1), 2);
        let dir = NullDirectory;
        for c in 1..=2u64 {
            e.join(c, [0u8; 32], 0).unwrap();
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        let (ok, _) = e
            .accept_plain(1, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 10)
            .unwrap();
        assert!(ok);
        // Client 1 already uploaded: evicting it must not strand the
        // round (cohort unchanged, fold kept) — client 2 finishes.
        e.evict_clients(&[1], &NoEval, 50);
        assert_eq!(e.phase_name(), "training");
        let (ok, _) = e
            .accept_plain(2, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 60)
            .unwrap();
        assert!(ok);
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 2);
    }

    #[test]
    fn eviction_leaves_secagg_rounds_to_the_unmask_path() {
        let mut cfg = small_cfg(2, 1);
        cfg.secure_agg = true;
        cfg.vg_size = 2;
        let (mut e, bus) = engine(cfg, 2);
        let stream = bus.subscribe();
        let dir = NullDirectory;
        for c in 1..=2u64 {
            e.join(c, [c as u8; 32], 0).unwrap();
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        assert_eq!(e.phase_name(), "training");
        // A masked member's masks are already in its peers' sums —
        // eviction must not tear the cohort; dropout recovery owns it.
        e.evict_clients(&[1], &NoEval, 100);
        assert_eq!(e.phase_name(), "training");
        assert!(matches!(e.fetch(1, &dir, 100).unwrap(), RoundRole::Train(_)));
        assert!(!stream.drain().iter().any(|ev| ev.kind() == "client_evicted"));
    }

    #[test]
    fn async_goal_count_flushes_buffer() {
        let mut cfg = small_cfg(4, 2);
        cfg.mode = FlMode::Async { buffer_size: 3 };
        cfg.aggregator = "fedbuff".into();
        let (mut e, bus) = engine(cfg, 4);
        let stream = bus.subscribe();
        let dir = NullDirectory;
        for c in 1..=4u64 {
            e.join(c, [0u8; 32], 0).unwrap();
            assert!(matches!(e.fetch(c, &dir, 0).unwrap(), RoundRole::Train(_)));
        }
        for c in 1..=3u64 {
            let (ok, _) = e
                .accept_plain(c, 0, 0, vec![0.3; 4], 1.0, 0.5, &NoEval, 100)
                .unwrap();
            assert!(ok);
        }
        assert_eq!(e.round, 1); // flush #1 at the goal count
        for c in 1..=3u64 {
            e.accept_plain(c, 1, 0, vec![0.3; 4], 1.0, 0.4, &NoEval, 200)
                .unwrap();
        }
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(
            stream
                .drain()
                .iter()
                .filter(|ev| ev.kind() == "round_committed")
                .count(),
            2
        );
    }

    #[test]
    fn version_mismatch_upload_can_be_retried() {
        let (mut e, _bus) = engine(small_cfg(2, 1), 2);
        let dir = NullDirectory;
        for c in 1..=2u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        for c in 1..=2u64 {
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        // Wrong base version is rejected without consuming the client's
        // upload slot…
        let (ok, why) = e
            .accept_plain(1, 0, 99, vec![0.1; 2], 1.0, 0.1, &NoEval, 5)
            .unwrap();
        assert!(!ok);
        assert!(why.contains("base version"), "{why}");
        // …so a corrected retry succeeds and the round still commits
        // with both participants.
        let (ok, why) = e
            .accept_plain(1, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 6)
            .unwrap();
        assert!(ok, "{why}");
        let (ok, _) = e
            .accept_plain(2, 0, 0, vec![0.1; 2], 1.0, 0.1, &NoEval, 7)
            .unwrap();
        assert!(ok);
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 2);
    }

    #[test]
    fn custom_goal_pacing_commits_early_on_sync_uploads() {
        // The pacing seam is honored on the upload path, not just tick():
        // a GoalCount policy on a sync task commits as soon as the goal
        // is met instead of waiting for the full cohort or the deadline.
        let bus = EventBus::new();
        let mut e = RoundEngine::with_policies(
            5,
            small_cfg(4, 1),
            ModelSnapshot::new(0, vec![0.0; 2]),
            3,
            bus,
            Box::new(UniformRandom),
            Box::new(GoalCount { goal: 2 }),
        )
        .unwrap();
        e.start().unwrap();
        let dir = NullDirectory;
        for c in 1..=4u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        for c in 1..=4u64 {
            let _ = e.fetch(c, &dir, 0).unwrap();
        }
        for c in 1..=2u64 {
            let (ok, why) = e
                .accept_plain(c, 0, 0, vec![1.0; 2], 1.0, 0.1, &NoEval, 10)
                .unwrap();
            assert!(ok, "{why}");
        }
        // Committed at the goal — stragglers dropped, no deadline wait.
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 2);
    }

    #[test]
    fn sync_cohort_fetches_share_one_compression() {
        use std::sync::Arc;
        let (mut e, _bus) = engine(small_cfg(3, 1), 8);
        let dir = NullDirectory;
        for c in 1..=3u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        let mut blobs = Vec::new();
        for c in 1..=3u64 {
            if let RoundRole::Train(ri) = e.fetch(c, &dir, 0).unwrap() {
                blobs.push(ri.model_blob);
            }
        }
        assert_eq!(blobs.len(), 3);
        assert!(Arc::ptr_eq(&blobs[0], &blobs[1]));
        assert!(Arc::ptr_eq(&blobs[1], &blobs[2]));
        assert_eq!(e.global.compressions(), 1, "one zlib pass per version");
    }

    #[test]
    fn async_polls_share_cached_blob_until_version_bump() {
        use std::sync::Arc;
        let mut cfg = small_cfg(4, 2);
        cfg.mode = FlMode::Async { buffer_size: 3 };
        cfg.aggregator = "fedbuff".into();
        let (mut e, _bus) = engine(cfg, 4);
        for c in 1..=3u64 {
            e.join(c, [0u8; 32], 0).unwrap();
        }
        fn fetch_blob(e: &mut RoundEngine, c: u64, now: u64) -> Arc<Vec<u8>> {
            match e.fetch(c, &NullDirectory, now).unwrap() {
                RoundRole::Train(ri) => ri.model_blob,
                other => panic!("{other:?}"),
            }
        }
        let a = fetch_blob(&mut e, 1, 0);
        let b = fetch_blob(&mut e, 2, 1);
        assert!(
            Arc::ptr_eq(&a, &b),
            "unchanged version must serve the cached Arc"
        );
        assert_eq!(e.global.compressions(), 1, "repeat polls must not zlib");
        // Three uploads → flush → version bump → cache invalidated.
        for c in 1..=3u64 {
            let (ok, why) = e
                .accept_plain(c, 0, 0, vec![0.1; 4], 1.0, 0.5, &NoEval, 10)
                .unwrap();
            assert!(ok, "{why}");
        }
        assert_eq!(e.global.version, 1);
        let fresh = fetch_blob(&mut e, 3, 20);
        assert!(!Arc::ptr_eq(&a, &fresh), "stale blob must not be reused");
        assert_eq!(e.global.compressions(), 2);
        let decoded = ModelSnapshot::from_compressed(&fresh).unwrap();
        assert_eq!(decoded.version, 1);
    }

    #[test]
    fn restore_rebuilds_committed_boundary_without_events() {
        let (mut e, _bus) = engine(small_cfg(2, 3), 4);
        drive_round(&mut e, 2, 2, 0);
        assert_eq!(e.round, 1);
        let params = e.global.params.clone();
        let version = e.global.version;
        let bus = EventBus::new();
        let stream = bus.subscribe();
        let store = SnapshotStore::new(ModelSnapshot::new(version, params.clone()));
        let mut r = RoundEngine::restore(
            1,
            small_cfg(2, 3),
            store,
            7,
            bus.clone(),
            TaskState::Running,
            1,
            e.metrics.clone(),
        )
        .unwrap();
        assert!(stream.drain().is_empty(), "restore must not emit events");
        assert_eq!(r.round, 1);
        assert_eq!(r.state, TaskState::Running);
        assert_eq!(r.global.params, params);
        assert_eq!(r.global.version, version);
        assert_eq!(r.phase_name(), "joining");
        // The restored engine keeps orchestrating where it left off.
        drive_round(&mut r, 2, 2, 10);
        assert_eq!(r.round, 2);
        assert_eq!(r.metrics.rounds.len(), 2);
    }

    #[test]
    fn restore_replays_dp_accountant_from_round_history() {
        let mut cfg = small_cfg(2, 3);
        cfg.dp = crate::dp::DpConfig::paper_local();
        cfg.dp_population = 50;
        let (mut e, _bus) = engine(cfg.clone(), 2);
        drive_round(&mut e, 2, 2, 0);
        let eps_before = e.epsilon().unwrap();
        assert!(eps_before > 0.0);
        let store =
            SnapshotStore::new(ModelSnapshot::new(e.global.version, e.global.params.clone()));
        let r = RoundEngine::restore(
            1,
            cfg,
            store,
            7,
            EventBus::new(),
            TaskState::Running,
            1,
            e.metrics.clone(),
        )
        .unwrap();
        let eps_after = r.epsilon().unwrap();
        assert!(
            (eps_before - eps_after).abs() < 1e-12,
            "{eps_before} vs {eps_after}"
        );
    }

    /// Fold unit deltas for `members` the way a leaf would, returning
    /// the exported partial + loss sum for `accept_partial`.
    fn leaf_partial(e: &RoundEngine, members: &[u64], step: f32) -> (PartialFold, f64) {
        let agg = aggregation::by_name(&e.config.aggregator, e.config.prox_mu).unwrap();
        let mut fold = agg.begin(e.global.dim()).unwrap();
        let mut loss_sum = 0.0;
        for &m in members {
            fold.accept(
                &vec![step; e.global.dim()],
                &UpdateStats {
                    client_id: m,
                    weight: 1.0,
                    loss: 0.5,
                    staleness: 0,
                },
            )
            .unwrap();
            loss_sum += 0.5;
        }
        (fold.export(), loss_sum)
    }

    #[test]
    fn leaf_slices_cover_cohort_disjointly() {
        let (mut e, _bus) = engine(small_cfg(5, 1), 2);
        // No open round yet: structured refusal, not an error.
        assert!(!e.leaf_slice(0, 2).accepted);
        drive_round(&mut e, 5, 0, 0); // form cohort, nobody uploads
        assert_eq!(e.phase_name(), "training");
        assert!(!e.leaf_slice(0, 0).accepted, "zero leaves refused");
        assert!(!e.leaf_slice(2, 2).accepted, "index out of range refused");
        let mut seen = BTreeSet::new();
        let mut total = 0;
        for i in 0..3u32 {
            let a = e.leaf_slice(i, 3);
            assert!(a.accepted, "{}", a.reason);
            assert_eq!(a.round, 0);
            assert_eq!(a.base_version, 0);
            total += a.members.len();
            for m in a.members {
                assert!(seen.insert(m), "member {m} in two slices");
            }
        }
        assert_eq!(total, 5);
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn partial_merges_commit_round_bit_identical_to_flat() {
        // Flat reference: everyone uploads unit deltas directly.
        let (mut e_flat, _b1) = engine(small_cfg(4, 1), 3);
        drive_round(&mut e_flat, 4, 0, 0);
        let round = e_flat.round;
        for c in 1..=4u64 {
            let (ok, why) = e_flat
                .accept_plain(c, round, 0, vec![1.0; 3], 1.0, 0.5, &NoEval, 10)
                .unwrap();
            assert!(ok, "{why}");
        }
        assert_eq!(e_flat.state, TaskState::Completed);

        // Tree path: the same cohort split across two leaves.
        let (mut e, _b2) = engine(small_cfg(4, 1), 3);
        drive_round(&mut e, 4, 0, 0);
        for i in 0..2u32 {
            let a = e.leaf_slice(i, 2);
            assert!(a.accepted, "{}", a.reason);
            assert_eq!(a.members.len(), 2);
            let (part, loss_sum) = leaf_partial(&e, &a.members, 1.0);
            let (ok, folded, why) = e
                .accept_partial(
                    100 + i as u64,
                    a.round,
                    a.base_version,
                    &a.members,
                    &part,
                    loss_sum,
                    &NoEval,
                    10,
                )
                .unwrap();
            assert!(ok, "{why}");
            assert_eq!(folded, 2);
        }
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds[0].participants, 4);
        assert_eq!(e.metrics.total_uploads, 4);
        // Unit deltas and weights make every f64 sum exact: the tree
        // path must be bit-identical to the flat path.
        assert_eq!(e.global.params, e_flat.global.params);
    }

    #[test]
    fn partial_with_already_reported_member_is_rejected_whole() {
        let (mut e, _bus) = engine(small_cfg(4, 1), 2);
        drive_round(&mut e, 4, 0, 0);
        let a = e.leaf_slice(0, 2);
        assert!(a.accepted);
        // One of the leaf's members uploads directly first.
        let direct = a.members[0];
        let (ok, why) = e
            .accept_plain(direct, 0, 0, vec![1.0; 2], 1.0, 0.5, &NoEval, 5)
            .unwrap();
        assert!(ok, "{why}");
        let (part, loss_sum) = leaf_partial(&e, &a.members, 1.0);
        let (ok, folded, why) = e
            .accept_partial(100, 0, 0, &a.members, &part, loss_sum, &NoEval, 10)
            .unwrap();
        assert!(!ok);
        assert_eq!(folded, 0);
        assert!(why.contains("already reported"), "{why}");
        // Nothing was half-credited: only the direct upload counts.
        assert_eq!(e.metrics.total_uploads, 1);
        // Mismatched member/count bookkeeping is refused up front.
        let (ok, _, why) = e
            .accept_partial(100, 0, 0, &a.members[1..], &part, loss_sum, &NoEval, 11)
            .unwrap();
        assert!(!ok);
        assert!(why.contains("updates for"), "{why}");
        // Stale round is a structured refusal too.
        let (ok, _, why) = e
            .accept_partial(100, 7, 0, &a.members, &part, loss_sum, &NoEval, 12)
            .unwrap();
        assert!(!ok && why.contains("stale round"), "{why}");
    }

    #[test]
    fn leaf_death_mid_round_fails_and_retries_without_double_count() {
        // Two leaves own the cohort; leaf 1 dies before forwarding. The
        // existing pacing deadline fails the round, and the retry must
        // commit from a clean fold — the dead round's merged partial
        // must not leak into the final model.
        let mut cfg = small_cfg(4, 1);
        cfg.min_report_fraction = 0.9; // quorum 4: a lost leaf misses it
        let (mut e, bus) = engine(cfg, 3);
        let stream = bus.subscribe();
        drive_round(&mut e, 4, 0, 0);
        let a = e.leaf_slice(0, 2);
        assert!(a.accepted);
        let (part, loss_sum) = leaf_partial(&e, &a.members, 1.0);
        let (ok, _, why) = e
            .accept_partial(100, a.round, a.base_version, &a.members, &part, loss_sum, &NoEval, 10)
            .unwrap();
        assert!(ok, "{why}");
        // Leaf 1 never forwards; the deadline sweep fails the round.
        e.tick(&NoEval, &NullDirectory, 5000);
        assert_eq!(e.round, 0);
        assert_eq!(e.metrics.failed_rounds, 1);
        assert_eq!(e.phase_name(), "joining");
        assert!(stream.drain().iter().any(|ev| ev.kind() == "quorum_missed"));
        // A late partial from the dead round is refused (no round open).
        let (ok, _, why) = e
            .accept_partial(101, 0, 0, &[1], &part, 0.5, &NoEval, 5100)
            .unwrap();
        assert!(!ok, "{why}");
        // Retry: everyone rejoins and both leaves forward this time.
        drive_round(&mut e, 4, 0, 6000);
        for i in 0..2u32 {
            let a = e.leaf_slice(i, 2);
            assert!(a.accepted, "{}", a.reason);
            let (part, loss_sum) = leaf_partial(&e, &a.members, 1.0);
            let (ok, _, why) = e
                .accept_partial(
                    100 + i as u64,
                    a.round,
                    a.base_version,
                    &a.members,
                    &part,
                    loss_sum,
                    &NoEval,
                    6010,
                )
                .unwrap();
            assert!(ok, "{why}");
        }
        assert_eq!(e.state, TaskState::Completed);
        assert_eq!(e.metrics.rounds.len(), 1);
        assert_eq!(e.metrics.rounds[0].participants, 4);
        // Exactly one committed round of unit deltas: +1.0 per param.
        // Any leakage from the failed attempt would show up here.
        for p in &e.global.params {
            assert_eq!(*p, 1.0);
        }
    }

    #[test]
    fn lifecycle_transitions_enforced_and_observable() {
        let bus = EventBus::new();
        let mut e = RoundEngine::new(
            9,
            small_cfg(2, 3),
            ModelSnapshot::new(0, vec![0.0; 2]),
            1,
            bus.clone(),
        )
        .unwrap();
        let stream = bus.subscribe_task(9);
        assert!(e.pause().is_err()); // created → pause invalid
        e.start().unwrap();
        e.pause().unwrap();
        e.start().unwrap();
        e.cancel();
        assert!(e.start().is_err());
        let states: Vec<TaskState> = stream
            .drain()
            .into_iter()
            .filter_map(|ev| match ev {
                TaskEvent::TaskStateChanged { state, .. } => Some(state),
                _ => None,
            })
            .collect();
        assert_eq!(
            states,
            vec![
                TaskState::Running,
                TaskState::Paused,
                TaskState::Running,
                TaskState::Cancelled,
            ]
        );
    }
}
