//! Task lifecycle events (§3.3 observability): dashboards, the CLI and
//! the simulator subscribe to a [`TaskEvent`] stream instead of polling
//! `task_status`.
//!
//! The bus is deliberately simple: every subscriber gets every event
//! (optionally filtered to one task), delivery is best-effort in-process
//! mpsc, and dropped receivers are pruned on the next emit. Emission
//! happens while the management registry lock is held, so handlers must
//! never call back into the platform synchronously — they receive on
//! their own thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::proto::TaskState;

/// One observable lifecycle transition.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskEvent {
    /// Task moved between lifecycle states (start/pause/cancel/complete).
    TaskStateChanged { task_id: u64, state: TaskState },
    /// A client was admitted to the join pool (sync) or enrolled (async).
    ClientJoined { task_id: u64, client_id: u64 },
    /// A cohort formed and the round opened for training.
    RoundStarted {
        task_id: u64,
        round: u64,
        cohort: usize,
    },
    /// The round aggregated and the global model advanced.
    RoundCommitted {
        task_id: u64,
        round: u64,
        participants: usize,
        train_loss: f64,
    },
    /// The deadline passed with fewer reports than the quorum.
    QuorumMissed {
        task_id: u64,
        round: u64,
        reported: usize,
        quorum: usize,
    },
    /// The round was abandoned and will be retried (joiners stay queued).
    RoundFailed { task_id: u64, round: u64 },
    /// A cohort member's liveness lease expired mid-round and it was
    /// removed from the open cohort (its waiting-pool entries too).
    ClientEvicted {
        task_id: u64,
        client_id: u64,
        round: u64,
    },
    /// An evicted cohort slot was refilled from the waiting join pool.
    CohortBackfilled {
        task_id: u64,
        client_id: u64,
        round: u64,
    },
    /// The task reached its final round and completed.
    TaskCompleted { task_id: u64 },
}

impl TaskEvent {
    /// The task this event belongs to.
    pub fn task_id(&self) -> u64 {
        match self {
            TaskEvent::TaskStateChanged { task_id, .. }
            | TaskEvent::ClientJoined { task_id, .. }
            | TaskEvent::RoundStarted { task_id, .. }
            | TaskEvent::RoundCommitted { task_id, .. }
            | TaskEvent::QuorumMissed { task_id, .. }
            | TaskEvent::RoundFailed { task_id, .. }
            | TaskEvent::ClientEvicted { task_id, .. }
            | TaskEvent::CohortBackfilled { task_id, .. }
            | TaskEvent::TaskCompleted { task_id } => *task_id,
        }
    }

    /// Stable short name (log lines, dashboards).
    pub fn kind(&self) -> &'static str {
        match self {
            TaskEvent::TaskStateChanged { .. } => "task_state_changed",
            TaskEvent::ClientJoined { .. } => "client_joined",
            TaskEvent::RoundStarted { .. } => "round_started",
            TaskEvent::RoundCommitted { .. } => "round_committed",
            TaskEvent::QuorumMissed { .. } => "quorum_missed",
            TaskEvent::RoundFailed { .. } => "round_failed",
            TaskEvent::ClientEvicted { .. } => "client_evicted",
            TaskEvent::CohortBackfilled { .. } => "cohort_backfilled",
            TaskEvent::TaskCompleted { .. } => "task_completed",
        }
    }
}

/// Fan-out publisher shared by every [`crate::orchestrator::RoundEngine`]
/// under one management service. Cheap to clone.
#[derive(Clone, Default)]
pub struct EventBus {
    subs: Arc<Mutex<Vec<Sender<TaskEvent>>>>,
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus::default()
    }

    /// Subscribe to every task's events.
    pub fn subscribe(&self) -> EventStream {
        self.subscribe_filtered(None)
    }

    /// Subscribe to a single task's events.
    pub fn subscribe_task(&self, task_id: u64) -> EventStream {
        self.subscribe_filtered(Some(task_id))
    }

    fn subscribe_filtered(&self, only_task: Option<u64>) -> EventStream {
        let (tx, rx) = channel();
        // Poison recovery: the subscriber list is a plain Vec of senders
        // with no cross-entry invariant, so the list behind a guard
        // abandoned by a panicking emitter is still valid — losing the
        // whole event bus over one crashed handler would be worse.
        self.subs
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(tx);
        EventStream { rx, only_task }
    }

    /// Publish to all live subscribers; dead ones are pruned.
    pub fn emit(&self, event: TaskEvent) {
        let mut subs = self.subs.lock().unwrap_or_else(|p| p.into_inner());
        // An unbounded in-process mpsc send never blocks, so holding the
        // subscriber lock across it cannot stall the data plane.
        // florida-lint: allow(lock-across-send): unbounded mpsc, non-blocking
        subs.retain(|tx| tx.send(event.clone()).is_ok());
    }

    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

/// A subscriber's end of the bus. Dropping it unsubscribes (lazily).
pub struct EventStream {
    rx: Receiver<TaskEvent>,
    only_task: Option<u64>,
}

impl EventStream {
    fn admits(&self, ev: &TaskEvent) -> bool {
        match self.only_task {
            None => true,
            Some(id) => ev.task_id() == id,
        }
    }

    /// Non-blocking: the next matching event, if one is queued.
    pub fn try_next(&self) -> Option<TaskEvent> {
        while let Ok(ev) = self.rx.try_recv() {
            if self.admits(&ev) {
                return Some(ev);
            }
        }
        None
    }

    /// Block up to `timeout` for the next matching event.
    ///
    /// Wall-clock on purpose: this is the *subscriber's* wait, real time
    /// by nature (a dashboard or test blocking on delivery). Orchestration
    /// deadlines themselves run on the server's `Clock` seam.
    pub fn next_timeout(&self, timeout: Duration) -> Option<TaskEvent> {
        // florida-lint: allow(wall-clock-in-core): subscriber-side real-time wait
        let deadline = Instant::now() + timeout;
        loop {
            // florida-lint: allow(wall-clock-in-core): subscriber-side real-time wait
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(ev) if self.admits(&ev) => return Some(ev),
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    /// Drain everything currently queued (matching events only).
    pub fn drain(&self) -> Vec<TaskEvent> {
        let mut out = Vec::new();
        while let Ok(ev) = self.rx.try_recv() {
            if self.admits(&ev) {
                out.push(ev);
            }
        }
        out
    }

    /// Block up to `timeout` for the first matching event satisfying
    /// `pred` — the simulator's replacement for status polling.
    pub fn wait_for(
        &self,
        timeout: Duration,
        mut pred: impl FnMut(&TaskEvent) -> bool,
    ) -> Option<TaskEvent> {
        // florida-lint: allow(wall-clock-in-core): subscriber-side real-time wait
        let deadline = Instant::now() + timeout;
        loop {
            // florida-lint: allow(wall-clock-in-core): subscriber-side real-time wait
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(ev) if self.admits(&ev) && pred(&ev) => return Some(ev),
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscribers_receive_emitted_events() {
        let bus = EventBus::new();
        let all = bus.subscribe();
        let only_2 = bus.subscribe_task(2);
        bus.emit(TaskEvent::TaskCompleted { task_id: 1 });
        bus.emit(TaskEvent::RoundStarted {
            task_id: 2,
            round: 0,
            cohort: 4,
        });
        assert_eq!(all.drain().len(), 2);
        let got = only_2.drain();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].task_id(), 2);
        assert_eq!(got[0].kind(), "round_started");
    }

    #[test]
    fn dropped_subscribers_are_pruned() {
        let bus = EventBus::new();
        {
            let _short_lived = bus.subscribe();
            assert_eq!(bus.subscriber_count(), 1);
        }
        bus.emit(TaskEvent::TaskCompleted { task_id: 1 });
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn wait_for_matches_predicate_across_noise() {
        let bus = EventBus::new();
        let stream = bus.subscribe();
        bus.emit(TaskEvent::ClientJoined {
            task_id: 1,
            client_id: 9,
        });
        bus.emit(TaskEvent::RoundCommitted {
            task_id: 1,
            round: 3,
            participants: 8,
            train_loss: 0.25,
        });
        let hit = stream
            .wait_for(Duration::from_millis(200), |ev| {
                matches!(ev, TaskEvent::RoundCommitted { round: 3, .. })
            })
            .expect("committed event");
        assert_eq!(hit.kind(), "round_committed");
        // Timeout path: nothing else queued.
        assert!(stream
            .wait_for(Duration::from_millis(10), |_| true)
            .is_none());
    }

    #[test]
    fn try_next_skips_filtered_events() {
        let bus = EventBus::new();
        let only_7 = bus.subscribe_task(7);
        bus.emit(TaskEvent::TaskCompleted { task_id: 1 });
        bus.emit(TaskEvent::TaskCompleted { task_id: 7 });
        let ev = only_7.try_next().expect("task 7 event");
        assert_eq!(ev.task_id(), 7);
        assert!(only_7.try_next().is_none());
    }
}
