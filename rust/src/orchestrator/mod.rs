//! Round orchestration (§3.1.3, §4.2, §4.3): the task-workflow side of
//! the platform, decoupled from service management.
//!
//! * [`RoundEngine`] — per-task typed phase state machine
//!   (Joining → Training → Unmasking → Committed/Failed) with explicit
//!   transition methods. All phase/round internals live here; nothing
//!   outside `orchestrator/` matches on a phase or mutates a round.
//! * [`CohortPolicy`] / [`PacingPolicy`] — the pluggable "user-defined
//!   logic" seams (selection and pacing); the third seam is the existing
//!   [`crate::aggregation::Aggregator`].
//! * [`TaskBuilder`] / [`TaskHandle`] — the FLaaS-facing API for
//!   creating and administering tasks.
//! * [`TaskEvent`] / [`EventBus`] — the lifecycle subscription stream
//!   dashboards and the simulator observe instead of polling.
//!
//! `services::management::ManagementService` is the thin multi-tenant
//! registry over these engines.

pub mod builder;
pub mod engine;
pub mod events;
pub mod policy;

pub use builder::{TaskBuilder, TaskHandle};
pub use engine::{Evaluator, NoEval, RoundEngine};
pub use events::{EventBus, EventStream, TaskEvent};
pub use policy::{
    default_pacing, ClientDirectory, CohortContext, CohortPolicy, FixedDeadline, GoalCount,
    NullDirectory, OverProvision, PacingDecision, PacingPolicy, RoundProgress, Tiered,
    UniformRandom,
};
