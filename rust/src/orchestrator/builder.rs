//! FLaaS-facing task API: a fluent [`TaskBuilder`] (replacing raw
//! `TaskConfig` struct literals) and a [`TaskHandle`] for admin
//! operations + event subscription (§3.3.1 task creation/management).
//!
//! ```no_run
//! # use florida::orchestrator::TaskBuilder;
//! # use florida::model::ModelSnapshot;
//! # use florida::services::FloridaServer;
//! # let server = FloridaServer::for_testing(false, 1);
//! let handle = TaskBuilder::new("spam-classifier")
//!     .app("mail")
//!     .workflow("spam")
//!     .clients_per_round(32)
//!     .rounds(10)
//!     .secure_agg(16)
//!     .deploy(&server.management, ModelSnapshot::new(0, vec![0.0; 8]))
//!     .unwrap();
//! let events = handle.subscribe();
//! # let _ = events;
//! ```

use crate::config::{CohortSpec, FlMode, TaskConfig};
use crate::dp::DpConfig;
use crate::error::Result;
use crate::metrics::TaskMetrics;
use crate::model::ModelSnapshot;
use crate::proto::{SelectionCriteria, TaskDescriptor};
use crate::services::management::ManagementService;

use super::events::EventStream;
use super::policy::{CohortPolicy, PacingPolicy};

/// Fluent task construction. Every knob defaults to
/// [`TaskConfig::default`]; validation happens at deploy time.
pub struct TaskBuilder {
    config: TaskConfig,
    cohort_policy: Option<Box<dyn CohortPolicy>>,
    pacing: Option<Box<dyn PacingPolicy>>,
}

impl TaskBuilder {
    pub fn new(task_name: &str) -> TaskBuilder {
        let mut config = TaskConfig::default();
        config.task_name = task_name.to_string();
        TaskBuilder {
            config,
            cohort_policy: None,
            pacing: None,
        }
    }

    /// Wrap an existing config (JSON-deployed tasks, CLI `--task`).
    pub fn from_config(config: TaskConfig) -> TaskBuilder {
        TaskBuilder {
            config,
            cohort_policy: None,
            pacing: None,
        }
    }

    pub fn app(mut self, app_name: &str) -> Self {
        self.config.app_name = app_name.to_string();
        self
    }

    pub fn workflow(mut self, workflow_name: &str) -> Self {
        self.config.workflow_name = workflow_name.to_string();
        self
    }

    pub fn preset(mut self, preset: &str) -> Self {
        self.config.preset = preset.to_string();
        self
    }

    pub fn clients_per_round(mut self, k: usize) -> Self {
        self.config.clients_per_round = k;
        self
    }

    /// Degraded floor: rounds proceed with `min_clients ≤ pool < k`
    /// after the join grace instead of stalling at Joining.
    pub fn min_clients(mut self, floor: usize) -> Self {
        self.config.min_clients = floor;
        self
    }

    pub fn rounds(mut self, total_rounds: u64) -> Self {
        self.config.total_rounds = total_rounds;
        self
    }

    /// Synchronous rounds (the default).
    pub fn sync(mut self) -> Self {
        self.config.mode = FlMode::Sync;
        self
    }

    /// Buffered-async federation (§4.3): flush every `buffer_size`
    /// contributions.
    pub fn buffered_async(mut self, buffer_size: usize) -> Self {
        self.config.mode = FlMode::Async { buffer_size };
        self
    }

    /// Aggregation strategy: fedavg | fedprox | dga | fedbuff.
    pub fn aggregator(mut self, name: &str) -> Self {
        self.config.aggregator = name.to_string();
        self
    }

    pub fn server_lr(mut self, lr: f32) -> Self {
        self.config.server_lr = lr;
        self
    }

    pub fn client_lr(mut self, lr: f32) -> Self {
        self.config.client_lr = lr;
        self
    }

    pub fn prox_mu(mut self, mu: f32) -> Self {
        self.config.prox_mu = mu;
        self
    }

    /// Enable secure aggregation with the given virtual-group size.
    pub fn secure_agg(mut self, vg_size: usize) -> Self {
        self.config.secure_agg = true;
        self.config.vg_size = vg_size;
        self
    }

    /// Disable secure aggregation (plaintext uploads — the default).
    pub fn plaintext(mut self) -> Self {
        self.config.secure_agg = false;
        self
    }

    pub fn quantizer(mut self, range: f32, bits: u32) -> Self {
        self.config.quant_range = range;
        self.config.quant_bits = bits;
        self
    }

    pub fn dp(mut self, dp: DpConfig) -> Self {
        self.config.dp = dp;
        self
    }

    pub fn dp_population(mut self, population: usize) -> Self {
        self.config.dp_population = population;
        self
    }

    pub fn selection(mut self, criteria: SelectionCriteria) -> Self {
        self.config.selection = criteria;
        self
    }

    pub fn round_timeout_ms(mut self, timeout_ms: u64) -> Self {
        self.config.round_timeout_ms = timeout_ms;
        self
    }

    pub fn min_report_fraction(mut self, fraction: f64) -> Self {
        self.config.min_report_fraction = fraction;
        self
    }

    /// Config-expressible cohort policy (serializes with the task).
    pub fn cohort_policy(mut self, spec: CohortSpec) -> Self {
        self.config.cohort = spec;
        self
    }

    /// Custom cohort policy object (overrides the config spec).
    pub fn custom_cohort_policy(mut self, policy: Box<dyn CohortPolicy>) -> Self {
        self.cohort_policy = Some(policy);
        self
    }

    /// Custom pacing policy object (overrides the mode-derived default).
    pub fn custom_pacing(mut self, policy: Box<dyn PacingPolicy>) -> Self {
        self.pacing = Some(policy);
        self
    }

    /// Finish building, returning the validated config (for wire/JSON
    /// paths that carry configs rather than live tasks).
    pub fn build(self) -> Result<TaskConfig> {
        self.config.validate()?;
        Ok(self.config)
    }

    /// Create the task (Created state — start it via the handle).
    pub fn create<'a>(
        self,
        mgmt: &'a ManagementService,
        init: ModelSnapshot,
    ) -> Result<TaskHandle<'a>> {
        let TaskBuilder {
            config,
            cohort_policy,
            pacing,
        } = self;
        let id = if cohort_policy.is_some() || pacing.is_some() {
            mgmt.create_task_with_policies(config, init, cohort_policy, pacing)?
        } else {
            mgmt.create_task(config, init)?
        };
        Ok(TaskHandle { mgmt, id })
    }

    /// Create **and start** the task — the one-call deploy path.
    pub fn deploy<'a>(
        self,
        mgmt: &'a ManagementService,
        init: ModelSnapshot,
    ) -> Result<TaskHandle<'a>> {
        let handle = self.create(mgmt, init)?;
        handle.start()?;
        Ok(handle)
    }
}

/// Admin handle for one deployed task: lifecycle operations, status and
/// the task-scoped event stream. Cheap — holds only the registry
/// reference and the task id.
#[derive(Clone, Copy)]
pub struct TaskHandle<'a> {
    mgmt: &'a ManagementService,
    id: u64,
}

impl<'a> TaskHandle<'a> {
    /// Re-attach to an existing task by id (router/CLI surfaces).
    pub fn attach(mgmt: &'a ManagementService, id: u64) -> TaskHandle<'a> {
        TaskHandle { mgmt, id }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn start(&self) -> Result<()> {
        self.mgmt.start_task(self.id)
    }

    pub fn pause(&self) -> Result<()> {
        self.mgmt.pause_task(self.id)
    }

    pub fn cancel(&self) -> Result<()> {
        self.mgmt.cancel_task(self.id)
    }

    pub fn descriptor(&self) -> Result<TaskDescriptor> {
        self.mgmt.with_task(self.id, |t| Ok(t.descriptor()))
    }

    /// (descriptor, metrics, epsilon) — the dashboard status tuple.
    pub fn status(&self) -> Result<(TaskDescriptor, TaskMetrics, Option<f64>)> {
        self.mgmt.task_status(self.id)
    }

    /// Subscribe to this task's lifecycle events.
    pub fn subscribe(&self) -> EventStream {
        self.mgmt.events().subscribe_task(self.id)
    }

    /// Force a durability checkpoint at the task's current
    /// committed-round boundary (a no-op for in-memory deployments).
    pub fn checkpoint(&self) -> Result<()> {
        self.mgmt.checkpoint_task(self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::TaskEvent;
    use crate::proto::TaskState;
    use crate::services::management::NoEval;
    use std::sync::Arc;

    fn mgmt() -> ManagementService {
        ManagementService::new(Arc::new(NoEval), 11)
    }

    #[test]
    fn builder_sets_config_fields() {
        let cfg = TaskBuilder::new("t")
            .app("mail")
            .workflow("spam")
            .clients_per_round(8)
            .min_clients(4)
            .rounds(3)
            .aggregator("fedprox")
            .prox_mu(0.1)
            .secure_agg(4)
            .round_timeout_ms(5000)
            .min_report_fraction(0.6)
            .cohort_policy(CohortSpec::OverProvision { spawn_factor: 1.25 })
            .build()
            .unwrap();
        assert_eq!(cfg.task_name, "t");
        assert_eq!(cfg.app_name, "mail");
        assert_eq!(cfg.clients_per_round, 8);
        assert_eq!(cfg.min_clients, 4);
        assert!(cfg.secure_agg);
        assert_eq!(cfg.vg_size, 4);
        assert_eq!(
            cfg.cohort,
            CohortSpec::OverProvision { spawn_factor: 1.25 }
        );
    }

    #[test]
    fn build_validates() {
        assert!(TaskBuilder::new("bad").clients_per_round(0).build().is_err());
        assert!(TaskBuilder::new("bad")
            .buffered_async(4)
            .secure_agg(2)
            .build()
            .is_err());
    }

    #[test]
    fn deploy_creates_started_task_and_handle_controls_it() {
        let m = mgmt();
        let handle = TaskBuilder::new("built")
            .clients_per_round(2)
            .rounds(1)
            .deploy(&m, ModelSnapshot::new(0, vec![0.0; 2]))
            .unwrap();
        assert_eq!(handle.descriptor().unwrap().state, TaskState::Running);
        handle.pause().unwrap();
        assert_eq!(handle.descriptor().unwrap().state, TaskState::Paused);
        handle.start().unwrap();
        handle.cancel().unwrap();
        assert_eq!(handle.descriptor().unwrap().state, TaskState::Cancelled);
        let (desc, metrics, eps) = handle.status().unwrap();
        assert_eq!(desc.task_id, handle.id());
        assert_eq!(metrics.rounds.len(), 0);
        assert!(eps.is_none());
        // In-memory deployment: an admin checkpoint is a free no-op.
        handle.checkpoint().unwrap();
    }

    #[test]
    fn create_leaves_task_unstarted() {
        let m = mgmt();
        let handle = TaskBuilder::new("staged")
            .clients_per_round(1)
            .create(&m, ModelSnapshot::new(0, vec![0.0]))
            .unwrap();
        assert_eq!(handle.descriptor().unwrap().state, TaskState::Created);
        handle.start().unwrap();
        assert_eq!(handle.descriptor().unwrap().state, TaskState::Running);
    }

    #[test]
    fn handle_subscription_is_task_scoped() {
        let m = mgmt();
        let a = TaskBuilder::new("a")
            .deploy(&m, ModelSnapshot::new(0, vec![0.0]))
            .unwrap();
        let events_a = a.subscribe();
        let b = TaskBuilder::new("b")
            .deploy(&m, ModelSnapshot::new(0, vec![0.0]))
            .unwrap();
        b.pause().unwrap();
        a.pause().unwrap();
        let got = events_a.drain();
        assert!(!got.is_empty());
        assert!(got.iter().all(|ev| ev.task_id() == a.id()));
        assert!(matches!(
            got.last().unwrap(),
            TaskEvent::TaskStateChanged {
                state: TaskState::Paused,
                ..
            }
        ));
    }
}
