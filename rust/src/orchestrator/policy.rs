//! Pluggable round policies (§3.1.3, §4.2, §4.3): the "user-defined
//! logic" a task ships as configuration instead of platform code.
//!
//! Two policy seams parameterize the [`crate::orchestrator::RoundEngine`]:
//!
//! * [`CohortPolicy`] — who trains this round. Decides when the join pool
//!   is ready and which joiners become the cohort (uniform random as the
//!   paper's default, tiered by `DeviceCaps`, or over-provisioned per
//!   §4.2 so rounds tolerate dropouts instead of stalling).
//! * [`PacingPolicy`] — when the round closes. Fixed-deadline sync rounds
//!   vs buffered-async / FedBuff-style goal counts; the engine's `tick()`
//!   and upload paths consult it instead of hard-coding quorum logic.
//!
//! The third seam, the aggregation strategy, already exists as
//! [`crate::aggregation::Aggregator`].

use crate::proto::{DeviceCaps, DeviceProfile};
use crate::util::Rng;

// ---------------------------------------------------------------------------
// Cohort formation
// ---------------------------------------------------------------------------

/// Read-only view of the client registry a cohort policy may consult
/// (implemented by `SelectionService`, and by the session-aware
/// `services::LiveDirectory`; `NullDirectory` for tests/benches).
pub trait ClientDirectory {
    fn caps_of(&self, client_id: u64) -> Option<DeviceCaps>;

    /// The heterogeneity profile the client reported at `SessionOpen`
    /// (protocol v2). `None` for sessionless v1 clients — directories
    /// without a session view keep the default.
    fn profile_of(&self, _client_id: u64) -> Option<DeviceProfile> {
        None
    }
}

/// A directory that knows nothing — every client reads as capless.
pub struct NullDirectory;

impl ClientDirectory for NullDirectory {
    fn caps_of(&self, _client_id: u64) -> Option<DeviceCaps> {
        None
    }
}

/// Everything a cohort policy sees when deciding whether to open a round.
pub struct CohortContext<'a> {
    /// Waiting joiners in FIFO arrival order.
    pub pool: &'a [u64],
    /// Configured cohort size (`clients_per_round`).
    pub target: usize,
    /// Degraded floor: with `min_clients ≤ pool < target` and the join
    /// grace elapsed, a smaller cohort may form. Equal to `target` when
    /// degraded rounds are disabled.
    pub min_clients: usize,
    /// How long the oldest joiner has been waiting.
    pub waited_ms: u64,
    /// Join grace before degraded formation is allowed.
    pub grace_ms: u64,
    /// Registry view for caps-aware policies.
    pub directory: &'a dyn ClientDirectory,
}

impl CohortContext<'_> {
    /// Degraded formation: take the whole (undersized) pool once the
    /// grace period expires. Shared fallback for every policy.
    fn degraded(&self) -> Option<Vec<u64>> {
        if self.min_clients < self.target
            && !self.pool.is_empty()
            && self.pool.len() >= self.min_clients.max(1)
            && self.waited_ms >= self.grace_ms
        {
            let mut cohort = self.pool.to_vec();
            cohort.sort_unstable();
            Some(cohort)
        } else {
            None
        }
    }
}

/// Decides when a cohort forms and who is in it. Returned cohorts are
/// sorted by client id (deterministic virtual-group formation).
pub trait CohortPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// `Some(cohort)` to open the round now, `None` to keep waiting.
    fn form(&self, ctx: &CohortContext<'_>, rng: &mut Rng) -> Option<Vec<u64>>;
}

/// The paper's default: `target` joiners chosen uniformly at random.
pub struct UniformRandom;

impl CohortPolicy for UniformRandom {
    fn name(&self) -> &'static str {
        "uniform_random"
    }

    fn form(&self, ctx: &CohortContext<'_>, rng: &mut Rng) -> Option<Vec<u64>> {
        if ctx.pool.len() < ctx.target {
            return ctx.degraded();
        }
        let idx = rng.sample_indices(ctx.pool.len(), ctx.target);
        let mut cohort: Vec<u64> = idx.into_iter().map(|i| ctx.pool[i]).collect();
        cohort.sort_unstable();
        Some(cohort)
    }
}

/// Partitions by reported capability: candidates are ranked by the
/// compute tier from their session's [`DeviceProfile`] (the paper's
/// heterogeneity axis), falling back to `DeviceCaps::tier` for
/// sessionless v1 clients, shuffled within a rank for fairness; the top
/// `target` are selected. Capless clients rank lowest.
pub struct Tiered;

/// Rank for tier-aware selection: profiled compute tiers sit strictly
/// above integrity-only ranks, so a v2 `Low` device still outranks a
/// capless v1 one but never a profiled `Mid`/`High`.
fn capability_rank(dir: &dyn ClientDirectory, client_id: u64) -> u8 {
    if let Some(profile) = dir.profile_of(client_id) {
        return 4 + profile.compute_tier as u8; // 4..=6
    }
    dir.caps_of(client_id)
        .map(|caps| caps.tier as u8) // 0..=2 (IntegrityTier)
        .unwrap_or(0)
}

impl CohortPolicy for Tiered {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn form(&self, ctx: &CohortContext<'_>, rng: &mut Rng) -> Option<Vec<u64>> {
        if ctx.pool.len() < ctx.target {
            return ctx.degraded();
        }
        let mut ranked: Vec<u64> = ctx.pool.to_vec();
        rng.shuffle(&mut ranked);
        // Stable sort keeps the shuffle order within equal ranks.
        ranked.sort_by_key(|&c| std::cmp::Reverse(capability_rank(ctx.directory, c)));
        let mut cohort: Vec<u64> = ranked.into_iter().take(ctx.target).collect();
        cohort.sort_unstable();
        Some(cohort)
    }
}

/// §4.2 over-provisioning: spawn `ceil(target × spawn_factor)` clients
/// (bounded by the pool) so the round still meets quorum when a fraction
/// drop out, instead of stalling or retrying.
pub struct OverProvision {
    pub spawn_factor: f64,
}

impl CohortPolicy for OverProvision {
    fn name(&self) -> &'static str {
        "over_provision"
    }

    fn form(&self, ctx: &CohortContext<'_>, rng: &mut Rng) -> Option<Vec<u64>> {
        if ctx.pool.len() < ctx.target {
            return ctx.degraded();
        }
        let desired = ((ctx.target as f64) * self.spawn_factor).ceil() as usize;
        let take = desired.clamp(ctx.target, ctx.pool.len());
        let idx = rng.sample_indices(ctx.pool.len(), take);
        let mut cohort: Vec<u64> = idx.into_iter().map(|i| ctx.pool[i]).collect();
        cohort.sort_unstable();
        Some(cohort)
    }
}

impl crate::config::CohortSpec {
    /// Instantiate the policy object this config spec names.
    pub fn build(&self) -> Box<dyn CohortPolicy> {
        match *self {
            crate::config::CohortSpec::UniformRandom => Box::new(UniformRandom),
            crate::config::CohortSpec::Tiered => Box::new(Tiered),
            crate::config::CohortSpec::OverProvision { spawn_factor } => {
                Box::new(OverProvision { spawn_factor })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Round pacing
// ---------------------------------------------------------------------------

/// What the engine should do with the open round right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PacingDecision {
    /// Keep collecting reports.
    Wait,
    /// Aggregate and advance.
    Commit,
    /// Abandon the round (retry with the queued joiners).
    Fail,
}

/// Progress snapshot handed to [`PacingPolicy::assess`].
#[derive(Clone, Copy, Debug)]
pub struct RoundProgress {
    /// Members of the open cohort (buffer capacity for async flushes).
    pub cohort: usize,
    /// Reports received so far.
    pub reported: usize,
    pub now_ms: u64,
    pub deadline_ms: u64,
    /// Fraction of the cohort that must report for a deadline commit.
    pub min_report_fraction: f64,
}

impl RoundProgress {
    /// Minimum reports for a deadline commit (≥ 1).
    pub fn quorum(&self) -> usize {
        let q = (self.cohort as f64 * self.min_report_fraction).ceil() as usize;
        q.max(1)
    }
}

/// Decides when an open round commits, waits, or fails.
pub trait PacingPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Report deadline for a round opening at `now_ms`.
    fn deadline_ms(&self, now_ms: u64, round_timeout_ms: u64) -> u64 {
        now_ms + round_timeout_ms
    }

    fn assess(&self, p: &RoundProgress) -> PacingDecision;
}

/// Synchronous pacing: commit when the whole cohort reported; at the
/// deadline, commit with a quorum of stragglers dropped, else fail and
/// retry the round.
pub struct FixedDeadline;

impl PacingPolicy for FixedDeadline {
    fn name(&self) -> &'static str {
        "fixed_deadline"
    }

    fn assess(&self, p: &RoundProgress) -> PacingDecision {
        if p.cohort > 0 && p.reported >= p.cohort {
            return PacingDecision::Commit;
        }
        if p.now_ms < p.deadline_ms {
            return PacingDecision::Wait;
        }
        if p.reported >= p.quorum() {
            PacingDecision::Commit
        } else {
            PacingDecision::Fail
        }
    }
}

/// Buffered-async / FedBuff pacing: commit (flush) as soon as `goal`
/// contributions are buffered; never fails — stragglers' uploads simply
/// land in the next flush epoch.
pub struct GoalCount {
    pub goal: usize,
}

impl PacingPolicy for GoalCount {
    fn name(&self) -> &'static str {
        "goal_count"
    }

    fn assess(&self, p: &RoundProgress) -> PacingDecision {
        if p.reported >= self.goal.max(1) {
            PacingDecision::Commit
        } else {
            PacingDecision::Wait
        }
    }
}

/// The mode-derived pacing default: fixed-deadline sync rounds, goal-count
/// flushes for buffered async. The single source for this mapping.
pub fn default_pacing(mode: crate::config::FlMode) -> Box<dyn PacingPolicy> {
    match mode {
        crate::config::FlMode::Sync => Box::new(FixedDeadline),
        crate::config::FlMode::Async { buffer_size } => Box::new(GoalCount { goal: buffer_size }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::attest::IntegrityTier;

    struct TierDir;

    impl ClientDirectory for TierDir {
        fn caps_of(&self, client_id: u64) -> Option<DeviceCaps> {
            let mut caps = DeviceCaps::default();
            // Clients 1..=3 are Strong, the rest Basic.
            caps.tier = if client_id <= 3 {
                IntegrityTier::Strong
            } else {
                IntegrityTier::Basic
            };
            Some(caps)
        }
    }

    fn ctx<'a>(
        pool: &'a [u64],
        target: usize,
        min_clients: usize,
        waited_ms: u64,
        directory: &'a dyn ClientDirectory,
    ) -> CohortContext<'a> {
        CohortContext {
            pool,
            target,
            min_clients,
            waited_ms,
            grace_ms: 1000,
            directory,
        }
    }

    #[test]
    fn uniform_random_waits_then_forms_full_cohort() {
        let mut rng = Rng::new(1);
        let dir = NullDirectory;
        let pool: Vec<u64> = (1..=10).collect();
        assert!(UniformRandom
            .form(&ctx(&pool[..3], 4, 4, 0, &dir), &mut rng)
            .is_none());
        let cohort = UniformRandom
            .form(&ctx(&pool, 4, 4, 0, &dir), &mut rng)
            .unwrap();
        assert_eq!(cohort.len(), 4);
        assert!(cohort.windows(2).all(|w| w[0] < w[1]), "{cohort:?}");
        assert!(cohort.iter().all(|c| pool.contains(c)));
    }

    #[test]
    fn degraded_cohort_needs_floor_and_grace() {
        let mut rng = Rng::new(2);
        let dir = NullDirectory;
        let pool: Vec<u64> = vec![5, 3, 8];
        // Below the floor: never degrade.
        assert!(UniformRandom
            .form(&ctx(&pool[..1], 4, 2, 9999, &dir), &mut rng)
            .is_none());
        // At the floor but inside the grace window: keep waiting.
        assert!(UniformRandom
            .form(&ctx(&pool, 4, 2, 500, &dir), &mut rng)
            .is_none());
        // Floor met and grace elapsed: the whole pool trains, sorted.
        let cohort = UniformRandom
            .form(&ctx(&pool, 4, 2, 1000, &dir), &mut rng)
            .unwrap();
        assert_eq!(cohort, vec![3, 5, 8]);
        // min_clients == target disables degraded formation entirely.
        assert!(UniformRandom
            .form(&ctx(&pool, 4, 4, 99_999, &dir), &mut rng)
            .is_none());
    }

    /// Directory serving v2 profiles: odd ids High, even ids Low.
    struct ProfileDir;

    impl ClientDirectory for ProfileDir {
        fn caps_of(&self, _client_id: u64) -> Option<DeviceCaps> {
            Some(DeviceCaps::default())
        }

        fn profile_of(&self, client_id: u64) -> Option<DeviceProfile> {
            Some(DeviceProfile {
                compute_tier: if client_id % 2 == 1 {
                    crate::proto::ComputeTier::High
                } else {
                    crate::proto::ComputeTier::Low
                },
                ..Default::default()
            })
        }
    }

    #[test]
    fn tiered_partitions_by_reported_compute_tier() {
        let mut rng = Rng::new(9);
        let dir = ProfileDir;
        let pool: Vec<u64> = (1..=8).collect(); // 1,3,5,7 High; 2,4,6,8 Low
        let cohort = Tiered.form(&ctx(&pool, 4, 4, 0, &dir), &mut rng).unwrap();
        assert_eq!(cohort, vec![1, 3, 5, 7], "High tier fills the cohort");
        // A profiled Low device still outranks an integrity-only one.
        struct MixedDir;
        impl ClientDirectory for MixedDir {
            fn caps_of(&self, _c: u64) -> Option<DeviceCaps> {
                let mut caps = DeviceCaps::default();
                caps.tier = IntegrityTier::Strong; // best integrity rank
                Some(caps)
            }
            fn profile_of(&self, c: u64) -> Option<DeviceProfile> {
                (c == 2).then(|| DeviceProfile {
                    compute_tier: crate::proto::ComputeTier::Low,
                    ..Default::default()
                })
            }
        }
        let cohort = Tiered
            .form(&ctx(&[1, 2], 1, 1, 0, &MixedDir), &mut rng)
            .unwrap();
        assert_eq!(cohort, vec![2], "session profile beats integrity-only rank");
    }

    #[test]
    fn tiered_prefers_strong_devices() {
        let mut rng = Rng::new(3);
        let dir = TierDir;
        let pool: Vec<u64> = (1..=8).collect(); // 1..=3 Strong, 4..=8 Basic
        let cohort = Tiered.form(&ctx(&pool, 3, 3, 0, &dir), &mut rng).unwrap();
        assert_eq!(cohort, vec![1, 2, 3]);
        // With target 5 the two extra slots come from the Basic tier.
        let cohort = Tiered.form(&ctx(&pool, 5, 5, 0, &dir), &mut rng).unwrap();
        assert_eq!(cohort.len(), 5);
        assert!(cohort.contains(&1) && cohort.contains(&2) && cohort.contains(&3));
    }

    #[test]
    fn over_provision_spawns_extra_when_pool_allows() {
        let mut rng = Rng::new(4);
        let dir = NullDirectory;
        let pool: Vec<u64> = (1..=10).collect();
        let policy = OverProvision { spawn_factor: 1.5 };
        // ceil(4 × 1.5) = 6 drafted.
        let cohort = policy.form(&ctx(&pool, 4, 4, 0, &dir), &mut rng).unwrap();
        assert_eq!(cohort.len(), 6);
        // Pool smaller than desired but ≥ target: clamp to the pool.
        let cohort = policy
            .form(&ctx(&pool[..5], 4, 4, 0, &dir), &mut rng)
            .unwrap();
        assert_eq!(cohort.len(), 5);
        // Pool below target: still waits.
        assert!(policy.form(&ctx(&pool[..3], 4, 4, 0, &dir), &mut rng).is_none());
    }

    #[test]
    fn fixed_deadline_assessment() {
        let p = |cohort, reported, now_ms| RoundProgress {
            cohort,
            reported,
            now_ms,
            deadline_ms: 100,
            min_report_fraction: 0.5,
        };
        assert_eq!(FixedDeadline.assess(&p(4, 4, 10)), PacingDecision::Commit);
        assert_eq!(FixedDeadline.assess(&p(4, 2, 10)), PacingDecision::Wait);
        // Past the deadline: quorum (2 of 4) commits, below it fails.
        assert_eq!(FixedDeadline.assess(&p(4, 2, 100)), PacingDecision::Commit);
        assert_eq!(FixedDeadline.assess(&p(4, 1, 100)), PacingDecision::Fail);
        // Quorum is never below 1.
        assert_eq!(p(0, 0, 0).quorum(), 1);
    }

    #[test]
    fn goal_count_flushes_at_goal_and_never_fails() {
        let policy = GoalCount { goal: 3 };
        let p = |reported| RoundProgress {
            cohort: 3,
            reported,
            now_ms: 1_000_000,
            deadline_ms: 0, // long past — must not matter
            min_report_fraction: 1.0,
        };
        assert_eq!(policy.assess(&p(2)), PacingDecision::Wait);
        assert_eq!(policy.assess(&p(3)), PacingDecision::Commit);
        assert_eq!(policy.assess(&p(7)), PacingDecision::Commit);
    }
}
