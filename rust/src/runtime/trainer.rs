//! On-device training + server-side evaluation over the AOT artifacts.
//!
//! [`HloTrainer`] implements the SDK [`crate::client::Trainer`] trait: it
//! owns one device's data shard and Adam state, samples the paper's
//! "20% of the split" per round (~67 samples at batch 8 ≈ 8 local steps),
//! and executes the compiled `train_<preset>` artifact through the PJRT
//! runtime. [`HloEvaluator`] implements the management-side
//! [`crate::services::management::Evaluator`] over `eval_<preset>`.

use std::sync::Arc;

use crate::client::{TrainOutcome, Trainer};
use crate::config::ArtifactPreset;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::model::ModelSnapshot;
use crate::runtime::{EvalRequest, RuntimeHandle, TrainRequest};
use crate::services::management::Evaluator;
use crate::util::Rng;

/// Samples per-round minibatches from a device's shard.
pub struct ShardSampler {
    data: Arc<Dataset>,
    /// Indices into `data` owned by this device.
    shard: Vec<usize>,
    /// Fraction of the shard used per round (paper: 0.2).
    pub fraction: f64,
    rng: Rng,
}

impl ShardSampler {
    pub fn new(data: Arc<Dataset>, shard: Vec<usize>, fraction: f64, seed: u64) -> ShardSampler {
        assert!(!shard.is_empty(), "empty shard");
        ShardSampler {
            data,
            shard,
            fraction,
            rng: Rng::new(seed),
        }
    }

    /// Draw k batches of size b: (tokens i32[k*b*T], labels i32[k*b], count).
    pub fn sample(&mut self, k: usize, b: usize) -> (Vec<i32>, Vec<i32>, usize) {
        let t = self.data.seq_len;
        let want = ((self.shard.len() as f64 * self.fraction).round() as usize)
            .clamp(1, self.shard.len());
        let need = k * b;
        let mut tokens = Vec::with_capacity(need * t);
        let mut labels = Vec::with_capacity(need);
        // Choose `want` distinct examples, then cycle them to fill k*b
        // (paper uses ~67 samples for 8×8=64 slots; ours cycles if short).
        let chosen = self.rng.sample_indices(self.shard.len(), want);
        for i in 0..need {
            let idx = self.shard[chosen[i % chosen.len()]];
            tokens.extend_from_slice(self.data.row(idx));
            labels.push(self.data.labels[idx]);
        }
        (tokens, labels, want.min(need))
    }
}

/// Device-side trainer over the compiled train artifact.
pub struct HloTrainer {
    rt: RuntimeHandle,
    preset: ArtifactPreset,
    sampler: ShardSampler,
    /// Client-held Adam state (persists across rounds, never uploaded).
    m: Vec<f32>,
    v: Vec<f32>,
    step: f32,
    /// Last round's mean training accuracy (observability).
    pub last_acc: f64,
}

impl HloTrainer {
    pub fn new(rt: RuntimeHandle, preset: ArtifactPreset, sampler: ShardSampler) -> HloTrainer {
        let p = preset.param_count;
        HloTrainer {
            rt,
            preset,
            sampler,
            m: vec![0.0; p],
            v: vec![0.0; p],
            step: 0.0,
            last_acc: 0.0,
        }
    }
}

impl Trainer for HloTrainer {
    fn train(
        &mut self,
        model: &ModelSnapshot,
        _round: u64,
        lr: f32,
        prox_mu: f32,
    ) -> Result<TrainOutcome> {
        if model.dim() != self.preset.param_count {
            return Err(Error::Model(format!(
                "model dim {} != artifact {}",
                model.dim(),
                self.preset.param_count
            )));
        }
        let (tokens, labels, n_examples) =
            self.sampler.sample(self.preset.local_steps, self.preset.batch);
        let resp = self.rt.train(TrainRequest {
            preset: self.preset.name.clone(),
            params: model.params.clone(),
            m: std::mem::take(&mut self.m),
            v: std::mem::take(&mut self.v),
            step: self.step,
            tokens,
            labels,
            lr,
            prox_mu,
            anchor: model.params.clone(),
        })?;
        self.m = resp.m;
        self.v = resp.v;
        self.step = resp.step;
        let k = resp.losses.len().max(1);
        let loss = resp.losses.iter().map(|&l| l as f64).sum::<f64>() / k as f64;
        self.last_acc = resp.accs.iter().map(|&a| a as f64).sum::<f64>() / k as f64;
        Ok(TrainOutcome {
            new_params: resp.params,
            weight: n_examples as f64,
            loss,
        })
    }
}

/// Server-side evaluator over the compiled eval artifact.
pub struct HloEvaluator {
    rt: RuntimeHandle,
    preset: ArtifactPreset,
    test: Arc<Dataset>,
    /// Max batches per evaluation (bounds server eval cost).
    pub max_batches: usize,
}

impl HloEvaluator {
    pub fn new(rt: RuntimeHandle, preset: ArtifactPreset, test: Arc<Dataset>) -> HloEvaluator {
        HloEvaluator {
            rt,
            preset,
            test,
            max_batches: 4,
        }
    }
}

impl Evaluator for HloEvaluator {
    fn evaluate(&self, preset: &str, params: &[f32]) -> Option<(f64, f64)> {
        if preset != self.preset.name || params.len() != self.preset.param_count {
            return None;
        }
        let b = self.preset.eval_batch;
        let t = self.test.seq_len;
        let n_batches = (self.test.len() / b).min(self.max_batches).max(1);
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;
        for i in 0..n_batches {
            let mut tokens = Vec::with_capacity(b * t);
            let mut labels = Vec::with_capacity(b);
            for j in 0..b {
                let idx = (i * b + j) % self.test.len();
                tokens.extend_from_slice(self.test.row(idx));
                labels.push(self.test.labels[idx]);
            }
            match self.rt.eval(EvalRequest {
                preset: preset.to_string(),
                params: params.to_vec(),
                tokens,
                labels,
            }) {
                Ok((l, a)) => {
                    loss_sum += l;
                    acc_sum += a;
                }
                Err(e) => {
                    log::warn!("eval failed: {e}");
                    return None;
                }
            }
        }
        Some((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SpamCorpus, SpamCorpusConfig};

    fn tiny_data() -> (Arc<Dataset>, Vec<Vec<usize>>) {
        let mut cfg = SpamCorpusConfig::for_model(256, 32);
        cfg.n_train = 200;
        cfg.n_test = 40;
        let c = SpamCorpus::generate(&cfg, 4);
        (Arc::new(c.train), c.shards)
    }

    #[test]
    fn sampler_shapes_and_fraction() {
        let (data, shards) = tiny_data();
        let mut s = ShardSampler::new(Arc::clone(&data), shards[0].clone(), 0.2, 1);
        let (tokens, labels, n) = s.sample(2, 4);
        assert_eq!(tokens.len(), 2 * 4 * 32);
        assert_eq!(labels.len(), 8);
        assert_eq!(n, 8.min((shards[0].len() as f64 * 0.2).round() as usize).max(1).min(8));
    }

    #[test]
    fn sampler_draws_within_shard() {
        let (data, shards) = tiny_data();
        let shard = shards[1].clone();
        let mut s = ShardSampler::new(Arc::clone(&data), shard.clone(), 1.0, 2);
        let (tokens, _, _) = s.sample(1, 4);
        // Every sampled row must equal some row in the shard.
        for chunk in tokens.chunks(32) {
            assert!(shard.iter().any(|&i| data.row(i) == chunk));
        }
    }

    #[test]
    fn sampler_varies_between_rounds() {
        let (data, shards) = tiny_data();
        let mut s = ShardSampler::new(data, shards[0].clone(), 0.5, 3);
        let (a, _, _) = s.sample(2, 4);
        let (b, _, _) = s.sample(2, 4);
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_shard_panics() {
        let (data, _) = tiny_data();
        let _ = ShardSampler::new(data, vec![], 0.2, 1);
    }
}
