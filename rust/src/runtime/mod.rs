//! PJRT runtime: loads the AOT artifacts (HLO text lowered from the L2
//! JAX model + L1 Pallas kernels) and executes them natively.
//!
//! Python never runs here — `artifacts/*.hlo.txt` were produced once by
//! `make artifacts`; this module parses the HLO text, compiles it on the
//! PJRT CPU client, and serves train/eval executions to the platform.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so
//! all PJRT interaction is confined to dedicated worker threads; the rest
//! of the platform talks to them through the cloneable [`RuntimeHandle`].
//! One worker per core is the right default — the PJRT CPU backend
//! parallelizes internally.

pub mod trainer;

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use crate::config::Manifest;
use crate::error::{Error, Result};

pub use trainer::{HloEvaluator, HloTrainer, ShardSampler};

/// A local-training execution request (mirrors the train artifact ABI:
/// see python/compile/model.py `make_train_fn`).
#[derive(Clone, Debug)]
pub struct TrainRequest {
    pub preset: String,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
    /// i32[k, B, T] flattened.
    pub tokens: Vec<i32>,
    /// i32[k, B] flattened.
    pub labels: Vec<i32>,
    pub lr: f32,
    pub prox_mu: f32,
    pub anchor: Vec<f32>,
}

/// Result of k local steps.
#[derive(Clone, Debug)]
pub struct TrainResponse {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
    /// Per-step losses/accuracies (length k).
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
}

/// Evaluation request (one batch).
#[derive(Clone, Debug)]
pub struct EvalRequest {
    pub preset: String,
    pub params: Vec<f32>,
    /// i32[B_eval, T] flattened.
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
}

enum Job {
    Train(TrainRequest, Sender<Result<TrainResponse>>),
    Eval(EvalRequest, Sender<Result<(f64, f64)>>),
    Shutdown,
}

/// Cloneable handle to the runtime worker pool.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Job>,
}

impl RuntimeHandle {
    pub fn train(&self, req: TrainRequest) -> Result<TrainResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(Job::Train(req, tx))
            .map_err(|_| Error::Runtime("runtime worker gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime worker dropped reply".into()))?
    }

    pub fn eval(&self, req: EvalRequest) -> Result<(f64, f64)> {
        let (tx, rx) = channel();
        self.tx
            .send(Job::Eval(req, tx))
            .map_err(|_| Error::Runtime("runtime worker gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime worker dropped reply".into()))?
    }
}

/// The runtime: spawns PJRT worker threads and hands out handles.
pub struct Runtime {
    workers: Vec<thread::JoinHandle<()>>,
    handles: Vec<RuntimeHandle>,
    next: Mutex<usize>,
}

impl Runtime {
    /// Spawn `n_workers` PJRT worker threads over the given manifest.
    pub fn new(manifest: Manifest, n_workers: usize) -> Result<Arc<Runtime>> {
        let n = n_workers.max(1);
        let mut workers = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<Job>();
            let man = manifest.clone();
            let jh = thread::Builder::new()
                .name(format!("pjrt-worker-{i}"))
                .spawn(move || worker_main(man, rx))
                .map_err(Error::Io)?;
            workers.push(jh);
            handles.push(RuntimeHandle { tx });
        }
        Ok(Arc::new(Runtime {
            workers,
            handles,
            next: Mutex::new(0),
        }))
    }

    /// Round-robin handle.
    pub fn handle(&self) -> RuntimeHandle {
        let mut g = self.next.lock().unwrap();
        let h = self.handles[*g % self.handles.len()].clone();
        *g += 1;
        h
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        for h in &self.handles {
            let _ = h.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side (owns the !Send PJRT objects)
// ---------------------------------------------------------------------------

struct CompiledPreset {
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    param_count: usize,
    local_steps: usize,
    batch: usize,
    eval_batch: usize,
    seq_len: usize,
}

fn worker_main(manifest: Manifest, rx: std::sync::mpsc::Receiver<Job>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            log::error!("pjrt worker failed to start client: {e}");
            return;
        }
    };
    let mut compiled: HashMap<String, CompiledPreset> = HashMap::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Train(req, reply) => {
                let r = get_preset(&client, &manifest, &mut compiled, &req.preset)
                    .and_then(|p| run_train(p, &req));
                let _ = reply.send(r);
            }
            Job::Eval(req, reply) => {
                let r = get_preset(&client, &manifest, &mut compiled, &req.preset)
                    .and_then(|p| run_eval(p, &req));
                let _ = reply.send(r);
            }
        }
    }
}

fn get_preset<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    compiled: &'a mut HashMap<String, CompiledPreset>,
    name: &str,
) -> Result<&'a CompiledPreset> {
    if !compiled.contains_key(name) {
        let p = manifest.preset(name)?;
        // florida-lint: allow(wall-clock-in-core): one-shot compile timing for a log line
        let t0 = std::time::Instant::now();
        let train = compile_hlo(client, &manifest.path_of(&p.train_path))?;
        let eval = compile_hlo(client, &manifest.path_of(&p.eval_path))?;
        log::info!(
            "pjrt: compiled preset {name} (P={}) in {:.1}s",
            p.param_count,
            t0.elapsed().as_secs_f64()
        );
        compiled.insert(
            name.to_string(),
            CompiledPreset {
                train,
                eval,
                param_count: p.param_count,
                local_steps: p.local_steps,
                batch: p.batch,
                eval_batch: p.eval_batch,
                seq_len: p.seq_len,
            },
        );
    }
    Ok(&compiled[name])
}

fn compile_hlo(client: &xla::PjRtClient, path: &str) -> Result<xla::PjRtLoadedExecutable> {
    // HLO TEXT is the interchange format — see DESIGN.md / aot.py: the
    // text parser reassigns instruction ids, avoiding the 64-bit-id protos
    // jax >= 0.5 emits (rejected by xla_extension 0.5.1).
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn run_train(p: &CompiledPreset, req: &TrainRequest) -> Result<TrainResponse> {
    let pc = p.param_count;
    for (name, v) in [
        ("params", &req.params),
        ("m", &req.m),
        ("v", &req.v),
        ("anchor", &req.anchor),
    ] {
        if v.len() != pc {
            return Err(Error::Runtime(format!("{name} dim {} != {pc}", v.len())));
        }
    }
    let (k, b, t) = (p.local_steps as i64, p.batch as i64, p.seq_len as i64);
    if req.tokens.len() != (k * b * t) as usize || req.labels.len() != (k * b) as usize {
        return Err(Error::Runtime(format!(
            "tokens/labels shape mismatch: {} vs {}, {} vs {}",
            req.tokens.len(),
            k * b * t,
            req.labels.len(),
            k * b
        )));
    }
    let args = [
        xla::Literal::vec1(&req.params),
        xla::Literal::vec1(&req.m),
        xla::Literal::vec1(&req.v),
        xla::Literal::scalar(req.step),
        xla::Literal::vec1(&req.tokens).reshape(&[k, b, t])?,
        xla::Literal::vec1(&req.labels).reshape(&[k, b])?,
        xla::Literal::scalar(req.lr),
        xla::Literal::scalar(req.prox_mu),
        xla::Literal::vec1(&req.anchor),
    ];
    let result = p.train.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    if parts.len() != 6 {
        return Err(Error::Runtime(format!("train tuple arity {}", parts.len())));
    }
    let mut it = parts.into_iter();
    let params = it.next().unwrap().to_vec::<f32>()?;
    let m = it.next().unwrap().to_vec::<f32>()?;
    let v = it.next().unwrap().to_vec::<f32>()?;
    let step: f32 = it.next().unwrap().get_first_element()?;
    let losses = it.next().unwrap().to_vec::<f32>()?;
    let accs = it.next().unwrap().to_vec::<f32>()?;
    Ok(TrainResponse {
        params,
        m,
        v,
        step,
        losses,
        accs,
    })
}

fn run_eval(p: &CompiledPreset, req: &EvalRequest) -> Result<(f64, f64)> {
    let (b, t) = (p.eval_batch as i64, p.seq_len as i64);
    if req.params.len() != p.param_count
        || req.tokens.len() != (b * t) as usize
        || req.labels.len() != b as usize
    {
        return Err(Error::Runtime("eval shape mismatch".into()));
    }
    let args = [
        xla::Literal::vec1(&req.params),
        xla::Literal::vec1(&req.tokens).reshape(&[b, t])?,
        xla::Literal::vec1(&req.labels).reshape(&[b])?,
    ];
    let result = p.eval.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
    let (loss, acc) = result.to_tuple2()?;
    let loss: f32 = loss.get_first_element()?;
    let acc: f32 = acc.get_first_element()?;
    Ok((loss as f64, acc as f64))
}
