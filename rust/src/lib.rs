//! Project Florida — reproduction of "Project Florida: Federated Learning
//! Made Easy" (Microsoft, 2023) as a three-layer rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate): the Florida platform, organised FLaaS-style
//! around a typed service router (`services::router`): four services —
//! registration, task orchestration, aggregation ingest, admin — are
//! dispatched through an ordered interceptor chain (auth → per-RPC
//! metrics → backpressure), and clients talk to them through typed
//! stubs (`client::FloridaClient`) generated over the `proto::rpc`
//! request/reply pairs, so protocol errors surface as `Err(Error::
//! Server)` instead of raw `Msg` pattern matches. Beneath the router,
//! the management service is a thin registry over per-task
//! `orchestrator::RoundEngine`s — typed phase state machines
//! parameterized by pluggable `CohortPolicy`/`PacingPolicy` seams,
//! administered through `TaskBuilder`/`TaskHandle` and observed through
//! the `TaskEvent` stream. Around them: the selection service,
//! two-stage secure aggregation (virtual groups + master aggregator),
//! authentication/attestation, the client SDK, transports, differential
//! privacy, and a multi-client device simulator. See
//! `docs/architecture.md` for the topology, the task lifecycle state
//! machine, and the policy seams.
//!
//! Layer 2 (python/compile/model.py, build-time only): the on-device
//! compute — a BERT-tiny-class transformer classifier fwd/bwd lowered via
//! `jax.jit(...).lower(...)` to HLO text artifacts.
//!
//! Layer 1 (python/compile/kernels/, build-time only): Pallas kernels for
//! the transformer hot spots (attention, fused MLP), lowered in interpret
//! mode into the same HLO.
//!
//! Python never runs on the request path: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and executes
//! them natively.

pub mod aggregation;
pub mod aggtree;
pub mod analysis;
pub mod client;
pub mod cli;
pub mod codec;
pub mod config;
pub mod crypto;
pub mod data;
pub mod dp;
pub mod error;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod orchestrator;
pub mod proto;
pub mod quant;
pub mod runtime;
pub mod secagg;
pub mod services;
pub mod shard;
pub mod simulator;
pub mod storage;
pub mod transport;
pub mod util;

pub use error::{Error, Result};
