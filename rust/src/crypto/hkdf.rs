//! HKDF-SHA256 (RFC 5869): the cross-platform key derivation function.
//!
//! Paper §4.1: "Florida utilizes strong and cross-platform compatible key
//! derivation functions (KDFs) to ensure consistent mask generation even
//! across different device operating systems." Every simulated client —
//! whatever transport/codec it speaks — derives pairwise mask seeds with
//! exactly this function, so masks cancel bit-for-bit.

use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

/// HKDF-Extract: PRK = HMAC(salt, ikm).
pub fn extract(salt: &[u8], ikm: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(salt).expect("hmac accepts any key len");
    mac.update(ikm);
    let mut out = [0u8; 32];
    out.copy_from_slice(&mac.finalize().into_bytes());
    out
}

/// HKDF-Expand: OKM of `len` bytes from PRK and info.
pub fn expand(prk: &[u8; 32], info: &[u8], len: usize) -> Vec<u8> {
    assert!(len <= 255 * 32, "hkdf expand length limit");
    let mut okm = Vec::with_capacity(len);
    let mut t: Vec<u8> = Vec::new();
    let mut counter = 1u8;
    while okm.len() < len {
        let mut mac = <HmacSha256 as Mac>::new_from_slice(prk).unwrap();
        mac.update(&t);
        mac.update(info);
        mac.update(&[counter]);
        t = mac.finalize().into_bytes().to_vec();
        let take = (len - okm.len()).min(32);
        okm.extend_from_slice(&t[..take]);
        counter += 1;
    }
    okm
}

/// Extract-then-expand convenience.
pub fn derive(salt: &[u8], ikm: &[u8], info: &[u8], len: usize) -> Vec<u8> {
    expand(&extract(salt, ikm), info, len)
}

/// Derive a fixed 16-byte key (AES-128 mask PRG seed).
pub fn derive_key16(salt: &[u8], ikm: &[u8], info: &[u8]) -> [u8; 16] {
    let v = derive(salt, ikm, info, 16);
    let mut k = [0u8; 16];
    k.copy_from_slice(&v);
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 5869 Test Case 1.
    #[test]
    fn rfc5869_case1() {
        let ikm = hex::decode("0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b").unwrap();
        let salt = hex::decode("000102030405060708090a0b0c").unwrap();
        let info = hex::decode("f0f1f2f3f4f5f6f7f8f9").unwrap();
        let prk = extract(&salt, &ikm);
        assert_eq!(
            hex::encode(&prk),
            "077709362c2e32df0ddc3f0dc47bba6390b6c73bb50f9c3122ec844ad7c2b3e5"
        );
        let okm = expand(&prk, &info, 42);
        assert_eq!(
            hex::encode(&okm),
            "3cb25f25faacd57a90434f64d0362f2a2d2d0a90cf1a5a4c5db02d56ecc4c5bf34007208d5b887185865"
        );
    }

    // RFC 5869 Test Case 3 (empty salt/info).
    #[test]
    fn rfc5869_case3() {
        let ikm = hex::decode("0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b0b").unwrap();
        let okm = derive(&[], &ikm, &[], 42);
        assert_eq!(
            hex::encode(&okm),
            "8da4e775a563c18f715f802a063c5a31b8a11f5c5ee1879ec3454e5f3c738d2d9d201395faa4b61a96c8"
        );
    }

    #[test]
    fn different_info_different_keys() {
        let k1 = derive_key16(b"salt", b"secret", b"pair:1:2");
        let k2 = derive_key16(b"salt", b"secret", b"pair:1:3");
        assert_ne!(k1, k2);
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            derive_key16(b"s", b"i", b"x"),
            derive_key16(b"s", b"i", b"x")
        );
    }
}
