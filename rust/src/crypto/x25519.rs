//! X25519 Diffie–Hellman (RFC 7748), implemented from scratch.
//!
//! Field arithmetic over GF(2²⁵⁵ − 19) with 51-bit limbs (u64×5, u128
//! products) and the constant-time Montgomery ladder. This is the pairwise
//! key-exchange primitive of the secure-aggregation protocol (§4.1):
//! every client advertises a public key; each pair derives the same shared
//! secret, which seeds the pairwise mask PRG via HKDF.
//!
//! Verified against the RFC 7748 test vectors in the unit tests below.

/// A field element mod 2^255-19, 5×51-bit limbs, loosely reduced.
#[derive(Clone, Copy, Debug)]
struct Fe([u64; 5]);

const MASK51: u64 = (1u64 << 51) - 1;

impl Fe {
    const ZERO: Fe = Fe([0; 5]);
    const ONE: Fe = Fe([1, 0, 0, 0, 0]);

    fn from_bytes(b: &[u8; 32]) -> Fe {
        let load = |i: usize| -> u64 {
            let mut v = [0u8; 8];
            v.copy_from_slice(&b[i..i + 8]);
            u64::from_le_bytes(v)
        };
        // 51-bit slices of the little-endian 255-bit integer.
        Fe([
            load(0) & MASK51,
            (load(6) >> 3) & MASK51,
            (load(12) >> 6) & MASK51,
            (load(19) >> 1) & MASK51,
            (load(24) >> 12) & MASK51,
        ])
    }

    fn to_bytes(self) -> [u8; 32] {
        // Fully carry so every limb < 2^51.
        let mut t = self.reduce_once().reduce_once().0;
        // Canonical freeze (ref10 trick): q = 1 iff t >= p, computed by
        // propagating the carry of t + 19 through the limbs.
        let mut q = (t[0].wrapping_add(19)) >> 51;
        q = (t[1] + q) >> 51;
        q = (t[2] + q) >> 51;
        q = (t[3] + q) >> 51;
        q = (t[4] + q) >> 51;
        // t = t + 19*q, then drop bit 255 — equivalent to t mod p.
        t[0] += 19 * q;
        t[1] += t[0] >> 51;
        t[0] &= MASK51;
        t[2] += t[1] >> 51;
        t[1] &= MASK51;
        t[3] += t[2] >> 51;
        t[2] &= MASK51;
        t[4] += t[3] >> 51;
        t[3] &= MASK51;
        t[4] &= MASK51; // discard 2^255
        let mut b = [0u8; 32];
        let mut acc: u128 = 0;
        let mut acc_bits = 0;
        let mut bi = 0;
        for &limb in &t {
            acc |= (limb as u128) << acc_bits;
            acc_bits += 51;
            while acc_bits >= 8 && bi < 32 {
                b[bi] = (acc & 0xff) as u8;
                acc >>= 8;
                acc_bits -= 8;
                bi += 1;
            }
        }
        while bi < 32 {
            b[bi] = (acc & 0xff) as u8;
            acc >>= 8;
            bi += 1;
        }
        b
    }

    fn reduce_once(self) -> Fe {
        let mut t = self.0;
        let mut c: u64;
        c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        c = t[1] >> 51;
        t[1] &= MASK51;
        t[2] += c;
        c = t[2] >> 51;
        t[2] &= MASK51;
        t[3] += c;
        c = t[3] >> 51;
        t[3] &= MASK51;
        t[4] += c;
        c = t[4] >> 51;
        t[4] &= MASK51;
        t[0] += c * 19;
        c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        Fe(t)
    }

    fn add(self, rhs: Fe) -> Fe {
        let a = self.0;
        let b = rhs.0;
        Fe([a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3], a[4] + b[4]]).reduce_once()
    }

    fn sub(self, rhs: Fe) -> Fe {
        // Add 2p to avoid underflow.
        let a = self.0;
        let b = rhs.0;
        Fe([
            a[0] + 0xFFFFFFFFFFFDA_u64 - b[0],
            a[1] + 0xFFFFFFFFFFFFE_u64 - b[1],
            a[2] + 0xFFFFFFFFFFFFE_u64 - b[2],
            a[3] + 0xFFFFFFFFFFFFE_u64 - b[3],
            a[4] + 0xFFFFFFFFFFFFE_u64 - b[4],
        ])
        .reduce_once()
    }

    fn mul(self, rhs: Fe) -> Fe {
        let a = self.0;
        let b = rhs.0;
        let a0 = a[0] as u128;
        let a1 = a[1] as u128;
        let a2 = a[2] as u128;
        let a3 = a[3] as u128;
        let a4 = a[4] as u128;
        let b0 = b[0] as u128;
        let b1 = b[1] as u128;
        let b2 = b[2] as u128;
        let b3 = b[3] as u128;
        let b4 = b[4] as u128;
        // Terms that wrap past 2^255 pick up a factor 19.
        let c0 = a0 * b0 + 19 * (a1 * b4 + a2 * b3 + a3 * b2 + a4 * b1);
        let c1 = a0 * b1 + a1 * b0 + 19 * (a2 * b4 + a3 * b3 + a4 * b2);
        let c2 = a0 * b2 + a1 * b1 + a2 * b0 + 19 * (a3 * b4 + a4 * b3);
        let c3 = a0 * b3 + a1 * b2 + a2 * b1 + a3 * b0 + 19 * (a4 * b4);
        let c4 = a0 * b4 + a1 * b3 + a2 * b2 + a3 * b1 + a4 * b0;
        Self::carry(c0, c1, c2, c3, c4)
    }

    fn square(self) -> Fe {
        self.mul(self)
    }

    fn carry(mut c0: u128, mut c1: u128, mut c2: u128, mut c3: u128, mut c4: u128) -> Fe {
        c1 += (c0 >> 51) as u128;
        c0 &= MASK51 as u128;
        c2 += (c1 >> 51) as u128;
        c1 &= MASK51 as u128;
        c3 += (c2 >> 51) as u128;
        c2 &= MASK51 as u128;
        c4 += (c3 >> 51) as u128;
        c3 &= MASK51 as u128;
        c0 += 19 * ((c4 >> 51) as u128);
        c4 &= MASK51 as u128;
        c1 += (c0 >> 51) as u128;
        c0 &= MASK51 as u128;
        Fe([c0 as u64, c1 as u64, c2 as u64, c3 as u64, c4 as u64])
    }

    fn mul_small(self, k: u64) -> Fe {
        let a = self.0;
        let k = k as u128;
        Self::carry(
            a[0] as u128 * k,
            a[1] as u128 * k,
            a[2] as u128 * k,
            a[3] as u128 * k,
            a[4] as u128 * k,
        )
    }

    /// a^(p-2) — inverse via Fermat (standard 254-squaring addition chain).
    fn invert(self) -> Fe {
        let z = self;
        let z2 = z.square(); // 2
        let z9 = z2.square().square().mul(z); // 9
        let z11 = z9.mul(z2); // 11
        let z2_5_0 = z11.square().mul(z9); // 2^5 - 2^0 = 31
        let mut t = z2_5_0;
        for _ in 0..5 {
            t = t.square();
        }
        let z2_10_0 = t.mul(z2_5_0);
        t = z2_10_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z2_20_0 = t.mul(z2_10_0);
        t = z2_20_0;
        for _ in 0..20 {
            t = t.square();
        }
        let z2_40_0 = t.mul(z2_20_0);
        t = z2_40_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z2_50_0 = t.mul(z2_10_0);
        t = z2_50_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z2_100_0 = t.mul(z2_50_0);
        t = z2_100_0;
        for _ in 0..100 {
            t = t.square();
        }
        let z2_200_0 = t.mul(z2_100_0);
        t = z2_200_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z2_250_0 = t.mul(z2_50_0);
        t = z2_250_0;
        for _ in 0..5 {
            t = t.square();
        }
        t.mul(z11)
    }

    /// Constant-time conditional swap.
    fn cswap(swap: u64, a: &mut Fe, b: &mut Fe) {
        let mask = 0u64.wrapping_sub(swap); // 0 or all-ones
        for i in 0..5 {
            let x = mask & (a.0[i] ^ b.0[i]);
            a.0[i] ^= x;
            b.0[i] ^= x;
        }
    }
}

/// Scalar multiplication: RFC 7748 X25519 function.
pub fn x25519(scalar: &[u8; 32], u: &[u8; 32]) -> [u8; 32] {
    // Clamp.
    let mut k = *scalar;
    k[0] &= 248;
    k[31] &= 127;
    k[31] |= 64;
    // Mask the high bit of u per RFC.
    let mut ub = *u;
    ub[31] &= 127;

    let x1 = Fe::from_bytes(&ub);
    let mut x2 = Fe::ONE;
    let mut z2 = Fe::ZERO;
    let mut x3 = x1;
    let mut z3 = Fe::ONE;
    let mut swap = 0u64;

    for t in (0..255).rev() {
        let kt = ((k[t >> 3] >> (t & 7)) & 1) as u64;
        swap ^= kt;
        Fe::cswap(swap, &mut x2, &mut x3);
        Fe::cswap(swap, &mut z2, &mut z3);
        swap = kt;

        let a = x2.add(z2);
        let aa = a.square();
        let b = x2.sub(z2);
        let bb = b.square();
        let e = aa.sub(bb);
        let c = x3.add(z3);
        let d = x3.sub(z3);
        let da = d.mul(a);
        let cb = c.mul(b);
        x3 = da.add(cb).square();
        z3 = x1.mul(da.sub(cb).square());
        x2 = aa.mul(bb);
        z2 = e.mul(aa.add(e.mul_small(121665)));
    }
    Fe::cswap(swap, &mut x2, &mut x3);
    Fe::cswap(swap, &mut z2, &mut z3);

    x2.mul(z2.invert()).to_bytes()
}

/// The curve base point u=9.
pub const BASEPOINT: [u8; 32] = {
    let mut b = [0u8; 32];
    b[0] = 9;
    b
};

/// X25519 public key (the u-coordinate).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct PublicKey(pub [u8; 32]);

/// Shared secret from Diffie–Hellman.
#[derive(Clone, Copy)]
pub struct SharedSecret(pub [u8; 32]);

/// An X25519 key pair.
#[derive(Clone)]
pub struct KeyPair {
    secret: [u8; 32],
    public: PublicKey,
}

impl KeyPair {
    /// Derive a key pair from 32 bytes of seed material.
    pub fn from_seed(seed: [u8; 32]) -> KeyPair {
        let public = PublicKey(x25519(&seed, &BASEPOINT));
        KeyPair {
            secret: seed,
            public,
        }
    }

    /// Generate from a (non-crypto) RNG — acceptable for the simulated
    /// fleet; a production device would use the OS CSPRNG.
    pub fn generate(rng: &mut crate::util::Rng) -> KeyPair {
        let mut seed = [0u8; 32];
        for chunk in seed.chunks_mut(8) {
            chunk.copy_from_slice(&rng.next_u64().to_le_bytes()[..chunk.len()]);
        }
        Self::from_seed(seed)
    }

    pub fn public(&self) -> PublicKey {
        self.public
    }

    /// The raw seed — what secure aggregation Shamir-shares for dropout
    /// recovery (§4.1): reconstructing it rebuilds the full keypair.
    pub fn seed_bytes(&self) -> [u8; 32] {
        self.secret
    }

    /// Diffie–Hellman agreement with a peer public key.
    pub fn agree(&self, peer: &PublicKey) -> SharedSecret {
        SharedSecret(x25519(&self.secret, &peer.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex32(s: &str) -> [u8; 32] {
        let v = crate::util::hex::decode(s).unwrap();
        let mut b = [0u8; 32];
        b.copy_from_slice(&v);
        b
    }

    #[test]
    fn rfc7748_vector_1() {
        let k = hex32("a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4");
        let u = hex32("e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c");
        let want = hex32("c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552");
        assert_eq!(x25519(&k, &u), want);
    }

    #[test]
    fn rfc7748_vector_2() {
        let k = hex32("4b66e9d4d1b4673c5ad22691957d6af5c11b6421e0ea01d42ca4169e7918ba0d");
        let u = hex32("e5210f12786811d3f4b7959d0538ae2c31dbe7106fc03c3efc4cd549c715a493");
        let want = hex32("95cbde9476e8907d7aade45cb4b873f88b595a68799fa152e6f8f7647aac7957");
        assert_eq!(x25519(&k, &u), want);
    }

    #[test]
    fn rfc7748_alice_bob() {
        let a_priv = hex32("77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a");
        let b_priv = hex32("5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb");
        let a_pub = x25519(&a_priv, &BASEPOINT);
        let b_pub = x25519(&b_priv, &BASEPOINT);
        assert_eq!(
            a_pub,
            hex32("8520f0098930a754748b7ddcb43ef75a0dbf3a0d26381af4eba4a98eaa9b4e6a")
        );
        assert_eq!(
            b_pub,
            hex32("de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f")
        );
        let shared = hex32("4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742");
        assert_eq!(x25519(&a_priv, &b_pub), shared);
        assert_eq!(x25519(&b_priv, &a_pub), shared);
    }

    #[test]
    fn dh_agreement_symmetry_many() {
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..8 {
            let a = KeyPair::generate(&mut rng);
            let b = KeyPair::generate(&mut rng);
            assert_eq!(a.agree(&b.public()).0, b.agree(&a.public()).0);
            let c = KeyPair::generate(&mut rng);
            assert_ne!(a.agree(&b.public()).0, a.agree(&c.public()).0);
        }
    }

    #[test]
    fn iterated_vector_1k() {
        // RFC 7748 §5.2 iteration test (1,000 iterations).
        let mut k = hex32("0900000000000000000000000000000000000000000000000000000000000000");
        let mut u = k;
        for _ in 0..1000 {
            let r = x25519(&k, &u);
            u = k;
            k = r;
        }
        assert_eq!(
            k,
            hex32("684cf59ba83309552800ef566f2f4d3c1c3887c49360e3875f2eb94d99532c51")
        );
    }
}
