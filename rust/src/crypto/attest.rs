//! Simulated device-integrity attestation (§3.1.5).
//!
//! The paper validates Google Play Integrity / Huawei SysIntegrity
//! verdicts issued by a trusted third party. Offline, we simulate that
//! third party as an "integrity authority" holding an HMAC key: devices
//! obtain signed verdicts (device id, tier, nonce, expiry), and the
//! Authentication Service verifies signature, nonce freshness, and expiry
//! before admitting the device. This exercises the same admission path.

use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

/// Integrity tier reported by the (simulated) authority.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum IntegrityTier {
    /// Basic device integrity only.
    Basic = 0,
    /// Device passes full integrity checks.
    Device = 1,
    /// Hardware-backed strong integrity.
    Strong = 2,
}

impl IntegrityTier {
    pub fn from_u8(v: u8) -> Option<IntegrityTier> {
        match v {
            0 => Some(IntegrityTier::Basic),
            1 => Some(IntegrityTier::Device),
            2 => Some(IntegrityTier::Strong),
            _ => None,
        }
    }
}

/// A signed attestation verdict, presented by the device at registration.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    pub device_id: String,
    pub tier: IntegrityTier,
    pub nonce: u64,
    /// Expiry, milliseconds since the platform epoch.
    pub expires_ms: u64,
    pub sig: [u8; 32],
}

/// The simulated trusted authority (e.g. Play Integrity back end).
pub struct Authority {
    key: Vec<u8>,
}

impl Authority {
    pub fn new(key: &[u8]) -> Authority {
        Authority { key: key.to_vec() }
    }

    fn mac(&self, device_id: &str, tier: IntegrityTier, nonce: u64, expires_ms: u64) -> [u8; 32] {
        let mut m = <HmacSha256 as Mac>::new_from_slice(&self.key).unwrap();
        m.update(device_id.as_bytes());
        m.update(&[tier as u8]);
        m.update(&nonce.to_le_bytes());
        m.update(&expires_ms.to_le_bytes());
        let mut out = [0u8; 32];
        out.copy_from_slice(&m.finalize().into_bytes());
        out
    }

    /// Issue a verdict for a device (authority side).
    pub fn issue(
        &self,
        device_id: &str,
        tier: IntegrityTier,
        nonce: u64,
        expires_ms: u64,
    ) -> Verdict {
        Verdict {
            device_id: device_id.to_string(),
            tier,
            nonce,
            expires_ms,
            sig: self.mac(device_id, tier, nonce, expires_ms),
        }
    }

    /// Verify a verdict's signature (verifier side; constant-time compare).
    pub fn verify(&self, v: &Verdict) -> bool {
        use subtle::ConstantTimeEq;
        let want = self.mac(&v.device_id, v.tier, v.nonce, v.expires_ms);
        want.ct_eq(&v.sig).into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_verify_roundtrip() {
        let auth = Authority::new(b"integrity-authority-key");
        let v = auth.issue("device-1", IntegrityTier::Device, 42, 1_000_000);
        assert!(auth.verify(&v));
    }

    #[test]
    fn tampering_detected() {
        let auth = Authority::new(b"k");
        let mut v = auth.issue("device-1", IntegrityTier::Strong, 1, 99);
        v.device_id = "device-2".into();
        assert!(!auth.verify(&v));

        let mut v2 = auth.issue("device-1", IntegrityTier::Basic, 1, 99);
        v2.tier = IntegrityTier::Strong; // tier upgrade forgery
        assert!(!auth.verify(&v2));

        let mut v3 = auth.issue("device-1", IntegrityTier::Basic, 1, 99);
        v3.expires_ms = u64::MAX; // expiry extension forgery
        assert!(!auth.verify(&v3));
    }

    #[test]
    fn wrong_authority_key_rejected() {
        let a = Authority::new(b"key-a");
        let b = Authority::new(b"key-b");
        let v = a.issue("d", IntegrityTier::Device, 7, 10);
        assert!(!b.verify(&v));
    }

    #[test]
    fn tier_ordering_supports_criteria() {
        assert!(IntegrityTier::Strong > IntegrityTier::Device);
        assert!(IntegrityTier::Device > IntegrityTier::Basic);
        assert_eq!(IntegrityTier::from_u8(1), Some(IntegrityTier::Device));
        assert_eq!(IntegrityTier::from_u8(9), None);
    }
}
