//! AES-128-CTR pseudo-random generator for secure-aggregation masks.
//!
//! §4.1: pairwise clients negotiate only a shared secret and must *expand*
//! it locally into a mask of the model's dimension, applied with modular
//! integer arithmetic. The expansion must be identical across platforms —
//! here it is AES-128 in counter mode keyed by an HKDF-derived key,
//! interpreted as a little-endian u32 stream.

use aes::Aes128;
use cipher::generic_array::GenericArray;
use cipher::{BlockEncrypt, KeyInit};

/// Deterministic u32 mask stream from a 16-byte seed.
pub struct MaskPrg {
    cipher: Aes128,
    counter: u64,
    buf: [u8; 16],
    used: usize,
}

impl MaskPrg {
    pub fn new(key: [u8; 16]) -> MaskPrg {
        MaskPrg {
            cipher: Aes128::new(GenericArray::from_slice(&key)),
            counter: 0,
            buf: [0u8; 16],
            used: 16,
        }
    }

    #[inline]
    fn refill(&mut self) {
        let mut block = [0u8; 16];
        block[..8].copy_from_slice(&self.counter.to_le_bytes());
        let ga = GenericArray::from_mut_slice(&mut block);
        self.cipher.encrypt_block(ga);
        self.buf = block;
        self.counter += 1;
        self.used = 0;
    }

    /// Next pseudo-random u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.used + 4 > 16 {
            self.refill();
        }
        let v = u32::from_le_bytes(self.buf[self.used..self.used + 4].try_into().unwrap());
        self.used += 4;
        v
    }

    /// Fill a u32 mask vector of length `n`.
    pub fn mask_vec(&mut self, n: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n);
        // Whole blocks: 4 words per AES block.
        let mut block = [0u8; 16];
        while out.len() + 4 <= n {
            block[..8].copy_from_slice(&self.counter.to_le_bytes());
            block[8..].fill(0);
            let ga = GenericArray::from_mut_slice(&mut block);
            self.cipher.encrypt_block(ga);
            self.counter += 1;
            out.push(u32::from_le_bytes(block[0..4].try_into().unwrap()));
            out.push(u32::from_le_bytes(block[4..8].try_into().unwrap()));
            out.push(u32::from_le_bytes(block[8..12].try_into().unwrap()));
            out.push(u32::from_le_bytes(block[12..16].try_into().unwrap()));
        }
        while out.len() < n {
            out.push(self.next_u32());
        }
        out
    }

    /// Add (+1) or subtract (−1) this PRG's mask into `acc` mod 2³².
    /// The pairwise cancellation of §4.1 relies on one side adding and the
    /// other subtracting the *same* stream.
    ///
    /// §Perf: the keystream is applied block-by-block straight out of the
    /// cipher (8 blocks per batch for ILP) — no intermediate mask vector
    /// is materialised. This is the client-side per-peer hot loop.
    pub fn apply_mask(&mut self, acc: &mut [u32], sign: i32) {
        debug_assert!(sign == 1 || sign == -1);
        const BATCH: usize = 8; // blocks encrypted per round-trip
        let mut blocks = [[0u8; 16]; BATCH];
        let mut i = 0;
        let n = acc.len();
        while i + 4 * BATCH <= n {
            for b in blocks.iter_mut() {
                b[..8].copy_from_slice(&self.counter.to_le_bytes());
                b[8..].fill(0);
                self.counter += 1;
            }
            // Batch encryption exposes instruction-level parallelism in
            // the AES rounds (pipelined AES-NI units). GenericArray<u8,U16>
            // is layout-identical to [u8; 16].
            let gas: &mut [cipher::generic_array::GenericArray<u8, cipher::consts::U16>] = unsafe {
                std::slice::from_raw_parts_mut(
                    blocks.as_mut_ptr()
                        as *mut cipher::generic_array::GenericArray<u8, cipher::consts::U16>,
                    BATCH,
                )
            };
            self.cipher.encrypt_blocks(gas);
            for b in blocks.iter() {
                for j in 0..4 {
                    let m = u32::from_le_bytes(b[4 * j..4 * j + 4].try_into().unwrap());
                    acc[i] = if sign == 1 {
                        acc[i].wrapping_add(m)
                    } else {
                        acc[i].wrapping_sub(m)
                    };
                    i += 1;
                }
            }
        }
        while i < n {
            let m = self.next_u32();
            acc[i] = if sign == 1 {
                acc[i].wrapping_add(m)
            } else {
                acc[i].wrapping_sub(m)
            };
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = MaskPrg::new([7u8; 16]);
        let mut b = MaskPrg::new([7u8; 16]);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_keys_differ() {
        let mut a = MaskPrg::new([1u8; 16]);
        let mut b = MaskPrg::new([2u8; 16]);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn mask_vec_matches_word_stream() {
        let mut a = MaskPrg::new([9u8; 16]);
        let mut b = MaskPrg::new([9u8; 16]);
        let v = a.mask_vec(103); // odd length exercises the tail path
        let w: Vec<u32> = (0..103).map(|_| b.next_u32()).collect();
        assert_eq!(v, w);
    }

    #[test]
    fn apply_mask_matches_mask_vec_stream() {
        // The batched fast path must produce exactly the same stream as
        // mask_vec (cross-version/cross-platform mask compatibility).
        for n in [0usize, 1, 3, 31, 32, 33, 100, 257] {
            let mut acc = vec![0u32; n];
            MaskPrg::new([5u8; 16]).apply_mask(&mut acc, 1);
            let want = MaskPrg::new([5u8; 16]).mask_vec(n);
            assert_eq!(acc, want, "n={n}");
        }
    }

    #[test]
    fn masks_cancel_pairwise() {
        // u adds s_{u,v}, v subtracts the same stream → exact cancellation.
        let mut acc = vec![5u32, 10, 0xffff_ffff, 42];
        let key = [3u8; 16];
        MaskPrg::new(key).apply_mask(&mut acc, 1);
        MaskPrg::new(key).apply_mask(&mut acc, -1);
        assert_eq!(acc, vec![5, 10, 0xffff_ffff, 42]);
    }

    #[test]
    fn rough_uniformity() {
        let mut p = MaskPrg::new([11u8; 16]);
        let n = 50_000;
        let ones: u32 = (0..n).map(|_| p.next_u32().count_ones()).sum();
        let frac = ones as f64 / (n as f64 * 32.0);
        assert!((frac - 0.5).abs() < 0.01, "bit bias {frac}");
    }
}
