//! Cryptographic substrate for secure aggregation and attestation (§4.1).
//!
//! The paper's SDK contribution includes *mutually compatible key
//! derivation across heterogeneous platforms*; this module is the single
//! implementation all simulated "platforms" share:
//!
//! * [`x25519`] — Diffie–Hellman on Curve25519 (RFC 7748), from scratch
//!   (the offline crate set has no curve library).
//! * [`hkdf`] — HKDF-SHA256 (RFC 5869) over the `hmac`/`sha2` crates.
//! * [`prg`] — AES-128-CTR pseudo-random generator expanding a pairwise
//!   shared secret into a mask over ℤ_{2³²} vectors.
//! * [`shamir`] — t-of-n secret sharing over GF(2⁸) for dropout recovery.
//! * [`attest`] — HMAC-signed device-integrity verdicts (the simulated
//!   Play-Integrity / SysIntegrity authority).

pub mod attest;
pub mod hkdf;
pub mod prg;
pub mod shamir;
pub mod x25519;

pub use prg::MaskPrg;
pub use x25519::{KeyPair, PublicKey, SharedSecret};
