//! Shamir t-of-n secret sharing over GF(2⁸) — dropout recovery (§4.1).
//!
//! In the pairwise-mask protocol, if a client drops out after peers have
//! applied masks involving it, its mask seeds must be reconstructable by
//! the surviving quorum or the virtual-group sum is garbage. Each client
//! therefore secret-shares its DH seed among the VG; the Secure Aggregator
//! collects t shares from survivors to unmask a dropout's contributions.
//! (Bonawitz et al. 2016 — the scheme Florida's §4.1 references.)

/// GF(2^8) with the AES polynomial x^8+x^4+x^3+x+1 (0x11b).

#[inline]
fn gf_mul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    for _ in 0..8 {
        if b & 1 != 0 {
            p ^= a;
        }
        let hi = a & 0x80;
        a <<= 1;
        if hi != 0 {
            a ^= 0x1b;
        }
        b >>= 1;
    }
    p
}

fn gf_pow(mut a: u8, mut e: u32) -> u8 {
    let mut r = 1u8;
    while e > 0 {
        if e & 1 == 1 {
            r = gf_mul(r, a);
        }
        a = gf_mul(a, a);
        e >>= 1;
    }
    r
}

#[inline]
fn gf_inv(a: u8) -> u8 {
    assert!(a != 0, "no inverse of 0");
    gf_pow(a, 254) // a^(2^8-2)
}

/// One share: (x, y-vector) — x is the share index (1..=255).
#[derive(Clone, Debug, PartialEq)]
pub struct Share {
    pub x: u8,
    pub y: Vec<u8>,
}

/// Split `secret` into `n` shares with threshold `t` (any t reconstruct).
pub fn split(
    secret: &[u8],
    t: usize,
    n: usize,
    rng: &mut crate::util::Rng,
) -> Vec<Share> {
    assert!(t >= 1 && t <= n && n <= 255, "bad (t,n) = ({t},{n})");
    // One random polynomial of degree t-1 per secret byte; share i gets
    // the evaluations at x = i.
    let mut coeffs: Vec<Vec<u8>> = Vec::with_capacity(secret.len());
    for &s in secret {
        let mut c = vec![s];
        for _ in 1..t {
            c.push(rng.next_u32() as u8);
        }
        coeffs.push(c);
    }
    (1..=n as u8)
        .map(|x| {
            let y = coeffs
                .iter()
                .map(|c| {
                    // Horner in GF(2^8).
                    let mut acc = 0u8;
                    for &ci in c.iter().rev() {
                        acc = gf_mul(acc, x) ^ ci;
                    }
                    acc
                })
                .collect();
            Share { x, y }
        })
        .collect()
}

/// Reconstruct the secret from >= t shares (Lagrange at x=0).
pub fn reconstruct(shares: &[Share]) -> Result<Vec<u8>, String> {
    if shares.is_empty() {
        return Err("no shares".into());
    }
    let len = shares[0].y.len();
    if shares.iter().any(|s| s.y.len() != len) {
        return Err("inconsistent share lengths".into());
    }
    let mut xs: Vec<u8> = shares.iter().map(|s| s.x).collect();
    xs.sort_unstable();
    xs.dedup();
    if xs.len() != shares.len() {
        return Err("duplicate share indices".into());
    }
    let mut secret = vec![0u8; len];
    for (i, si) in shares.iter().enumerate() {
        // Lagrange basis at 0: prod_{j!=i} x_j / (x_j ^ x_i)  (GF: sub==xor)
        let mut num = 1u8;
        let mut den = 1u8;
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            num = gf_mul(num, sj.x);
            den = gf_mul(den, sj.x ^ si.x);
        }
        let l = gf_mul(num, gf_inv(den));
        for (k, &yk) in si.y.iter().enumerate() {
            secret[k] ^= gf_mul(l, yk);
        }
    }
    Ok(secret)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gf_field_axioms_spot() {
        // 1 is identity; a*inv(a)=1 for all nonzero a.
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
        }
        // commutativity sample
        assert_eq!(gf_mul(0x57, 0x83), gf_mul(0x83, 0x57));
        // known AES vector: 0x57 * 0x83 = 0xc1
        assert_eq!(gf_mul(0x57, 0x83), 0xc1);
    }

    #[test]
    fn roundtrip_with_exact_threshold() {
        let mut rng = Rng::new(1);
        let secret = b"x25519-seed-material-0123456789a".to_vec();
        let shares = split(&secret, 3, 5, &mut rng);
        assert_eq!(shares.len(), 5);
        let got = reconstruct(&shares[..3]).unwrap();
        assert_eq!(got, secret);
        // Any other subset of 3 also works.
        let got = reconstruct(&[shares[1].clone(), shares[3].clone(), shares[4].clone()]).unwrap();
        assert_eq!(got, secret);
    }

    #[test]
    fn more_than_threshold_also_works() {
        let mut rng = Rng::new(2);
        let secret = vec![42u8; 16];
        let shares = split(&secret, 2, 4, &mut rng);
        assert_eq!(reconstruct(&shares).unwrap(), secret);
    }

    #[test]
    fn below_threshold_reveals_nothing_useful() {
        // With t-1 shares reconstruction gives the wrong value (w.h.p.) —
        // and information-theoretically each single share is uniform.
        let mut rng = Rng::new(3);
        let secret = vec![7u8; 8];
        let shares = split(&secret, 3, 5, &mut rng);
        let wrong = reconstruct(&shares[..2]).unwrap();
        assert_ne!(wrong, secret);
    }

    #[test]
    fn rejects_duplicates_and_empty() {
        let mut rng = Rng::new(4);
        let shares = split(b"s", 2, 3, &mut rng);
        assert!(reconstruct(&[]).is_err());
        assert!(reconstruct(&[shares[0].clone(), shares[0].clone()]).is_err());
    }

    #[test]
    fn one_of_one() {
        let mut rng = Rng::new(5);
        let shares = split(b"solo", 1, 1, &mut rng);
        assert_eq!(reconstruct(&shares).unwrap(), b"solo".to_vec());
    }
}
