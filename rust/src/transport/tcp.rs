//! TCP transport with 4-byte big-endian length framing.
//!
//! Exercises the real serialization path: partial reads, connection
//! lifecycle, and flow control. Used by the `serve` CLI mode and the
//! transport integration tests; the large-scale simulator uses `inproc`.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use super::{Connection, Dialer, Listener, MAX_FRAME};
use crate::error::{Error, Result};

const IO_TIMEOUT: Duration = Duration::from_secs(120);

/// A framed TCP connection.
pub struct TcpConn {
    stream: TcpStream,
    peer: String,
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<TcpConn> {
        stream
            .set_read_timeout(Some(IO_TIMEOUT))
            .and_then(|_| stream.set_write_timeout(Some(IO_TIMEOUT)))
            .and_then(|_| stream.set_nodelay(true))
            .map_err(Error::Io)?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        Ok(TcpConn { stream, peer })
    }
}

impl Connection for TcpConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if frame.len() > MAX_FRAME {
            return Err(Error::Transport(format!("frame {} > MAX_FRAME", frame.len())));
        }
        let len = (frame.len() as u32).to_be_bytes();
        self.stream.write_all(&len)?;
        self.stream.write_all(frame)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4)?;
        let len = u32::from_be_bytes(len4) as usize;
        if len > MAX_FRAME {
            return Err(Error::Transport(format!("incoming frame {len} > MAX_FRAME")));
        }
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Bound TCP listener.
pub struct TcpTransportListener {
    listener: TcpListener,
}

impl TcpTransportListener {
    /// Bind, e.g. "127.0.0.1:0" for an ephemeral port.
    pub fn bind(addr: &str) -> Result<TcpTransportListener> {
        Ok(TcpTransportListener {
            listener: TcpListener::bind(addr)?,
        })
    }
}

impl Listener for TcpTransportListener {
    fn accept(&self) -> Result<Box<dyn Connection>> {
        let (stream, _) = self.listener.accept()?;
        Ok(Box::new(TcpConn::new(stream)?))
    }

    fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default()
    }
}

/// TCP dialer.
pub struct TcpDialer;

impl Dialer for TcpDialer {
    fn dial(&self, addr: &str) -> Result<Box<dyn Connection>> {
        let stream = TcpStream::connect(addr)?;
        Ok(Box::new(TcpConn::new(stream)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn echo_roundtrip() {
        let l = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr();
        let server = thread::spawn(move || {
            let mut c = l.accept().unwrap();
            let f = c.recv().unwrap();
            c.send(&f).unwrap();
        });
        let mut c = TcpDialer.dial(&addr).unwrap();
        c.send(b"hello-tcp").unwrap();
        assert_eq!(c.recv().unwrap(), b"hello-tcp");
        server.join().unwrap();
    }

    #[test]
    fn large_frame_roundtrip() {
        // A flat BERT-tiny update is ~2.7 MB; verify multi-MB frames.
        let l = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr();
        let server = thread::spawn(move || {
            let mut c = l.accept().unwrap();
            let f = c.recv().unwrap();
            c.send(&f).unwrap();
        });
        let mut c = TcpDialer.dial(&addr).unwrap();
        let big: Vec<u8> = (0..3_000_000u32).map(|i| i as u8).collect();
        c.send(&big).unwrap();
        assert_eq!(c.recv().unwrap(), big);
        server.join().unwrap();
    }

    #[test]
    fn oversize_frame_rejected_on_send() {
        let l = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr();
        let _server = thread::spawn(move || {
            let _c = l.accept();
            thread::sleep(Duration::from_millis(50));
        });
        let mut c = TcpDialer.dial(&addr).unwrap();
        let too_big = vec![0u8; MAX_FRAME + 1];
        assert!(c.send(&too_big).is_err());
    }

    #[test]
    fn oversize_frame_rejected_on_recv() {
        // A hostile peer bypasses the send-side check with a raw socket
        // and claims a frame beyond MAX_FRAME; recv must reject the
        // length prefix without allocating the claimed buffer.
        let l = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr();
        let writer = thread::spawn(move || {
            use std::io::Write;
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            let len = ((MAX_FRAME + 1) as u32).to_be_bytes();
            s.write_all(&len).unwrap();
            s.write_all(&[0u8; 64]).unwrap();
            // Keep the socket open until the server side has rejected.
            thread::sleep(Duration::from_millis(100));
        });
        let mut c = l.accept().unwrap();
        let err = c.recv().unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME"), "{err}");
        writer.join().unwrap();
    }

    #[test]
    fn peer_close_is_error_not_hang() {
        let l = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr();
        let server = thread::spawn(move || {
            let c = l.accept().unwrap();
            drop(c); // close immediately
        });
        let mut c = TcpDialer.dial(&addr).unwrap();
        server.join().unwrap();
        assert!(c.recv().is_err());
    }
}
