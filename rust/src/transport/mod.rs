//! Transports: how clients reach the Florida services.
//!
//! The paper's clients speak gRPC or REST to a cloud endpoint. Offline we
//! provide two interchangeable transports behind one trait:
//!
//! * [`inproc`] — lock-free-ish channel transport for the device
//!   simulator (thousands of clients in one process).
//! * [`tcp`] — real `std::net` TCP with 4-byte length framing, exercising
//!   serialization, partial reads, and connection lifecycle.
//!
//! Frames are opaque byte vectors; the [`crate::proto`] envelope decides
//! binary ("gRPC") vs JSON ("REST") encoding per connection.

pub mod inproc;
pub mod tcp;

use crate::error::Result;

/// Maximum accepted frame (64 MiB) — large enough for a compressed
/// BERT-tiny snapshot, small enough to bound hostile allocations.
pub const MAX_FRAME: usize = 64 << 20;

/// A bidirectional, message-oriented connection.
pub trait Connection: Send {
    /// Send one frame (blocking).
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    /// Send one frame, consuming the buffer. Transports that can move
    /// the allocation (inproc channels) override this to skip the copy
    /// `send` would make; byte-stream transports use the default, which
    /// borrows and delegates. Frame producers (`encode_frame`) always
    /// yield owned buffers, so this is the server/client send path.
    fn send_owned(&mut self, frame: Vec<u8>) -> Result<()> {
        self.send(&frame)
    }
    /// Receive one frame (blocking; `Err` on close/timeout).
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Peer description for logs.
    fn peer(&self) -> String;
}

/// A listening endpoint producing connections.
pub trait Listener: Send {
    fn accept(&self) -> Result<Box<dyn Connection>>;
    /// Address clients should dial.
    fn local_addr(&self) -> String;
}

/// Client-side dialer.
pub trait Dialer: Send + Sync {
    fn dial(&self, addr: &str) -> Result<Box<dyn Connection>>;
}
