//! In-process channel transport — the AzureML-simulator analogue.
//!
//! A global registry maps string addresses to acceptors. `dial` performs a
//! handshake that hands the server an mpsc pair, after which both sides
//! exchange `Vec<u8>` frames with no serialization beyond the codec's.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, channel};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use super::{Connection, Dialer, Listener};
use crate::error::{Error, Result};

/// Receive timeout — generous; round orchestration has its own deadlines.
const RECV_TIMEOUT: Duration = Duration::from_secs(120);

type Handshake = (Sender<Vec<u8>>, Receiver<Vec<u8>>, String);
type Registry = Mutex<HashMap<String, Sender<Handshake>>>;

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The global address registry, poison surfaced as a transport error:
/// a panic in one simulated client must not take down every later
/// bind/dial in the process.
fn registry() -> Result<MutexGuard<'static, HashMap<String, Sender<Handshake>>>> {
    REGISTRY
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .map_err(|_| Error::Transport("inproc registry poisoned".into()))
}

/// One end of an in-process connection.
pub struct InprocConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    peer: String,
}

impl Connection for InprocConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.send_owned(frame.to_vec())
    }

    /// Zero-copy path: the frame's allocation moves straight into the
    /// channel — no per-frame `to_vec` double-buffering for the
    /// simulator's thousands of in-process clients.
    fn send_owned(&mut self, frame: Vec<u8>) -> Result<()> {
        self.tx
            .send(frame)
            .map_err(|_| Error::Transport("inproc peer closed".into()))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx
            .recv_timeout(RECV_TIMEOUT)
            .map_err(|e| Error::Transport(format!("inproc recv: {e}")))
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Listening side: registered under an address in the global registry.
pub struct InprocListener {
    addr: String,
    accept_rx: Receiver<Handshake>,
}

impl InprocListener {
    /// Bind an address. Errors if already bound.
    pub fn bind(addr: &str) -> Result<InprocListener> {
        let mut reg = registry()?;
        if reg.contains_key(addr) {
            return Err(Error::Transport(format!("inproc address {addr} in use")));
        }
        let (tx, rx) = channel();
        reg.insert(addr.to_string(), tx);
        Ok(InprocListener {
            addr: addr.to_string(),
            accept_rx: rx,
        })
    }
}

impl Listener for InprocListener {
    fn accept(&self) -> Result<Box<dyn Connection>> {
        let (tx, rx, peer) = self
            .accept_rx
            .recv_timeout(RECV_TIMEOUT)
            .map_err(|e| Error::Transport(format!("inproc accept: {e}")))?;
        Ok(Box::new(InprocConn { tx, rx, peer }))
    }

    fn local_addr(&self) -> String {
        self.addr.clone()
    }
}

impl Drop for InprocListener {
    fn drop(&mut self) {
        // Drop must not panic; a poisoned map is still a valid map, so
        // recover it to unregister the address.
        if let Some(reg) = REGISTRY.get() {
            reg.lock()
                .unwrap_or_else(|p| p.into_inner())
                .remove(&self.addr);
        }
    }
}

/// Dialer for in-process addresses.
pub struct InprocDialer;

impl Dialer for InprocDialer {
    fn dial(&self, addr: &str) -> Result<Box<dyn Connection>> {
        let acceptor = {
            let reg = registry()?;
            reg.get(addr)
                .cloned()
                .ok_or_else(|| Error::Transport(format!("no inproc listener at {addr}")))?
        };
        let (c2s_tx, c2s_rx) = channel();
        let (s2c_tx, s2c_rx) = channel();
        acceptor
            .send((s2c_tx, c2s_rx, format!("client->{addr}")))
            .map_err(|_| Error::Transport(format!("listener at {addr} gone")))?;
        Ok(Box::new(InprocConn {
            tx: c2s_tx,
            rx: s2c_rx,
            peer: addr.to_string(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn echo_roundtrip() {
        let l = InprocListener::bind("test-echo").unwrap();
        let server = thread::spawn(move || {
            let mut c = l.accept().unwrap();
            let f = c.recv().unwrap();
            c.send(&f).unwrap();
        });
        let mut c = InprocDialer.dial("test-echo").unwrap();
        c.send(b"ping").unwrap();
        assert_eq!(c.recv().unwrap(), b"ping");
        server.join().unwrap();
    }

    #[test]
    fn send_owned_moves_frame_without_copy() {
        let l = InprocListener::bind("test-owned").unwrap();
        let server = thread::spawn(move || {
            let mut c = l.accept().unwrap();
            let f = c.recv().unwrap();
            c.send_owned(f).unwrap();
        });
        let mut c = InprocDialer.dial("test-owned").unwrap();
        let frame = vec![42u8; 4096];
        let expect = frame.clone();
        c.send_owned(frame).unwrap();
        assert_eq!(c.recv().unwrap(), expect);
        server.join().unwrap();
    }

    #[test]
    fn dial_unbound_fails() {
        assert!(InprocDialer.dial("nope").is_err());
    }

    #[test]
    fn double_bind_fails_and_rebind_after_drop_works() {
        let l = InprocListener::bind("test-rebind").unwrap();
        assert!(InprocListener::bind("test-rebind").is_err());
        drop(l);
        let _l2 = InprocListener::bind("test-rebind").unwrap();
    }

    #[test]
    fn many_concurrent_clients() {
        let l = InprocListener::bind("test-many").unwrap();
        let server = thread::spawn(move || {
            for _ in 0..16 {
                let mut c = l.accept().unwrap();
                thread::spawn(move || {
                    let f = c.recv().unwrap();
                    c.send(&f).unwrap();
                });
            }
        });
        let clients: Vec<_> = (0..16)
            .map(|i| {
                thread::spawn(move || {
                    let mut c = InprocDialer.dial("test-many").unwrap();
                    let msg = vec![i as u8; 100];
                    c.send(&msg).unwrap();
                    assert_eq!(c.recv().unwrap(), msg);
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        server.join().unwrap();
    }
}
