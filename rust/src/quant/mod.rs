//! Fixed-point quantization for secure aggregation (§4.1).
//!
//! "For secure aggregation ... the model must be quantized and transformed
//! into an array of integers, an operation which can be only partially
//! reversed after the weights are aggregated."
//!
//! Scheme: values are clipped to [-r, r] and mapped affinely onto
//! `[0, 2^bits)`; masked sums are taken mod 2³². After aggregating `n`
//! clients the server subtracts `n` offsets and rescales. Headroom must
//! satisfy `bits + ceil(log2(n)) <= 32` or the modular sum wraps.

use crate::error::{Error, Result};

/// Quantizer configuration shared by clients and the aggregator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    /// Clip range: values are clamped to [-range, range].
    pub range: f32,
    /// Bits per coordinate (resolution 2r / 2^bits).
    pub bits: u32,
}

impl Quantizer {
    pub fn new(range: f32, bits: u32) -> Result<Quantizer> {
        if !(range > 0.0) {
            return Err(Error::Other(format!("quantizer range must be > 0, got {range}")));
        }
        if bits == 0 || bits > 30 {
            return Err(Error::Other(format!("quantizer bits must be in 1..=30, got {bits}")));
        }
        Ok(Quantizer { range, bits })
    }

    /// Paper-flavoured default: 20-bit lattice, headroom for 4096 clients.
    pub fn default_for(n_clients: usize) -> Quantizer {
        let head = (n_clients.max(2) as f64).log2().ceil() as u32 + 1;
        let bits = (32 - head).min(20);
        Quantizer { range: 4.0, bits }
    }

    #[inline]
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    #[inline]
    fn scale(&self) -> f32 {
        (self.levels() - 1) as f32 / (2.0 * self.range)
    }

    /// Max clients whose sum fits mod 2³² without wrapping.
    pub fn max_clients(&self) -> usize {
        (u32::MAX / (self.levels() - 1)) as usize
    }

    /// Quantize one value to a lattice point in [0, 2^bits).
    #[inline]
    pub fn quantize_one(&self, x: f32) -> u32 {
        let c = x.clamp(-self.range, self.range);
        // round-to-nearest onto the lattice
        (((c + self.range) * self.scale()) + 0.5) as u32
    }

    /// Dequantize a *single-client* lattice point.
    #[inline]
    pub fn dequantize_one(&self, q: u32) -> f32 {
        q as f32 / self.scale() - self.range
    }

    /// Quantize a vector. §Perf: scale is hoisted so the per-element work
    /// is clamp + fused multiply-add + cast (the division inside
    /// `scale()` dominated when recomputed per element).
    pub fn quantize(&self, xs: &[f32]) -> Vec<u32> {
        let scale = self.scale();
        let r = self.range;
        // NOTE: plain mul+add, not f32::mul_add — without -Ctarget-feature
        // =+fma the intrinsic lowers to a libm call and is ~2× slower.
        xs.iter()
            .map(|&x| ((x.clamp(-r, r) + r) * scale + 0.5) as u32)
            .collect()
    }

    /// Recover the *mean* of `n` clients from their (masked-summed mod 2³²)
    /// lattice values: subtract the n offsets, rescale, divide by n.
    pub fn dequantize_sum_to_mean(&self, sums: &[u32], n: usize) -> Result<Vec<f32>> {
        if n == 0 {
            return Err(Error::Other("dequantize with n=0".into()));
        }
        if n > self.max_clients() {
            return Err(Error::Other(format!(
                "{n} clients exceeds modular headroom for {} bits",
                self.bits
            )));
        }
        let scale = self.scale();
        let inv_n = 1.0 / n as f32;
        Ok(sums
            .iter()
            .map(|&s| (s as f32 * inv_n) / scale - self.range)
            .collect())
    }

    /// Worst-case per-coordinate rounding error (half a lattice step).
    pub fn step(&self) -> f32 {
        (2.0 * self.range) / (self.levels() - 1) as f32
    }
}

/// Wrapping (mod 2³²) element-wise accumulate: acc += xs.
pub fn add_mod(acc: &mut [u32], xs: &[u32]) {
    debug_assert_eq!(acc.len(), xs.len());
    for (a, &x) in acc.iter_mut().zip(xs) {
        *a = a.wrapping_add(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_error_within_half_step() {
        let q = Quantizer::new(1.0, 16).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = (rng.next_f32() - 0.5) * 2.0; // in [-1, 1)
            let err = (q.dequantize_one(q.quantize_one(x)) - x).abs();
            assert!(err <= q.step() * 0.5 + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn clipping_applied() {
        let q = Quantizer::new(0.5, 8).unwrap();
        assert_eq!(q.quantize_one(10.0), q.levels() - 1);
        assert_eq!(q.quantize_one(-10.0), 0);
    }

    #[test]
    fn sum_of_clients_recovers_mean() {
        let q = Quantizer::new(2.0, 16).unwrap();
        let mut rng = Rng::new(2);
        let n = 33;
        let dim = 257;
        let clients: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| (rng.next_f32() - 0.5) * 3.0).collect())
            .collect();
        let mut acc = vec![0u32; dim];
        for c in &clients {
            add_mod(&mut acc, &q.quantize(c));
        }
        let mean = q.dequantize_sum_to_mean(&acc, n).unwrap();
        for j in 0..dim {
            let want: f32 = clients.iter().map(|c| c[j].clamp(-2.0, 2.0)).sum::<f32>() / n as f32;
            assert!((mean[j] - want).abs() < q.step(), "{} vs {}", mean[j], want);
        }
    }

    #[test]
    fn headroom_enforced() {
        let q = Quantizer::new(1.0, 24).unwrap();
        assert!(q.dequantize_sum_to_mean(&[0], q.max_clients() + 1).is_err());
        assert!(q.dequantize_sum_to_mean(&[0], 2).is_ok());
    }

    #[test]
    fn default_for_scales_bits_down() {
        let small = Quantizer::default_for(8);
        let big = Quantizer::default_for(4096);
        assert!(small.bits >= big.bits);
        assert!(big.max_clients() >= 4096);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Quantizer::new(0.0, 16).is_err());
        assert!(Quantizer::new(-1.0, 16).is_err());
        assert!(Quantizer::new(1.0, 0).is_err());
        assert!(Quantizer::new(1.0, 31).is_err());
    }
}
