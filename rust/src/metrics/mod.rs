//! Metrics (§3.3.1 "Metrics" view): per-round records, export, the
//! text dashboard rendering used by the CLI task view, and per-RPC
//! service counters fed by the router's interceptor chain ([`rpc`]).

pub mod rpc;

pub use rpc::{RpcMetrics, RpcStat};

use crate::util::json::Json;

/// One completed aggregation round (or async buffer flush).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u64,
    pub started_ms: u64,
    pub ended_ms: u64,
    pub participants: usize,
    /// Mean reported client training loss.
    pub train_loss: f64,
    /// Server-side evaluation (if an evaluator is attached).
    pub eval_loss: Option<f64>,
    pub eval_accuracy: Option<f64>,
    /// Privacy spent so far (ε at the task δ), if DP is on.
    pub epsilon: Option<f64>,
}

impl RoundRecord {
    pub fn duration_ms(&self) -> u64 {
        self.ended_ms.saturating_sub(self.started_ms)
    }
}

/// Per-task metrics history.
#[derive(Clone, Debug, Default)]
pub struct TaskMetrics {
    pub rounds: Vec<RoundRecord>,
    /// Rounds that missed min_report_fraction and were retried.
    pub failed_rounds: u64,
    /// Total uploads accepted (incl. async buffer contributions).
    pub total_uploads: u64,
}

impl TaskMetrics {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    pub fn mean_round_duration_ms(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.duration_ms() as f64).sum::<f64>()
            / self.rounds.len() as f64
    }

    /// CSV export (one row per round) — dashboard drill-down equivalent.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,started_ms,ended_ms,duration_ms,participants,train_loss,eval_loss,eval_accuracy,epsilon\n",
        );
        for r in &self.rounds {
            s.push_str(&format!(
                "{},{},{},{},{},{:.6},{},{},{}\n",
                r.round,
                r.started_ms,
                r.ended_ms,
                r.duration_ms(),
                r.participants,
                r.train_loss,
                r.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.eval_accuracy.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.epsilon.map(|v| format!("{v:.4}")).unwrap_or_default(),
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                let mut j = Json::obj()
                    .set("round", r.round)
                    .set("duration_ms", r.duration_ms())
                    .set("participants", r.participants)
                    .set("train_loss", r.train_loss);
                if let Some(v) = r.eval_loss {
                    j = j.set("eval_loss", v);
                }
                if let Some(v) = r.eval_accuracy {
                    j = j.set("eval_accuracy", v);
                }
                if let Some(v) = r.epsilon {
                    j = j.set("epsilon", v);
                }
                j
            })
            .collect();
        Json::obj()
            .set("rounds", Json::Arr(rounds))
            .set("failed_rounds", self.failed_rounds)
            .set("total_uploads", self.total_uploads)
    }

    /// Render the task-view style text dashboard (§3.3.1 Task View).
    pub fn render_dashboard(&self, task_name: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("Task: {task_name}\n"));
        out.push_str(&format!(
            "rounds completed: {}   failed/retried: {}   uploads: {}\n",
            self.rounds.len(),
            self.failed_rounds,
            self.total_uploads
        ));
        out.push_str(
            "round  participants  duration     train-loss   eval-acc   eval-loss   epsilon\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{:>5}  {:>12}  {:>9}ms  {:>10.4}  {:>9}  {:>9}  {:>8}\n",
                r.round,
                r.participants,
                r.duration_ms(),
                r.train_loss,
                r.eval_accuracy
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_else(|| "-".into()),
                r.eval_loss
                    .map(|v| format!("{v:.4}"))
                    .unwrap_or_else(|| "-".into()),
                r.epsilon
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".into()),
            ));
        }
        // ASCII accuracy sparkline across rounds.
        let accs: Vec<f64> = self
            .rounds
            .iter()
            .filter_map(|r| r.eval_accuracy)
            .collect();
        if accs.len() >= 2 {
            out.push_str("accuracy: ");
            for &a in &accs {
                let idx = ((a.clamp(0.0, 1.0)) * 7.0).round() as usize;
                out.push(['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'][idx]);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u64, dur: u64, acc: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            started_ms: 1000 * round,
            ended_ms: 1000 * round + dur,
            participants: 32,
            train_loss: 0.5 / (round + 1) as f64,
            eval_loss: acc.map(|a| 1.0 - a),
            eval_accuracy: acc,
            epsilon: Some(0.2 * round as f64),
        }
    }

    #[test]
    fn duration_and_mean() {
        let mut m = TaskMetrics::default();
        m.push(rec(0, 100, Some(0.6)));
        m.push(rec(1, 300, Some(0.9)));
        assert_eq!(m.rounds[0].duration_ms(), 100);
        assert!((m.mean_round_duration_ms() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = TaskMetrics::default();
        m.push(rec(0, 100, Some(0.5)));
        let csv = m.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("0.500000"));
    }

    #[test]
    fn json_export_parses_back() {
        let mut m = TaskMetrics::default();
        m.push(rec(0, 50, None));
        m.push(rec(1, 60, Some(0.8)));
        m.failed_rounds = 1;
        let j = m.to_json();
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("rounds").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(back.req_usize("failed_rounds").unwrap(), 1);
    }

    #[test]
    fn dashboard_renders() {
        let mut m = TaskMetrics::default();
        for i in 0..5 {
            m.push(rec(i, 100, Some(0.5 + 0.1 * i as f64)));
        }
        let d = m.render_dashboard("spam");
        assert!(d.contains("Task: spam"));
        assert!(d.contains("accuracy: "));
        assert!(d.lines().count() >= 8);
    }
}
