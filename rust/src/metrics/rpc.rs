//! Per-RPC server metrics, fed by the router's `MetricsInterceptor`
//! (§3.3.1 "Metrics" view, service-level drill-down): call counts,
//! error counts, and latency per wire method.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Aggregate statistics for one RPC method.
#[derive(Clone, Debug, Default)]
pub struct RpcStat {
    /// Requests that reached the metrics interceptor (admitted by auth).
    pub calls: u64,
    /// Replies that were protocol errors (`ErrorReply`, negative acks).
    pub errors: u64,
    pub total_ns: u128,
    pub max_ns: u128,
}

impl RpcStat {
    pub fn mean_ns(&self) -> f64 {
        if self.calls == 0 {
            return 0.0;
        }
        self.total_ns as f64 / self.calls as f64
    }
}

/// Thread-safe per-method RPC counters. One instance per server,
/// shared with the router's interceptor chain.
#[derive(Debug, Default)]
pub struct RpcMetrics {
    inner: Mutex<HashMap<&'static str, RpcStat>>,
}

impl RpcMetrics {
    /// Record one completed dispatch for `method`.
    pub fn record(&self, method: &'static str, elapsed: Duration, error: bool) {
        let ns = elapsed.as_nanos();
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(method).or_default();
        s.calls += 1;
        if error {
            s.errors += 1;
        }
        s.total_ns += ns;
        s.max_ns = s.max_ns.max(ns);
    }

    /// Snapshot of one method's counters (`None` if never called).
    pub fn get(&self, method: &str) -> Option<RpcStat> {
        self.inner.lock().unwrap().get(method).cloned()
    }

    /// Total calls across all methods.
    pub fn total_calls(&self) -> u64 {
        self.inner.lock().unwrap().values().map(|s| s.calls).sum()
    }

    /// Sorted (method, stat) snapshot for dashboards/exports.
    pub fn snapshot(&self) -> Vec<(&'static str, RpcStat)> {
        let mut v: Vec<(&'static str, RpcStat)> = self
            .inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (*k, s.clone()))
            .collect();
        v.sort_by_key(|(k, _)| *k);
        v
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (method, s) in self.snapshot() {
            obj = obj.set(
                method,
                Json::obj()
                    .set("calls", s.calls)
                    .set("errors", s.errors)
                    .set("mean_us", s.mean_ns() / 1e3)
                    .set("max_us", s.max_ns as f64 / 1e3),
            );
        }
        obj
    }

    /// Aligned text table (CLI service view).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "method            calls   errors   mean(us)    max(us)\n",
        );
        for (method, s) in self.snapshot() {
            out.push_str(&format!(
                "{:<16} {:>6}  {:>7}  {:>9.1}  {:>9.1}\n",
                method,
                s.calls,
                s.errors,
                s.mean_ns() / 1e3,
                s.max_ns as f64 / 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_counts_errors_and_latency() {
        let m = RpcMetrics::default();
        m.record("poll_task", Duration::from_micros(10), false);
        m.record("poll_task", Duration::from_micros(30), true);
        m.record("register", Duration::from_micros(5), false);
        let s = m.get("poll_task").unwrap();
        assert_eq!(s.calls, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.max_ns, 30_000);
        assert!((s.mean_ns() - 20_000.0).abs() < 1.0);
        assert_eq!(m.total_calls(), 3);
        assert!(m.get("fetch_round").is_none());
    }

    #[test]
    fn snapshot_sorted_and_renders() {
        let m = RpcMetrics::default();
        m.record("upload_plain", Duration::from_micros(1), false);
        m.record("register", Duration::from_micros(1), false);
        let snap = m.snapshot();
        assert_eq!(snap[0].0, "register");
        assert_eq!(snap[1].0, "upload_plain");
        let text = m.render();
        assert!(text.contains("upload_plain"));
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("register").unwrap().req_usize("calls").unwrap(),
            1
        );
    }
}
