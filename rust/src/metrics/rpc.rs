//! Per-RPC server metrics, fed by the router's `MetricsInterceptor`
//! (§3.3.1 "Metrics" view, service-level drill-down): call counts,
//! error counts, and full latency distributions per wire method.
//!
//! Lock-free by construction: the method set is the closed wire surface
//! (`proto::rpc`), so the registry is a fixed array of atomic cells —
//! `record` is a name lookup plus relaxed atomic adds into a
//! [`Histogram`], never a mutex. The poll/upload fast path takes no new
//! lock here, and a poisoned-mutex panic in the interceptor chain is
//! impossible (the bug class the old `Mutex<HashMap>` implementation
//! carried; the `panicking-lock` lint now covers `metrics/` too).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::export::RpcReport;
use crate::obs::Histogram;
use crate::util::json::Json;

/// Every wire method the router can dispatch, sorted, plus the
/// `"unknown"` spillover slot for names outside the closed set (kept
/// last so the sorted order of real methods is the array order).
const METHODS: [&str; 17] = [
    "fetch_round",
    "forward_partial",
    "get_task_status",
    "get_telemetry",
    "heartbeat",
    "join_round",
    "leaf_assign",
    "poll_task",
    "register",
    "secagg_shares",
    "session_close",
    "session_heartbeat",
    "session_open",
    "unmask_response",
    "upload_plain",
    "upload_masked",
    "unknown",
];

/// Aggregate statistics for one RPC method.
#[derive(Clone, Debug, Default)]
pub struct RpcStat {
    /// Requests that reached the metrics interceptor (admitted by auth).
    pub calls: u64,
    /// Replies that were protocol errors (`ErrorReply`, negative acks).
    pub errors: u64,
    pub total_ns: u128,
    pub max_ns: u128,
}

impl RpcStat {
    pub fn mean_ns(&self) -> f64 {
        if self.calls == 0 {
            return 0.0;
        }
        self.total_ns as f64 / self.calls as f64
    }
}

/// One method's atomic cells. All orderings relaxed: cells are
/// independent monotone counters; exports tolerate in-flight skew.
#[derive(Default)]
struct MethodCell {
    calls: AtomicU64,
    errors: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
    latency: Histogram,
}

/// Thread-safe per-method RPC counters. One instance per server,
/// shared with the router's interceptor chain.
#[derive(Default)]
pub struct RpcMetrics {
    cells: [MethodCell; METHODS.len()],
}

impl std::fmt::Debug for RpcMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RpcMetrics")
            .field("total_calls", &self.total_calls())
            .finish_non_exhaustive()
    }
}

impl RpcMetrics {
    /// Slot index for `method`; unlisted names share the `"unknown"`
    /// spillover (a 17-entry linear scan beats any hash here).
    fn idx(method: &str) -> usize {
        METHODS
            .iter()
            .position(|m| *m == method)
            .unwrap_or(METHODS.len() - 1)
    }

    /// Record one completed dispatch for `method`.
    pub fn record(&self, method: &'static str, elapsed: Duration, error: bool) {
        let ns = u64::try_from(elapsed.as_nanos()).unwrap_or(u64::MAX);
        let cell = &self.cells[Self::idx(method)];
        cell.calls.fetch_add(1, Ordering::Relaxed);
        if error {
            cell.errors.fetch_add(1, Ordering::Relaxed);
        }
        cell.total_ns.fetch_add(ns, Ordering::Relaxed);
        cell.max_ns.fetch_max(ns, Ordering::Relaxed);
        cell.latency.record(ns);
    }

    fn stat_of(cell: &MethodCell) -> RpcStat {
        RpcStat {
            calls: cell.calls.load(Ordering::Relaxed),
            errors: cell.errors.load(Ordering::Relaxed),
            total_ns: cell.total_ns.load(Ordering::Relaxed) as u128,
            max_ns: cell.max_ns.load(Ordering::Relaxed) as u128,
        }
    }

    /// Snapshot of one method's counters (`None` if never called).
    pub fn get(&self, method: &str) -> Option<RpcStat> {
        let cell = &self.cells[Self::idx(method)];
        let stat = Self::stat_of(cell);
        if stat.calls == 0 {
            None
        } else {
            Some(stat)
        }
    }

    /// Latency distribution of one method (empty if never called).
    pub fn latency_of(&self, method: &str) -> crate::obs::HistogramSnapshot {
        self.cells[Self::idx(method)].latency.snapshot()
    }

    /// Total calls across all methods.
    pub fn total_calls(&self) -> u64 {
        self.cells
            .iter()
            .map(|c| c.calls.load(Ordering::Relaxed))
            .sum()
    }

    /// Sorted (method, stat) snapshot for dashboards/exports — only
    /// methods that have been called.
    pub fn snapshot(&self) -> Vec<(&'static str, RpcStat)> {
        let mut v: Vec<(&'static str, RpcStat)> = METHODS
            .iter()
            .zip(&self.cells)
            .map(|(m, c)| (*m, Self::stat_of(c)))
            .filter(|(_, s)| s.calls > 0)
            .collect();
        v.sort_by_key(|(k, _)| *k);
        v
    }

    /// Per-method latency digests for the telemetry export surface.
    pub fn report(&self) -> Vec<RpcReport> {
        let mut v: Vec<RpcReport> = METHODS
            .iter()
            .zip(&self.cells)
            .filter(|(_, c)| c.calls.load(Ordering::Relaxed) > 0)
            .map(|(m, c)| {
                let stat = Self::stat_of(c);
                let lat = c.latency.snapshot();
                RpcReport {
                    method: m,
                    calls: stat.calls,
                    errors: stat.errors,
                    mean_ns: stat.mean_ns(),
                    p50_ns: lat.p50(),
                    p95_ns: lat.p95(),
                    p99_ns: lat.p99(),
                    max_ns: stat.max_ns as u64,
                }
            })
            .collect();
        v.sort_by_key(|r| r.method);
        v
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for r in self.report() {
            obj = obj.set(
                r.method,
                Json::obj()
                    .set("calls", r.calls)
                    .set("errors", r.errors)
                    .set("mean_us", r.mean_ns / 1e3)
                    .set("p50_us", r.p50_ns as f64 / 1e3)
                    .set("p95_us", r.p95_ns as f64 / 1e3)
                    .set("p99_us", r.p99_ns as f64 / 1e3)
                    .set("max_us", r.max_ns as f64 / 1e3),
            );
        }
        obj
    }

    /// Aligned text table (CLI service view).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "method            calls   errors   mean(us)    p50(us)    p99(us)    max(us)\n",
        );
        for r in self.report() {
            out.push_str(&format!(
                "{:<16} {:>6}  {:>7}  {:>9.1}  {:>9.1}  {:>9.1}  {:>9.1}\n",
                r.method,
                r.calls,
                r.errors,
                r.mean_ns / 1e3,
                r.p50_ns as f64 / 1e3,
                r.p99_ns as f64 / 1e3,
                r.max_ns as f64 / 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_counts_errors_and_latency() {
        let m = RpcMetrics::default();
        m.record("poll_task", Duration::from_micros(10), false);
        m.record("poll_task", Duration::from_micros(30), true);
        m.record("register", Duration::from_micros(5), false);
        let s = m.get("poll_task").unwrap();
        assert_eq!(s.calls, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.max_ns, 30_000);
        assert!((s.mean_ns() - 20_000.0).abs() < 1.0);
        assert_eq!(m.total_calls(), 3);
        assert!(m.get("fetch_round").is_none());
    }

    #[test]
    fn snapshot_sorted_and_renders() {
        let m = RpcMetrics::default();
        m.record("upload_plain", Duration::from_micros(1), false);
        m.record("register", Duration::from_micros(1), false);
        let snap = m.snapshot();
        assert_eq!(snap[0].0, "register");
        assert_eq!(snap[1].0, "upload_plain");
        let text = m.render();
        assert!(text.contains("upload_plain"));
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            back.get("register").unwrap().req_usize("calls").unwrap(),
            1
        );
    }

    #[test]
    fn unlisted_methods_share_the_unknown_slot() {
        let m = RpcMetrics::default();
        m.record("not-a-method", Duration::from_micros(2), false);
        // The closed wire surface has no such method; the sample lands
        // in the spillover so total accounting never loses a call.
        assert!(m.get("register").is_none());
        assert_eq!(m.get("unknown").unwrap().calls, 1);
        assert_eq!(m.total_calls(), 1);
    }

    #[test]
    fn report_has_quantiles_from_the_latency_histogram() {
        let m = RpcMetrics::default();
        for us in [10u64, 10, 10, 10, 10, 10, 10, 10, 10, 800] {
            m.record("fetch_round", Duration::from_micros(us), false);
        }
        let r = m.report();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].method, "fetch_round");
        assert_eq!(r[0].calls, 10);
        // p50 sits in the 10µs band, p99 in the 800µs band.
        assert!(r[0].p50_ns < 100_000, "p50 {} ns", r[0].p50_ns);
        assert!(r[0].p99_ns >= 524_288, "p99 {} ns", r[0].p99_ns);
        assert_eq!(r[0].max_ns, 800_000);
        let lat = m.latency_of("fetch_round");
        assert_eq!(lat.count, 10);
        assert!(m.latency_of("register").is_empty());
        let j = m.to_json().to_string();
        assert!(j.contains("p99_us"));
    }

    #[test]
    fn concurrent_recording_never_drops_calls() {
        use std::sync::Arc;
        let m = Arc::new(RpcMetrics::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        m.record("upload_plain", Duration::from_nanos(50), false);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get("upload_plain").unwrap().calls, 20_000);
        assert_eq!(m.latency_of("upload_plain").count, 20_000);
    }
}
