//! Project Florida leader binary — see `florida help`.

fn main() {
    init_logger();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(florida::cli::run(&argv));
}

/// Minimal env_logger substitute (offline crate set has only the `log`
/// facade): RUST_LOG=debug|info|warn|error, default info.
fn init_logger() {
    struct StderrLogger(log::LevelFilter);
    impl log::Log for StderrLogger {
        fn enabled(&self, metadata: &log::Metadata) -> bool {
            metadata.level() <= self.0
        }
        fn log(&self, record: &log::Record) {
            if self.enabled(record.metadata()) {
                eprintln!("[{:<5} {}] {}", record.level(), record.target(), record.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("trace") => log::LevelFilter::Trace,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger(level)));
    log::set_max_level(level);
}
