//! Synthetic spam corpus (§5.1 substitution for SetFit/enron-spam).
//!
//! The paper trains BERT-tiny on Enron Spam split into 100 equal shards.
//! Offline we synthesize a text-classification corpus with the same task
//! shape: token sequences drawn from a Zipf "background vocabulary"
//! (natural-language-like frequencies) mixed with class-indicative tokens
//! ("spammy"/"hammy" words) at a configurable rate. The signal-to-noise
//! knob controls how hard the task is; the default makes 10 federated
//! rounds land in the paper's Fig-11 accuracy regime (high 90s for FL
//! without DP) without being trivially separable from one batch.

use crate::util::Rng;

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct SpamCorpusConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Probability a token is class-indicative rather than background.
    pub indicator_rate: f64,
    /// Zipf exponent of the background distribution.
    pub zipf_s: f64,
    pub seed: u64,
}

impl SpamCorpusConfig {
    pub fn for_model(vocab: usize, seq_len: usize) -> SpamCorpusConfig {
        SpamCorpusConfig {
            vocab,
            seq_len,
            n_train: 6_700, // ~100 shards × 67 examples (paper's per-round use)
            n_test: 512,
            indicator_rate: 0.10,
            zipf_s: 1.2,
            seed: 0x5AA4_u64, // "SPAM"
        }
    }
}

/// A labelled token-sequence dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub seq_len: usize,
    /// Row-major [n, seq_len].
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// The full spam task data: train set, test set, and shard assignment.
pub struct SpamCorpus {
    pub train: Dataset,
    pub test: Dataset,
    /// Shard id → example indices into `train`.
    pub shards: Vec<Vec<usize>>,
}

/// Token-range layout inside the vocabulary.
/// [0, bg_end) — Zipf background shared by both classes
/// [bg_end, bg_end + ind) — ham-indicative
/// [bg_end + ind, vocab) — spam-indicative
fn ranges(vocab: usize) -> (usize, usize) {
    let bg_end = vocab * 3 / 4;
    let ind = (vocab - bg_end) / 2;
    (bg_end, ind)
}

fn gen_example(cfg: &SpamCorpusConfig, label: i32, rng: &mut Rng, out: &mut Vec<i32>) {
    let (bg_end, ind) = ranges(cfg.vocab);
    for _ in 0..cfg.seq_len {
        let tok = if rng.chance(cfg.indicator_rate) {
            let base = if label == 0 { bg_end } else { bg_end + ind };
            rng.range(base, base + ind)
        } else {
            rng.zipf(bg_end, cfg.zipf_s)
        };
        out.push(tok as i32);
    }
}

fn gen_dataset(cfg: &SpamCorpusConfig, n: usize, rng: &mut Rng) -> Dataset {
    let mut tokens = Vec::with_capacity(n * cfg.seq_len);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let label = if rng.chance(0.5) { 1 } else { 0 };
        gen_example(cfg, label, rng, &mut tokens);
        labels.push(label);
    }
    Dataset {
        seq_len: cfg.seq_len,
        tokens,
        labels,
    }
}

impl SpamCorpus {
    /// Generate the corpus and split the train set into `n_shards` equal
    /// shards (paper: "we split the dataset in 100 subsets of same size").
    pub fn generate(cfg: &SpamCorpusConfig, n_shards: usize) -> SpamCorpus {
        let mut rng = Rng::new(cfg.seed);
        let train = gen_dataset(cfg, cfg.n_train, &mut rng);
        let test = gen_dataset(cfg, cfg.n_test, &mut rng);
        let mut idx: Vec<usize> = (0..train.len()).collect();
        rng.shuffle(&mut idx);
        let per = train.len() / n_shards.max(1);
        let shards = (0..n_shards)
            .map(|s| idx[s * per..(s + 1) * per].to_vec())
            .collect();
        SpamCorpus { train, test, shards }
    }

    /// Non-IID variant: shard class mix drawn from Dirichlet(alpha) —
    /// small alpha → heavily label-skewed shards (real cross-device data).
    pub fn generate_non_iid(
        cfg: &SpamCorpusConfig,
        n_shards: usize,
        alpha: f64,
    ) -> SpamCorpus {
        let mut rng = Rng::new(cfg.seed);
        let train = gen_dataset(cfg, cfg.n_train, &mut rng);
        let test = gen_dataset(cfg, cfg.n_test, &mut rng);
        // Partition indices by class, then deal to shards by per-shard
        // class proportions.
        let mut by_class: [Vec<usize>; 2] = [Vec::new(), Vec::new()];
        for (i, &l) in train.labels.iter().enumerate() {
            by_class[l as usize].push(i);
        }
        for c in by_class.iter_mut() {
            rng.shuffle(c);
        }
        let per = train.len() / n_shards.max(1);
        let mut cursors = [0usize, 0usize];
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let p = rng.dirichlet(alpha, 2);
            let mut want1 = (p[1] * per as f64).round() as usize;
            want1 = want1.min(per);
            let mut shard = Vec::with_capacity(per);
            for _ in 0..want1 {
                if cursors[1] < by_class[1].len() {
                    shard.push(by_class[1][cursors[1]]);
                    cursors[1] += 1;
                } else if cursors[0] < by_class[0].len() {
                    shard.push(by_class[0][cursors[0]]);
                    cursors[0] += 1;
                }
            }
            while shard.len() < per {
                if cursors[0] < by_class[0].len() {
                    shard.push(by_class[0][cursors[0]]);
                    cursors[0] += 1;
                } else if cursors[1] < by_class[1].len() {
                    shard.push(by_class[1][cursors[1]]);
                    cursors[1] += 1;
                } else {
                    break;
                }
            }
            shards.push(shard);
        }
        SpamCorpus { train, test, shards }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SpamCorpusConfig {
        let mut c = SpamCorpusConfig::for_model(512, 32);
        c.n_train = 1000;
        c.n_test = 100;
        c
    }

    #[test]
    fn corpus_shapes_and_ranges() {
        let c = cfg();
        let corpus = SpamCorpus::generate(&c, 10);
        assert_eq!(corpus.train.len(), 1000);
        assert_eq!(corpus.test.len(), 100);
        assert_eq!(corpus.train.tokens.len(), 1000 * 32);
        assert!(corpus.train.tokens.iter().all(|&t| t >= 0 && (t as usize) < c.vocab));
        assert!(corpus.train.labels.iter().all(|&l| l == 0 || l == 1));
        assert_eq!(corpus.shards.len(), 10);
        assert!(corpus.shards.iter().all(|s| s.len() == 100));
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        let corpus = SpamCorpus::generate(&cfg(), 10);
        let mut all: Vec<usize> = corpus.shards.iter().flatten().copied().collect();
        all.sort_unstable();
        let n = all.len();
        all.dedup();
        assert_eq!(all.len(), n);
        assert_eq!(n, 1000);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SpamCorpus::generate(&cfg(), 4);
        let b = SpamCorpus::generate(&cfg(), 4);
        assert_eq!(a.train.tokens, b.train.tokens);
        let mut c2 = cfg();
        c2.seed ^= 1;
        let c = SpamCorpus::generate(&c2, 4);
        assert_ne!(a.train.tokens, c.train.tokens);
    }

    #[test]
    fn classes_are_distinguishable_by_indicators() {
        // Mean count of spam-indicative tokens must differ strongly by class.
        let c = cfg();
        let corpus = SpamCorpus::generate(&c, 4);
        let (bg_end, ind) = super::ranges(c.vocab);
        let spam_lo = (bg_end + ind) as i32;
        let mut counts = [0f64; 2];
        let mut ns = [0f64; 2];
        for i in 0..corpus.train.len() {
            let label = corpus.train.labels[i] as usize;
            let k = corpus
                .train
                .row(i)
                .iter()
                .filter(|&&t| t >= spam_lo)
                .count();
            counts[label] += k as f64;
            ns[label] += 1.0;
        }
        let ham_rate = counts[0] / ns[0];
        let spam_rate = counts[1] / ns[1];
        assert!(spam_rate > ham_rate * 5.0, "{spam_rate} vs {ham_rate}");
    }

    #[test]
    fn zipf_background_is_skewed() {
        let c = cfg();
        let corpus = SpamCorpus::generate(&c, 4);
        let (bg_end, _) = super::ranges(c.vocab);
        let mut hist = vec![0usize; bg_end];
        for &t in &corpus.train.tokens {
            if (t as usize) < bg_end {
                hist[t as usize] += 1;
            }
        }
        assert!(hist[0] > hist[bg_end / 2].max(1) * 3);
    }

    #[test]
    fn non_iid_skews_shard_labels() {
        let corpus = SpamCorpus::generate_non_iid(&cfg(), 10, 0.2);
        // With alpha=0.2 at least one shard should be > 80% one class.
        let mut max_skew: f64 = 0.0;
        for s in &corpus.shards {
            let ones = s.iter().filter(|&&i| corpus.train.labels[i] == 1).count();
            let frac = ones as f64 / s.len() as f64;
            max_skew = max_skew.max(frac.max(1.0 - frac));
        }
        assert!(max_skew > 0.8, "max skew {max_skew}");
        // And shards still cover the right total.
        let total: usize = corpus.shards.iter().map(Vec::len).sum();
        assert_eq!(total, 1000);
    }
}
