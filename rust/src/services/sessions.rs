//! Session registry (protocol v2): the server's view of the live fleet.
//!
//! Every v2 client holds a **liveness lease**: granted at `SessionOpen`
//! together with the negotiated protocol version, renewed by
//! `SessionHeartbeat` (which carries [`LoadHints`]), and swept when it
//! expires — [`SessionRegistry::sweep`] returns the evicted client ids so
//! the orchestrator can repair open cohorts instead of waiting out the
//! round deadline. v1 clients get an *implicit* session the first time
//! they send a bare `Heartbeat` ([`SessionRegistry::touch_v1`]), so the
//! legacy liveness ping participates in the same eviction machinery.
//!
//! The registry is also the capability store: the [`DeviceProfile`] a
//! device submitted at open is served to cohort policies through
//! [`LiveDirectory`], which pairs it with the selection registry's
//! [`DeviceCaps`] — that is how `Tiered` partitions by reported compute
//! tier.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

use crate::error::{Error, Result};
use crate::orchestrator::ClientDirectory;
use crate::proto::{DeviceCaps, DeviceProfile, LoadHints};
use crate::services::selection::SelectionService;

/// Token issued to v1 implicit sessions (bare `Heartbeat`, no handshake).
pub const IMPLICIT_TOKEN: u64 = 0;

/// One live client session.
#[derive(Clone, Debug)]
pub struct Session {
    pub client_id: u64,
    /// Renewal credential; [`IMPLICIT_TOKEN`] for v1 implicit sessions.
    pub token: u64,
    pub profile: DeviceProfile,
    /// Negotiated protocol version.
    pub proto: u32,
    pub opened_ms: u64,
    /// Lease expiry; the sweep evicts at `now >= expires_ms`.
    pub expires_ms: u64,
    /// Last load/battery hints carried by a heartbeat.
    pub hints: LoadHints,
}

struct Inner {
    lease_ms: u64,
    next_token: u64,
    live: HashMap<u64, Session>,
}

/// Registry of live sessions keyed by client id.
pub struct SessionRegistry {
    inner: Mutex<Inner>,
}

impl SessionRegistry {
    pub fn new(lease_ms: u64) -> SessionRegistry {
        SessionRegistry {
            inner: Mutex::new(Inner {
                lease_ms: lease_ms.max(1),
                next_token: 1,
                live: HashMap::new(),
            }),
        }
    }

    /// Lock the registry, recovering from poisoning: every mutation in
    /// this file is a single-step map insert/remove/field write, so a
    /// guard abandoned by a panicking thread still holds a structurally
    /// intact map — panicking the server thread that inherited it would
    /// turn one crashed request into fleet-wide session loss.
    fn locked(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn lease_ms(&self) -> u64 {
        self.locked().lease_ms
    }

    /// Adjust the lease granted to new opens/renewals (CLI `--lease-ms`,
    /// simulator scenarios, tests).
    pub fn set_lease_ms(&self, lease_ms: u64) {
        self.locked().lease_ms = lease_ms.max(1);
    }

    /// Open (or replace) the client's session: a fresh token and a full
    /// lease. Returns `(token, lease_ms)`.
    pub fn open(
        &self,
        client_id: u64,
        profile: DeviceProfile,
        proto: u32,
        now_ms: u64,
    ) -> (u64, u64) {
        let mut g = self.locked();
        let token = g.next_token;
        g.next_token += 1;
        let lease_ms = g.lease_ms;
        g.live.insert(
            client_id,
            Session {
                client_id,
                token,
                profile,
                proto,
                opened_ms: now_ms,
                expires_ms: now_ms + lease_ms,
                hints: LoadHints::default(),
            },
        );
        (token, lease_ms)
    }

    /// Renew the lease. The token must match the live session — a stale
    /// token (the session was replaced or evicted) forces a reopen, so a
    /// zombie client can never keep an abandoned session alive.
    pub fn renew(&self, client_id: u64, token: u64, hints: LoadHints, now_ms: u64) -> Result<u64> {
        let mut g = self.locked();
        let lease_ms = g.lease_ms;
        let s = g
            .live
            .get_mut(&client_id)
            .ok_or_else(|| Error::Selection(format!("no live session for client {client_id}")))?;
        if s.token != token {
            return Err(Error::Selection(format!(
                "stale session token for client {client_id}"
            )));
        }
        s.expires_ms = now_ms + lease_ms;
        s.hints = hints;
        Ok(lease_ms)
    }

    /// v1 compatibility: a bare `Heartbeat` renews the client's
    /// *implicit* session, or opens one (default profile, token
    /// [`IMPLICIT_TOKEN`]) so legacy clients join the liveness
    /// machinery. A negotiated v2 session is deliberately NOT renewed
    /// here — it must present its token via `SessionHeartbeat`, so a
    /// zombie's token-free heartbeat cannot keep a replaced session
    /// alive (same guarantee [`SessionRegistry::renew`] enforces).
    pub fn touch_v1(&self, client_id: u64, now_ms: u64) {
        let mut g = self.locked();
        let lease_ms = g.lease_ms;
        if let Some(s) = g.live.get_mut(&client_id) {
            if s.token == IMPLICIT_TOKEN {
                s.expires_ms = now_ms + lease_ms;
            }
            return;
        }
        g.live.insert(
            client_id,
            Session {
                client_id,
                token: IMPLICIT_TOKEN,
                profile: DeviceProfile::default(),
                proto: crate::proto::PROTO_V1,
                opened_ms: now_ms,
                expires_ms: now_ms + lease_ms,
                hints: LoadHints::default(),
            },
        );
    }

    /// Release a session early. Returns whether a matching session was
    /// closed (a stale token closes nothing).
    pub fn close(&self, client_id: u64, token: u64) -> bool {
        let mut g = self.locked();
        match g.live.get(&client_id) {
            Some(s) if s.token == token => {
                g.live.remove(&client_id);
                true
            }
            _ => false,
        }
    }

    /// Evict every expired lease; returns the evicted client ids (sorted,
    /// for deterministic downstream handling).
    pub fn sweep(&self, now_ms: u64) -> Vec<u64> {
        let mut g = self.locked();
        let mut evicted: Vec<u64> = g
            .live
            .values()
            .filter(|s| now_ms >= s.expires_ms)
            .map(|s| s.client_id)
            .collect();
        for id in &evicted {
            g.live.remove(id);
        }
        evicted.sort_unstable();
        evicted
    }

    pub fn get(&self, client_id: u64) -> Option<Session> {
        self.locked().live.get(&client_id).cloned()
    }

    pub fn profile_of(&self, client_id: u64) -> Option<DeviceProfile> {
        self.locked().live.get(&client_id).map(|s| s.profile)
    }

    pub fn live_count(&self) -> usize {
        self.locked().live.len()
    }
}

/// The capability view handed to cohort policies: device caps from the
/// selection registry, heterogeneity profile from the live session
/// (routed to the client's home shard — with one shard this is the old
/// flat registry).
pub struct LiveDirectory<'a> {
    pub selection: &'a SelectionService,
    pub sessions: &'a crate::shard::ShardedSessions,
}

impl ClientDirectory for LiveDirectory<'_> {
    fn caps_of(&self, client_id: u64) -> Option<DeviceCaps> {
        self.selection.caps_of(client_id)
    }

    fn profile_of(&self, client_id: u64) -> Option<DeviceProfile> {
        self.sessions.profile_of(client_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{ComputeTier, PROTO_V2};

    fn profile(tier: ComputeTier) -> DeviceProfile {
        DeviceProfile {
            compute_tier: tier,
            ..Default::default()
        }
    }

    #[test]
    fn open_renew_and_expire() {
        let reg = SessionRegistry::new(1000);
        let (token, lease) = reg.open(1, profile(ComputeTier::High), PROTO_V2, 0);
        assert_eq!(lease, 1000);
        assert_eq!(reg.live_count(), 1);
        assert_eq!(reg.profile_of(1).unwrap().compute_tier, ComputeTier::High);
        // Renewal extends the lease and records the hints.
        let hints = LoadHints {
            load: 0.5,
            battery: 0.25,
            charging: false,
        };
        assert_eq!(reg.renew(1, token, hints, 900).unwrap(), 1000);
        assert_eq!(reg.get(1).unwrap().expires_ms, 1900);
        assert_eq!(reg.get(1).unwrap().hints, hints);
        // Not yet expired: sweep leaves it alone.
        assert!(reg.sweep(1899).is_empty());
        // Expired: swept and gone.
        assert_eq!(reg.sweep(1900), vec![1]);
        assert!(reg.get(1).is_none());
        assert!(reg.renew(1, token, LoadHints::default(), 2000).is_err());
    }

    #[test]
    fn stale_token_cannot_renew_or_close() {
        let reg = SessionRegistry::new(1000);
        let (t1, _) = reg.open(1, DeviceProfile::default(), PROTO_V2, 0);
        // Reopen replaces the session; the old token is dead.
        let (t2, _) = reg.open(1, DeviceProfile::default(), PROTO_V2, 10);
        assert_ne!(t1, t2);
        assert!(reg.renew(1, t1, LoadHints::default(), 20).is_err());
        assert!(!reg.close(1, t1));
        assert_eq!(reg.live_count(), 1);
        assert!(reg.close(1, t2));
        assert_eq!(reg.live_count(), 0);
    }

    #[test]
    fn v1_touch_opens_implicit_session_and_expires() {
        let reg = SessionRegistry::new(500);
        reg.touch_v1(7, 0);
        let s = reg.get(7).unwrap();
        assert_eq!(s.token, IMPLICIT_TOKEN);
        assert_eq!(s.proto, crate::proto::PROTO_V1);
        // Repeated touches renew; an un-heartbeated client expires.
        reg.touch_v1(7, 400);
        assert!(reg.sweep(600).is_empty());
        assert_eq!(reg.sweep(900), vec![7]);
    }

    #[test]
    fn v1_touch_cannot_renew_a_negotiated_session() {
        let reg = SessionRegistry::new(500);
        let (token, _) = reg.open(3, profile(ComputeTier::Low), PROTO_V2, 0);
        // A token-free legacy heartbeat must not extend a v2 lease — a
        // zombie could otherwise keep a replaced session alive forever.
        reg.touch_v1(3, 100);
        let s = reg.get(3).unwrap();
        assert_eq!(s.token, token, "touch must not rotate or replace the session");
        assert_eq!(s.profile.compute_tier, ComputeTier::Low);
        assert_eq!(s.expires_ms, 500, "v2 lease unchanged by bare heartbeat");
        // The token path still renews it.
        reg.renew(3, token, LoadHints::default(), 100).unwrap();
        assert_eq!(reg.get(3).unwrap().expires_ms, 600);
    }

    #[test]
    fn sweep_returns_sorted_ids() {
        let reg = SessionRegistry::new(100);
        for id in [9u64, 2, 5] {
            reg.open(id, DeviceProfile::default(), PROTO_V2, 0);
        }
        assert_eq!(reg.sweep(100), vec![2, 5, 9]);
    }

    #[test]
    fn live_directory_combines_caps_and_profile() {
        let sel = SelectionService::new(1);
        let reg = crate::shard::ShardedSessions::new(1000);
        let id = sel.register("dir-dev", DeviceCaps::default(), 0);
        reg.open(id, profile(ComputeTier::High), PROTO_V2, 0);
        let dir = LiveDirectory {
            selection: &sel,
            sessions: &reg,
        };
        assert!(dir.caps_of(id).is_some());
        assert_eq!(dir.profile_of(id).unwrap().compute_tier, ComputeTier::High);
        // Sessionless client: caps only (profile falls back to None).
        let other = sel.register("capless", DeviceCaps::default(), 0);
        assert!(dir.caps_of(other).is_some());
        assert!(dir.profile_of(other).is_none());
    }
}
