//! Authentication Service (§3.1.5): validates device attestation before a
//! device may join any federated task.
//!
//! The trusted third party (Play Integrity / SysIntegrity) is simulated by
//! [`crate::crypto::attest::Authority`]. This service checks: signature,
//! expiry, nonce freshness (replay defence), and identity binding.

use std::collections::HashSet;
use std::sync::Mutex;

use crate::crypto::attest::{Authority, Verdict};
use crate::error::{Error, Result};

/// Authentication service state.
pub struct AuthService {
    authority: Authority,
    /// Nonces already accepted (replay defence).
    seen_nonces: Mutex<HashSet<(String, u64)>>,
    /// When false, devices are admitted without attestation (dev mode —
    /// the paper's attestation is Android/Huawei-only).
    pub required: bool,
}

impl AuthService {
    pub fn new(authority_key: &[u8], required: bool) -> AuthService {
        AuthService {
            authority: Authority::new(authority_key),
            seen_nonces: Mutex::new(HashSet::new()),
            required,
        }
    }

    /// Access to the simulated authority so tests/simulator can issue
    /// verdicts "from the trusted third party".
    pub fn authority(&self) -> &Authority {
        &self.authority
    }

    /// Validate a verdict presented by `device_id` at time `now_ms`.
    pub fn validate(&self, device_id: &str, v: &Verdict, now_ms: u64) -> Result<()> {
        if !self.required {
            return Ok(());
        }
        if v.device_id != device_id {
            return Err(Error::Attestation(format!(
                "verdict bound to {:?}, presented by {:?}",
                v.device_id, device_id
            )));
        }
        if now_ms >= v.expires_ms {
            return Err(Error::Attestation(format!(
                "verdict expired at {} (now {now_ms})",
                v.expires_ms
            )));
        }
        if !self.authority.verify(v) {
            return Err(Error::Attestation("bad signature".into()));
        }
        // Security-critical: NEVER recover a poisoned replay set. A
        // half-observed insert could let a replayed nonce through, so a
        // poisoned guard fails closed as an attestation error.
        let mut seen = self
            .seen_nonces
            .lock()
            .map_err(|_| Error::Attestation("nonce replay set poisoned".into()))?;
        if !seen.insert((v.device_id.clone(), v.nonce)) {
            return Err(Error::Attestation(format!("replayed nonce {}", v.nonce)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::attest::IntegrityTier;

    fn svc() -> AuthService {
        AuthService::new(b"test-authority", true)
    }

    #[test]
    fn valid_verdict_accepted_once() {
        let s = svc();
        let v = s.authority().issue("d1", IntegrityTier::Device, 1, 1000);
        assert!(s.validate("d1", &v, 10).is_ok());
        // replay
        assert!(matches!(
            s.validate("d1", &v, 11),
            Err(Error::Attestation(_))
        ));
    }

    #[test]
    fn expired_rejected() {
        let s = svc();
        let v = s.authority().issue("d1", IntegrityTier::Device, 2, 100);
        assert!(s.validate("d1", &v, 100).is_err());
        assert!(s.validate("d1", &v, 1000).is_err());
    }

    #[test]
    fn identity_binding_enforced() {
        let s = svc();
        let v = s.authority().issue("d1", IntegrityTier::Device, 3, 1000);
        assert!(s.validate("d2", &v, 10).is_err());
    }

    #[test]
    fn forged_signature_rejected() {
        let s = svc();
        let other = Authority::new(b"evil");
        let v = other.issue("d1", IntegrityTier::Strong, 4, 1000);
        assert!(s.validate("d1", &v, 10).is_err());
    }

    #[test]
    fn optional_mode_admits_everything() {
        let s = AuthService::new(b"k", false);
        let other = Authority::new(b"evil");
        let v = other.issue("d1", IntegrityTier::Strong, 5, 0);
        assert!(s.validate("d1", &v, 10).is_ok());
    }

    #[test]
    fn distinct_nonces_accepted() {
        let s = svc();
        for n in 0..10 {
            let v = s.authority().issue("d1", IntegrityTier::Device, n, 1000);
            assert!(s.validate("d1", &v, 10).is_ok());
        }
    }
}
