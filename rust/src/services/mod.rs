//! Back-end services (§3.1): Authentication, Selection, Secure Aggregator,
//! Master Aggregator, and the Management Service that orchestrates them.
//! `server.rs` glues them behind one dispatch surface shared by the
//! in-process simulator and the TCP/inproc wire transports.

pub mod auth;
pub mod management;
pub mod master_aggregator;
pub mod secure_aggregator;
pub mod selection;
pub mod server;

pub use server::FloridaServer;
