//! Back-end services (§3.1): Authentication, Selection, Sessions (the
//! protocol-v2 liveness-lease registry), Secure Aggregator, Master
//! Aggregator, and the Management Service — a thin multi-tenant
//! registry over the per-task round engines in [`crate::orchestrator`].
//! `router.rs` exposes them as four FLaaS-style [`router::Service`]s
//! behind an ordered interceptor chain (auth → policy → metrics →
//! backpressure, with `policy.rs` holding the admission engine);
//! `server.rs` assembles the platform and keeps `handle()` as a thin
//! shim over the router, shared by the in-process simulator and the
//! TCP/inproc wire transports.

pub mod auth;
pub mod management;
pub mod master_aggregator;
pub mod policy;
pub mod router;
pub mod secure_aggregator;
pub mod selection;
pub mod server;
pub mod sessions;

pub use policy::PolicyEngine;
pub use server::FloridaServer;
pub use sessions::{LiveDirectory, SessionRegistry};
