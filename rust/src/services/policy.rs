//! Admission policy engine: the gate every consequential request
//! passes before any service (and therefore any `RoundEngine`) sees it.
//!
//! Three coupled mechanisms, all driven by the router clock
//! (`RequestCtx::now_ms`, so manual-clock tests are deterministic):
//!
//! * **Token-bucket rate limits** keyed by client principal: each
//!   request spends one token; buckets refill at `refill_per_sec` up to
//!   `bucket_capacity`. A drained bucket sheds the request before it
//!   reaches the service.
//! * **Per-tenant quotas**: task-discovery traffic (`PollTask`) is
//!   counted per app name in fixed windows; a tenant over
//!   `tenant_quota` is refused for the rest of the window.
//! * **Reputation with decay**: every eviction and every engine-level
//!   ingest rejection (`Ack { ok: false }` on the aggregation surface —
//!   NaN deltas, wrong dims, duplicate spam) costs
//!   `reputation_penalty`; reputation recovers toward 1.0 at
//!   `reputation_recovery_per_sec`. Clients below `min_reputation` are
//!   refused outright until they earn their way back.
//!
//! The engine is shared between [`PolicyInterceptor`] (in the router
//! chain, after auth so `ctx.principal` is set) and
//! [`crate::services::FloridaServer::tick`] (which reports lease
//! evictions). Offenses are recorded even while `enabled` is false, so
//! a deployment can observe reputations before turning enforcement on.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::config::PolicyConfig;
use crate::error::{Error, Result};
use crate::proto::{rpc, Msg};

use super::router::{Interceptor, RequestCtx, ServiceKind};
use super::FloridaServer;

/// Per-client admission state: the token bucket plus the reputation
/// ledger, both lazily advanced to the current clock on access.
#[derive(Clone, Copy, Debug)]
struct ClientState {
    tokens: f64,
    reputation: f64,
    advanced_ms: u64,
}

impl ClientState {
    fn new(cfg: &PolicyConfig, now_ms: u64) -> ClientState {
        ClientState {
            tokens: cfg.bucket_capacity,
            reputation: 1.0,
            advanced_ms: now_ms,
        }
    }

    /// Refill tokens and recover reputation for the elapsed time.
    fn advance(&mut self, cfg: &PolicyConfig, now_ms: u64) {
        let dt = now_ms.saturating_sub(self.advanced_ms) as f64 / 1000.0;
        self.tokens = (self.tokens + dt * cfg.refill_per_sec).min(cfg.bucket_capacity);
        self.reputation =
            (self.reputation + dt * cfg.reputation_recovery_per_sec).min(1.0);
        self.advanced_ms = now_ms;
    }
}

/// One tenant's fixed quota window.
#[derive(Clone, Copy, Debug)]
struct TenantWindow {
    start_ms: u64,
    count: u64,
}

struct Inner {
    cfg: PolicyConfig,
    clients: HashMap<u64, ClientState>,
    tenants: HashMap<String, TenantWindow>,
    /// Requests refused by policy since boot (observability).
    rejected: u64,
}

/// The shared policy engine. One per server, threaded into the router
/// chain as a [`PolicyInterceptor`].
pub struct PolicyEngine {
    inner: Mutex<Inner>,
    // Shed counters live outside the Mutex so the telemetry export
    // path never contends with (or blocks behind) admission decisions.
    shed_reputation: AtomicU64,
    shed_rate: AtomicU64,
    shed_quota: AtomicU64,
}

impl PolicyEngine {
    pub fn new(cfg: PolicyConfig) -> PolicyEngine {
        PolicyEngine {
            inner: Mutex::new(Inner {
                cfg,
                clients: HashMap::new(),
                tenants: HashMap::new(),
                rejected: 0,
            }),
            shed_reputation: AtomicU64::new(0),
            shed_rate: AtomicU64::new(0),
            shed_quota: AtomicU64::new(0),
        }
    }

    /// Poison-aware lock (same contract as the management registry): a
    /// panicking request thread must not turn every later admission
    /// decision into a panic. `Err` fails closed on the admit path.
    fn locked(&self) -> Result<MutexGuard<'_, Inner>> {
        self.inner
            .lock()
            .map_err(|_| Error::Server("policy engine poisoned".into()))
    }

    /// Swap the active configuration (validated first). Existing
    /// buckets/reputations carry over; capacities clamp on next use.
    pub fn set_config(&self, cfg: PolicyConfig) -> Result<()> {
        cfg.validate()?;
        self.locked()?.cfg = cfg;
        Ok(())
    }

    pub fn config(&self) -> PolicyConfig {
        self.locked().map(|g| g.cfg).unwrap_or_default()
    }

    /// Requests refused by policy since boot.
    pub fn rejections(&self) -> u64 {
        self.locked().map(|g| g.rejected).unwrap_or(0)
    }

    /// A client's current reputation, if the engine has seen it.
    pub fn reputation_of(&self, client_id: u64) -> Option<f64> {
        self.locked().ok()?.clients.get(&client_id).map(|s| s.reputation)
    }

    /// The admission decision for one routed request. `Err` becomes the
    /// `ErrorReply` shed before any service runs. Composes the two
    /// shard-routable halves — the client gate, then the tenant quota —
    /// exactly as [`crate::shard::ShardedPolicy`] does across engines,
    /// so N=1 and the single-engine path share one code shape.
    pub fn admit(&self, msg: &Msg, ctx: &RequestCtx) -> Result<()> {
        // Reputation gate + token bucket, for requests that act as a
        // client principal (auth ran first, so `ctx.principal` is the
        // verified identity; pre-registration traffic has none).
        if let Some(id) = ctx.principal.or_else(|| rpc::client_id_of(msg)) {
            self.admit_principal(id, ctx.now_ms)?;
        }
        // Per-tenant quota on task discovery.
        if matches!(msg, Msg::PollTask { .. }) {
            self.admit_tenant(msg, ctx.now_ms)?;
        }
        Ok(())
    }

    /// Client half of admission: the reputation floor and the token
    /// bucket, keyed by principal — the part a sharded deployment
    /// routes to the client's home shard.
    pub fn admit_principal(&self, id: u64, now_ms: u64) -> Result<()> {
        let mut g = self.locked()?;
        if !g.cfg.enabled {
            return Ok(());
        }
        let cfg = g.cfg;
        let refusal = {
            let st = g
                .clients
                .entry(id)
                .or_insert_with(|| ClientState::new(&cfg, now_ms));
            st.advance(&cfg, now_ms);
            if st.reputation < cfg.min_reputation {
                self.shed_reputation.fetch_add(1, Relaxed);
                Some(format!(
                    "policy: client {id} reputation {:.2} below floor {:.2}",
                    st.reputation, cfg.min_reputation
                ))
            } else if st.tokens < 1.0 {
                self.shed_rate.fetch_add(1, Relaxed);
                Some(format!("policy: client {id} over rate limit"))
            } else {
                st.tokens -= 1.0;
                None
            }
        };
        if let Some(reason) = refusal {
            g.rejected += 1;
            return Err(Error::Server(reason));
        }
        Ok(())
    }

    /// Tenant half of admission: `PollTask` discovery counted per app
    /// name in fixed windows — routed by app-name hash when sharded.
    /// Non-discovery messages pass without taking the lock.
    pub fn admit_tenant(&self, msg: &Msg, now_ms: u64) -> Result<()> {
        let Msg::PollTask { app_name, .. } = msg else {
            return Ok(());
        };
        let mut g = self.locked()?;
        if !g.cfg.enabled || g.cfg.tenant_quota == 0 {
            return Ok(());
        }
        let cfg = g.cfg;
        let over = {
            let w = g.tenants.entry(app_name.clone()).or_insert(TenantWindow {
                start_ms: now_ms,
                count: 0,
            });
            if now_ms.saturating_sub(w.start_ms) >= cfg.quota_window_ms {
                w.start_ms = now_ms;
                w.count = 0;
            }
            w.count += 1;
            w.count > cfg.tenant_quota
        };
        if over {
            self.shed_quota.fetch_add(1, Relaxed);
            g.rejected += 1;
            return Err(Error::Server(format!(
                "policy: tenant {app_name:?} over quota ({} per {} ms)",
                cfg.tenant_quota, cfg.quota_window_ms
            )));
        }
        Ok(())
    }

    /// Charge one offense (eviction, rejected ingest) against a client.
    pub fn record_offense(&self, client_id: u64, now_ms: u64, what: &str) {
        let Ok(mut g) = self.locked() else {
            return;
        };
        let cfg = g.cfg;
        let st = g
            .clients
            .entry(client_id)
            .or_insert_with(|| ClientState::new(&cfg, now_ms));
        st.advance(&cfg, now_ms);
        st.reputation = (st.reputation - cfg.reputation_penalty).max(0.0);
        log::debug!(
            "policy: client {client_id} penalized for {what} (reputation {:.2})",
            st.reputation
        );
    }

    /// Sheds broken down by refusal reason, for the telemetry export
    /// surface. Lock-free: safe to call from the snapshot path even
    /// while admission decisions are in flight.
    pub fn shed_counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("policy_shed_reputation", self.shed_reputation.load(Relaxed)),
            ("policy_shed_rate", self.shed_rate.load(Relaxed)),
            ("policy_shed_quota", self.shed_quota.load(Relaxed)),
        ]
    }

    /// Session-sweep feedback: evicted clients lose reputation, so a
    /// device that repeatedly joins and goes dark stops being drafted
    /// into cohorts once its score sinks below the floor.
    pub fn record_evictions(&self, evicted: &[u64], now_ms: u64) {
        for &id in evicted {
            self.record_offense(id, now_ms, "lease eviction");
        }
    }
}

/// The router-chain face of the policy engine. Sits after
/// [`super::router::AuthInterceptor`] (it needs the verified principal)
/// and ahead of metrics/backpressure, so refused traffic never counts
/// as served and never occupies an in-flight slot. Holds the sharded
/// wrapper so admission routes to the principal's home shard — with
/// one shard this is exactly the old single-engine chain.
pub struct PolicyInterceptor {
    engine: Arc<crate::shard::ShardedPolicy>,
}

impl PolicyInterceptor {
    pub fn new(engine: Arc<crate::shard::ShardedPolicy>) -> PolicyInterceptor {
        PolicyInterceptor { engine }
    }
}

impl Interceptor for PolicyInterceptor {
    fn name(&self) -> &'static str {
        "policy"
    }

    fn before(&self, _: &FloridaServer, ctx: &mut RequestCtx, msg: &Msg) -> Result<()> {
        self.engine.admit(msg, ctx)
    }

    fn after(&self, _: &FloridaServer, ctx: &RequestCtx, reply: &Msg, _: Duration) {
        // Engine-level ingest rejections (NaN deltas, wrong dims,
        // duplicate spam) feed the reputation ledger. Only structured
        // negative Acks count: router-level `ErrorReply`s (backpressure
        // sheds, unroutable frames) are not the client's model update.
        if ctx.service == ServiceKind::AggregationIngest
            && matches!(reply, Msg::Ack { ok: false, .. })
        {
            if let Some(id) = ctx.principal {
                self.engine.record_offense(id, ctx.now_ms, ctx.method);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(now_ms: u64, principal: Option<u64>) -> RequestCtx {
        RequestCtx {
            now_ms,
            service: ServiceKind::Task,
            method: "fetch_round",
            principal,
            trace_id: None,
        }
    }

    fn heartbeat(id: u64) -> Msg {
        Msg::Heartbeat { client_id: id }
    }

    fn strict() -> PolicyConfig {
        PolicyConfig {
            enabled: true,
            bucket_capacity: 2.0,
            refill_per_sec: 1.0,
            tenant_quota: 3,
            quota_window_ms: 1_000,
            min_reputation: 0.5,
            reputation_penalty: 0.3,
            reputation_recovery_per_sec: 0.1,
        }
    }

    #[test]
    fn disabled_engine_admits_everything() {
        let e = PolicyEngine::new(PolicyConfig::default());
        for _ in 0..10_000 {
            e.admit(&heartbeat(7), &ctx(0, Some(7))).unwrap();
        }
        assert_eq!(e.rejections(), 0);
    }

    #[test]
    fn token_bucket_drains_and_refills() {
        let e = PolicyEngine::new(strict());
        e.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
        e.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
        let err = e.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap_err();
        assert!(err.to_string().contains("rate limit"), "{err}");
        // Another client has its own bucket.
        e.admit(&heartbeat(2), &ctx(0, Some(2))).unwrap();
        // One second refills one token.
        e.admit(&heartbeat(1), &ctx(1_000, Some(1))).unwrap();
        assert_eq!(e.rejections(), 1);
    }

    #[test]
    fn reputation_floor_refuses_then_recovers() {
        let e = PolicyEngine::new(strict());
        e.record_offense(5, 0, "test");
        e.record_offense(5, 0, "test");
        assert!(e.reputation_of(5).unwrap() < 0.5);
        let err = e.admit(&heartbeat(5), &ctx(0, Some(5))).unwrap_err();
        assert!(err.to_string().contains("reputation"), "{err}");
        // 0.1/s recovery: ~2 s back over the 0.5 floor.
        e.admit(&heartbeat(5), &ctx(2_100, Some(5))).unwrap();
    }

    #[test]
    fn eviction_feedback_lowers_reputation() {
        let e = PolicyEngine::new(strict());
        e.record_evictions(&[8, 9], 0);
        assert!((e.reputation_of(8).unwrap() - 0.7).abs() < 1e-9);
        assert!((e.reputation_of(9).unwrap() - 0.7).abs() < 1e-9);
        assert_eq!(e.reputation_of(10), None);
    }

    #[test]
    fn tenant_quota_windows_roll() {
        let e = PolicyEngine::new(strict());
        let poll = |id: u64, app: &str| Msg::PollTask {
            client_id: id,
            app_name: app.into(),
            workflow_name: "w".into(),
        };
        // Distinct clients so individual buckets stay warm: only the
        // shared tenant window fills.
        for id in 0..3 {
            e.admit(&poll(id, "mail"), &ctx(0, None)).unwrap();
        }
        let err = e.admit(&poll(3, "mail"), &ctx(0, None)).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        // Other tenants are unaffected; the window rolls over.
        e.admit(&poll(4, "keyboard"), &ctx(0, None)).unwrap();
        e.admit(&poll(5, "mail"), &ctx(1_000, None)).unwrap();
    }

    #[test]
    fn shed_counters_break_down_by_reason() {
        let e = PolicyEngine::new(strict());
        // Rate: drain client 1's two-token bucket, then one more.
        e.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
        e.admit(&heartbeat(1), &ctx(0, Some(1))).unwrap();
        assert!(e.admit(&heartbeat(1), &ctx(0, Some(1))).is_err());
        // Reputation: sink client 5 below the floor, then knock.
        e.record_offense(5, 0, "test");
        e.record_offense(5, 0, "test");
        assert!(e.admit(&heartbeat(5), &ctx(0, Some(5))).is_err());
        // Quota: four distinct clients polling one tenant.
        let poll = |id: u64| Msg::PollTask {
            client_id: id,
            app_name: "mail".into(),
            workflow_name: "w".into(),
        };
        for id in 10..13 {
            e.admit(&poll(id), &ctx(0, None)).unwrap();
        }
        assert!(e.admit(&poll(13), &ctx(0, None)).is_err());
        let shed: HashMap<&str, u64> = e.shed_counters().into_iter().collect();
        assert_eq!(shed["policy_shed_rate"], 1);
        assert_eq!(shed["policy_shed_reputation"], 1);
        assert_eq!(shed["policy_shed_quota"], 1);
    }

    #[test]
    fn offenses_recorded_while_disabled_then_enforced() {
        let e = PolicyEngine::new(PolicyConfig::default());
        e.record_offense(3, 0, "observe");
        e.record_offense(3, 0, "observe");
        e.record_offense(3, 0, "observe");
        e.admit(&heartbeat(3), &ctx(0, Some(3))).unwrap();
        e.set_config(strict()).unwrap();
        assert!(e.admit(&heartbeat(3), &ctx(0, Some(3))).is_err());
    }
}
