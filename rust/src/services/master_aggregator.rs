//! Master Aggregator (§3.1.3): stage two of the aggregation pipeline.
//!
//! Owns the task's aggregation strategy ("user-defined logic"), optional
//! central DP noise, and the server learning rate. Ingest is streaming:
//! the round engine opens a fold with [`MasterAggregator::begin_fold`],
//! feeds each upload at arrival, and [`MasterAggregator::commit_fold`]
//! finishes the fold, noises, and advances the global [`SnapshotStore`]
//! (one version bump — which also invalidates the distribution cache).

use crate::aggregation::{Aggregator, AggregatorFold, UpdateStats};
use crate::dp::{DpConfig, DpMode, GaussianMechanism};
use crate::error::Result;
use crate::model::SnapshotStore;
use crate::services::secure_aggregator::VgInterim;
use crate::util::Rng;

/// Master aggregator: stateless policy over a mutable global snapshot.
pub struct MasterAggregator {
    strategy: Box<dyn Aggregator>,
    dp: DpConfig,
    server_lr: f32,
}

impl MasterAggregator {
    pub fn new(strategy: Box<dyn Aggregator>, dp: DpConfig, server_lr: f32) -> MasterAggregator {
        MasterAggregator {
            strategy,
            dp,
            server_lr,
        }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Open a streaming ingest fold for one round / buffer epoch.
    pub fn begin_fold(&self, dim: usize) -> Result<Box<dyn AggregatorFold>> {
        self.strategy.begin(dim)
    }

    /// Finish `fold`, apply optional central DP noise, and advance the
    /// model. Returns the number of folded contributors.
    pub fn commit_fold(
        &self,
        global: &mut SnapshotStore,
        fold: Box<dyn AggregatorFold>,
        rng: &mut Rng,
    ) -> Result<usize> {
        let participants = fold.count();
        let mut combined = fold.finish()?;
        self.maybe_central_noise(&mut combined, rng);
        global.apply_delta(&combined, self.server_lr)?;
        Ok(participants)
    }

    /// Secure path: stream per-VG interims (stage two of §3.1.2's
    /// two-stage process) through the strategy fold, weighting each
    /// interim by its contributor count.
    pub fn apply_interims(
        &self,
        global: &mut SnapshotStore,
        interims: &[VgInterim],
        rng: &mut Rng,
    ) -> Result<usize> {
        let first = interims
            .first()
            .ok_or_else(|| crate::error::Error::Other("no interims to aggregate".into()))?;
        let mut fold = self.strategy.begin(first.mean_delta.len())?;
        for iv in interims {
            fold.accept(
                &iv.mean_delta,
                &UpdateStats {
                    client_id: iv.vg_id as u64,
                    weight: iv.contributors as f64,
                    loss: iv.mean_loss,
                    staleness: 0,
                },
            )?;
        }
        self.commit_fold(global, fold, rng)?;
        Ok(interims.iter().map(|iv| iv.contributors).sum())
    }

    fn maybe_central_noise(&self, delta: &mut [f32], rng: &mut Rng) {
        if self.dp.mode == DpMode::Central {
            GaussianMechanism::add_noise(
                delta,
                self.dp.clip_norm,
                self.dp.noise_multiplier,
                rng,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::FedAvg;
    use crate::model::ModelSnapshot;

    fn store(params: Vec<f32>) -> SnapshotStore {
        SnapshotStore::new(ModelSnapshot::new(0, params))
    }

    fn feed(
        ma: &MasterAggregator,
        global: &mut SnapshotStore,
        updates: &[(u64, Vec<f32>, f64)],
        rng: &mut Rng,
    ) -> Result<usize> {
        let mut fold = ma.begin_fold(global.dim())?;
        for (id, delta, weight) in updates {
            fold.accept(
                delta,
                &UpdateStats {
                    client_id: *id,
                    weight: *weight,
                    loss: 0.5,
                    staleness: 0,
                },
            )?;
        }
        ma.commit_fold(global, fold, rng)
    }

    #[test]
    fn streaming_commit_moves_model() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = store(vec![0.0, 0.0]);
        let mut rng = Rng::new(1);
        let n = feed(
            &ma,
            &mut global,
            &[(1, vec![1.0, 0.0], 1.0), (2, vec![0.0, 1.0], 1.0)],
            &mut rng,
        )
        .unwrap();
        assert_eq!(n, 2);
        assert_eq!(global.version, 1);
        assert!((global.params[0] - 0.5).abs() < 1e-6);
        assert!((global.params[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn server_lr_scales_step() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 0.5);
        let mut global = store(vec![0.0]);
        let mut rng = Rng::new(2);
        feed(&ma, &mut global, &[(1, vec![2.0], 1.0)], &mut rng).unwrap();
        assert!((global.params[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn commit_invalidates_distribution_cache() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = store(vec![0.0; 16]);
        let mut rng = Rng::new(9);
        let before = global.compressed().unwrap();
        feed(&ma, &mut global, &[(1, vec![1.0; 16], 1.0)], &mut rng).unwrap();
        let after = global.compressed().unwrap();
        assert!(!std::sync::Arc::ptr_eq(&before, &after));
        let decoded = ModelSnapshot::from_compressed(&after).unwrap();
        assert_eq!(decoded.version, 1);
    }

    #[test]
    fn interims_weighted_by_contributors() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = SnapshotStore::new(ModelSnapshot::new(3, vec![0.0]));
        let mut rng = Rng::new(3);
        let interims = vec![
            VgInterim {
                vg_id: 0,
                mean_delta: vec![1.0],
                contributors: 3,
                mean_loss: 0.2,
            },
            VgInterim {
                vg_id: 1,
                mean_delta: vec![-1.0],
                contributors: 1,
                mean_loss: 0.9,
            },
        ];
        let n = ma.apply_interims(&mut global, &interims, &mut rng).unwrap();
        assert_eq!(n, 4);
        // (3*1 + 1*(-1)) / 4 = 0.5
        assert!((global.params[0] - 0.5).abs() < 1e-6);
        assert_eq!(global.version, 4);
    }

    #[test]
    fn central_dp_adds_noise() {
        let dp = DpConfig {
            mode: DpMode::Central,
            clip_norm: 1.0,
            noise_multiplier: 1.0,
        };
        let ma = MasterAggregator::new(Box::new(FedAvg), dp, 1.0);
        let mut g1 = store(vec![0.0; 64]);
        let mut g2 = store(vec![0.0; 64]);
        let mut rng1 = Rng::new(4);
        let mut rng2 = Rng::new(5);
        feed(&ma, &mut g1, &[(1, vec![0.0; 64], 1.0)], &mut rng1).unwrap();
        feed(&ma, &mut g2, &[(1, vec![0.0; 64], 1.0)], &mut rng2).unwrap();
        // Zero update + central noise → nonzero, seed-dependent params.
        assert!(g1.params.iter().any(|&x| x != 0.0));
        assert_ne!(g1.params, g2.params);
    }

    #[test]
    fn empty_folds_error() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = store(vec![0.0]);
        let mut rng = Rng::new(6);
        let fold = ma.begin_fold(1).unwrap();
        assert!(ma.commit_fold(&mut global, fold, &mut rng).is_err());
        assert!(ma.apply_interims(&mut global, &[], &mut rng).is_err());
        assert_eq!(global.version, 0, "failed commit must not move the model");
    }
}
