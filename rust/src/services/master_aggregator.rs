//! Master Aggregator (§3.1.3): stage two of the aggregation pipeline.
//!
//! Combines per-VG interim results (or plaintext updates when secure
//! aggregation is off), applies the task's aggregation strategy
//! ("user-defined logic"), optional central DP noise, and updates the
//! global model snapshot.

use crate::aggregation::{Aggregator, ClientUpdate};
use crate::dp::{DpConfig, DpMode, GaussianMechanism};
use crate::error::Result;
use crate::model::ModelSnapshot;
use crate::services::secure_aggregator::VgInterim;
use crate::util::Rng;

/// Master aggregator: stateless policy over a mutable global snapshot.
pub struct MasterAggregator {
    strategy: Box<dyn Aggregator>,
    dp: DpConfig,
    server_lr: f32,
}

impl MasterAggregator {
    pub fn new(strategy: Box<dyn Aggregator>, dp: DpConfig, server_lr: f32) -> MasterAggregator {
        MasterAggregator {
            strategy,
            dp,
            server_lr,
        }
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    /// Plaintext path: aggregate client updates and advance the model.
    /// Returns the number of contributors.
    pub fn apply_plain(
        &self,
        global: &mut ModelSnapshot,
        updates: &[ClientUpdate],
        rng: &mut Rng,
    ) -> Result<usize> {
        let mut combined = self.strategy.aggregate(updates)?;
        self.maybe_central_noise(&mut combined, rng);
        global.apply_delta(&combined, self.server_lr)?;
        Ok(updates.len())
    }

    /// Secure path: combine VG interims (stage two of §3.1.2's two-stage
    /// process), weighting each interim by its contributor count.
    pub fn apply_interims(
        &self,
        global: &mut ModelSnapshot,
        interims: &[VgInterim],
        rng: &mut Rng,
    ) -> Result<usize> {
        // Interims are already per-VG means; convert to pseudo-updates so
        // the configured strategy applies uniformly.
        let updates: Vec<ClientUpdate> = interims
            .iter()
            .map(|iv| ClientUpdate {
                client_id: iv.vg_id as u64,
                delta: iv.mean_delta.clone(),
                weight: iv.contributors as f64,
                loss: iv.mean_loss,
                staleness: 0,
            })
            .collect();
        let mut combined = self.strategy.aggregate(&updates)?;
        self.maybe_central_noise(&mut combined, rng);
        global.apply_delta(&combined, self.server_lr)?;
        Ok(interims.iter().map(|iv| iv.contributors).sum())
    }

    fn maybe_central_noise(&self, delta: &mut [f32], rng: &mut Rng) {
        if self.dp.mode == DpMode::Central {
            GaussianMechanism::add_noise(
                delta,
                self.dp.clip_norm,
                self.dp.noise_multiplier,
                rng,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::FedAvg;

    fn upd(id: u64, delta: Vec<f32>, weight: f64) -> ClientUpdate {
        ClientUpdate {
            client_id: id,
            delta,
            weight,
            loss: 0.5,
            staleness: 0,
        }
    }

    #[test]
    fn plain_path_moves_model() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = ModelSnapshot::new(0, vec![0.0, 0.0]);
        let mut rng = Rng::new(1);
        let n = ma
            .apply_plain(
                &mut global,
                &[upd(1, vec![1.0, 0.0], 1.0), upd(2, vec![0.0, 1.0], 1.0)],
                &mut rng,
            )
            .unwrap();
        assert_eq!(n, 2);
        assert_eq!(global.version, 1);
        assert!((global.params[0] - 0.5).abs() < 1e-6);
        assert!((global.params[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn server_lr_scales_step() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 0.5);
        let mut global = ModelSnapshot::new(0, vec![0.0]);
        let mut rng = Rng::new(2);
        ma.apply_plain(&mut global, &[upd(1, vec![2.0], 1.0)], &mut rng)
            .unwrap();
        assert!((global.params[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn interims_weighted_by_contributors() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = ModelSnapshot::new(3, vec![0.0]);
        let mut rng = Rng::new(3);
        let interims = vec![
            VgInterim {
                vg_id: 0,
                mean_delta: vec![1.0],
                contributors: 3,
                mean_loss: 0.2,
            },
            VgInterim {
                vg_id: 1,
                mean_delta: vec![-1.0],
                contributors: 1,
                mean_loss: 0.9,
            },
        ];
        let n = ma.apply_interims(&mut global, &interims, &mut rng).unwrap();
        assert_eq!(n, 4);
        // (3*1 + 1*(-1)) / 4 = 0.5
        assert!((global.params[0] - 0.5).abs() < 1e-6);
        assert_eq!(global.version, 4);
    }

    #[test]
    fn central_dp_adds_noise() {
        let dp = DpConfig {
            mode: DpMode::Central,
            clip_norm: 1.0,
            noise_multiplier: 1.0,
        };
        let ma = MasterAggregator::new(Box::new(FedAvg), dp, 1.0);
        let mut g1 = ModelSnapshot::new(0, vec![0.0; 64]);
        let mut g2 = ModelSnapshot::new(0, vec![0.0; 64]);
        let mut rng1 = Rng::new(4);
        let mut rng2 = Rng::new(5);
        let ups = [upd(1, vec![0.0; 64], 1.0)];
        ma.apply_plain(&mut g1, &ups, &mut rng1).unwrap();
        ma.apply_plain(&mut g2, &ups, &mut rng2).unwrap();
        // Zero update + central noise → nonzero, seed-dependent params.
        assert!(g1.params.iter().any(|&x| x != 0.0));
        assert_ne!(g1.params, g2.params);
    }

    #[test]
    fn empty_updates_error() {
        let ma = MasterAggregator::new(Box::new(FedAvg), DpConfig::off(), 1.0);
        let mut global = ModelSnapshot::new(0, vec![0.0]);
        let mut rng = Rng::new(6);
        assert!(ma.apply_plain(&mut global, &[], &mut rng).is_err());
        assert!(ma.apply_interims(&mut global, &[], &mut rng).is_err());
    }
}
